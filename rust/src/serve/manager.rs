//! The token-keyed session manager: every verb of the wire protocol,
//! independent of any transport.
//!
//! One [`SessionManager`] multiplexes all tenants over the process's
//! worker pool.  Sessions own no threads: each verb executes on the
//! calling connection's thread under that tenant's own mutex, and the
//! heavy phases inside ask/tell (surrogate refits, pool scoring)
//! fan out through `util::parallel` exactly as CLI-driven sessions
//! do.  The global map lock is held only to look up or insert a
//! tenant's `Arc`, never across session work — a slow tenant delays
//! nobody else.
//!
//! Durability is by construction, not by protocol discipline:
//!
//! - every session lives under the PR 7 write-ahead
//!   [`SessionJournal`] in `<serve-root>/<token>/`, so the daemon can
//!   be SIGKILLed at any instant and a restart on the same root
//!   recovers every in-flight session bit-identically;
//! - an idle tenant is *evicted* by simply dropping its in-memory
//!   half (the journal already holds everything) and is lazily
//!   rehydrated — [`SessionJournal::resume`] + `replay_into` — on its
//!   next touch.  Eviction, daemon restart and client reconnect are
//!   therefore the same code path;
//! - a journaled-but-untold ask is re-materialized on rehydration (and
//!   verified against the journal), so a `tell` that raced a crash or
//!   arrived on a different connection than its `ask` still applies.
//!
//! `tell` is seq-keyed and idempotent: re-telling an already-answered
//! exchange is acknowledged as a duplicate without re-applying; a
//! `tell` for a seq the session never issued is a structured
//! `unknown-request` error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::{Algo, ScorerKind};
use crate::serve::cell::SessionCell;
use crate::serve::protocol::{
    batch_json, err_line, ok_line, parse_request, state_json, OpenSpec, Request, ServeError,
};
use crate::sim::{Objective, WorkflowRegistry};
use crate::tuner::journal::checkpoint_exists;
use crate::tuner::{
    replay_into, DiagSink, Evaluator, EvaluatorState, MeasurementBatch, MeasurementResult,
    SessionJournal, TraceError, TraceHeader,
};
use crate::util::fsio;
use crate::util::json::{self, Json};

/// Default idle TTL before a session is evicted to disk.
pub const DEFAULT_SESSION_TTL: Duration = Duration::from_secs(900);

/// Per-tenant diagnostics file (the session's `DiagSink::File`
/// target), kept beside the journal in the token directory.
pub const DIAG_FILE: &str = "diag.log";

/// The idempotent finish artifact: written atomically when a session
/// finishes, answered verbatim on any repeat `finish`.
pub const RESULT_FILE: &str = "result.json";

/// The evaluator is on the *client* side of the wire, so rehydration
/// replays journaled outcomes with no evaluator at all: the journal
/// carries every told value, `replay_into` never measures, and the
/// client's own evaluator state is restored client-side from the
/// journaled checkpoint returned by `open`.
struct RemoteEvaluator;

impl Evaluator for RemoteEvaluator {
    fn evaluate(&mut self, _batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        unreachable!("replay never measures; live measurement happens client-side")
    }
}

/// A tenant's in-memory half.  Everything here is reconstructible from
/// the journal: dropping a `Live` *is* eviction.
struct Live {
    cell: SessionCell,
    journal: SessionJournal,
    /// The asked-but-untold batch, keyed by its exchange seq
    /// (`journal.exchanges()` at ask time).  Kept so a re-`ask` after
    /// a reconnect is answered idempotently instead of panicking the
    /// session, and so `tell` can check arity.
    outstanding: Option<(usize, MeasurementBatch)>,
    /// True once `ask` returned (or would return) the empty batch.
    done: bool,
    /// Last evaluator checkpoint journaled with a tell — returned on
    /// resume-by-token so a restarted client can restore its own
    /// noise stream.
    last_eval: Option<EvaluatorState>,
}

struct Tenant {
    dir: PathBuf,
    live: Option<Live>,
    last_used: Instant,
}

impl Tenant {
    fn unloaded(dir: PathBuf) -> Tenant {
        Tenant {
            dir,
            live: None,
            last_used: Instant::now(),
        }
    }
}

/// Lock a tenant, treating a poisoned mutex like a crash: the
/// in-memory half may be torn mid-update, but the write-ahead journal
/// is the source of truth, so dropping the live state and rehydrating
/// is always safe.
fn lock_tenant(arc: &Arc<Mutex<Tenant>>) -> MutexGuard<'_, Tenant> {
    match arc.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            let mut g = poisoned.into_inner();
            g.live = None;
            g
        }
    }
}

/// Non-finite floats have no JSON literal; encode them as strings
/// (`"NaN"`, `"inf"`, `"-inf"` all parse back via `str::parse`).
/// Lazy pools report `NaN` ground truth by design, so `finish`
/// payloads must survive them.
fn float_json(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Str(x.to_string())
    }
}

/// The multi-tenant session registry for one serve root.
pub struct SessionManager {
    root: PathBuf,
    threads: usize,
    ttl: Option<Duration>,
    next_token: AtomicU64,
    tenants: Mutex<HashMap<String, Arc<Mutex<Tenant>>>>,
}

impl SessionManager {
    /// Open (creating if needed) a serve root.  `ttl: None` disables
    /// idle eviction (tests drive eviction explicitly).
    pub fn new(
        root: &Path,
        threads: usize,
        ttl: Option<Duration>,
    ) -> Result<SessionManager, ServeError> {
        std::fs::create_dir_all(root).map_err(|e| {
            ServeError::Trace(TraceError::Io(format!(
                "cannot create serve root {}: {e}",
                root.display()
            )))
        })?;
        Ok(SessionManager {
            root: root.to_path_buf(),
            threads,
            ttl,
            next_token: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
        })
    }

    /// The configured idle TTL.
    pub fn ttl(&self) -> Option<Duration> {
        self.ttl
    }

    /// The one transport-facing entry point: one request line in, one
    /// response line out.  Never panics outward, never drops the
    /// conversation — every failure is a structured error response.
    pub fn handle_line(&self, line: &str) -> String {
        match parse_request(line).and_then(|req| self.handle(req)) {
            Ok(resp) => resp,
            Err(e) => err_line(&e),
        }
    }

    /// Dispatch one decoded request.
    pub fn handle(&self, req: Request) -> Result<String, ServeError> {
        match req {
            Request::Open { token: Some(t), .. } => self.open_resume(&t),
            Request::Open { spec, .. } => {
                self.open_fresh(&spec.expect("parse_request yields spec when token absent"))
            }
            Request::Ask { token } => self.ask(&token),
            Request::Tell {
                token,
                seq,
                results,
                eval,
            } => self.tell(&token, seq, &results, eval),
            Request::State { token } => self.state(&token),
            Request::Finish { token } => self.finish(&token),
            Request::Close { token } => self.close(&token),
        }
    }

    // ---- verb implementations --------------------------------------

    fn open_fresh(&self, spec: &OpenSpec) -> Result<String, ServeError> {
        let header = header_for(spec)?;
        let token = self.allocate_token();
        let dir = self.root.join(&token);
        let journal =
            SessionJournal::create(&dir, &header, 0).map_err(ServeError::Trace)?;
        let mut cell = SessionCell::build(&header, 0, self.threads)?;
        cell.set_diag_sink(DiagSink::File(dir.join(DIAG_FILE)));
        cell.arm_from_header(&header);
        let live = Live {
            cell,
            journal,
            outstanding: None,
            done: false,
            last_eval: None,
        };
        let tenant = Tenant {
            dir,
            live: Some(live),
            last_used: Instant::now(),
        };
        self.tenants
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(token.clone(), Arc::new(Mutex::new(tenant)));
        Ok(ok_line(vec![
            ("token", Json::Str(token)),
            ("resumed", Json::Bool(false)),
            ("done", Json::Bool(false)),
            ("exchanges", Json::Num(0.0)),
            ("header", header.to_json()),
        ]))
    }

    fn open_resume(&self, token: &str) -> Result<String, ServeError> {
        self.with_live(token, |live| {
            let done = live.done;
            let exchanges = live.journal.exchanges();
            let mut pairs = vec![
                ("token", Json::Str(token.into())),
                ("resumed", Json::Bool(true)),
                ("done", Json::Bool(done)),
                ("exchanges", Json::Num(exchanges as f64)),
                ("header", live.journal.header().to_json()),
            ];
            if let Some(eval) = &live.last_eval {
                pairs.push(("eval", crate::tuner::journal::eval_json(eval)));
            }
            Ok(ok_line(pairs))
        })
    }

    fn ask(&self, token: &str) -> Result<String, ServeError> {
        self.with_live(token, |live| {
            if let Some((seq, batch)) = &live.outstanding {
                // idempotent re-ask: same batch, same seq — the
                // reconnecting client picks up where it left off
                return Ok(ok_line(vec![
                    ("done", Json::Bool(false)),
                    ("seq", Json::Num(*seq as f64)),
                    ("batch", batch_json(batch)),
                ]));
            }
            if live.done {
                return Ok(ok_line(vec![
                    ("done", Json::Bool(true)),
                    ("seq", Json::Num(live.journal.exchanges() as f64)),
                ]));
            }
            let batch = live.cell.session_mut().try_ask().ok_or_else(|| {
                ServeError::Trace(TraceError::StateMismatch {
                    detail: "session has an untold batch the manager lost track of".into(),
                })
            })?;
            if batch.is_empty() {
                live.done = true;
                return Ok(ok_line(vec![
                    ("done", Json::Bool(true)),
                    ("seq", Json::Num(live.journal.exchanges() as f64)),
                ]));
            }
            let seq = live.journal.exchanges();
            live.journal.record_ask(&batch);
            if let Some(e) = live.journal.error() {
                return Err(ServeError::Trace(e.clone()));
            }
            let resp = ok_line(vec![
                ("done", Json::Bool(false)),
                ("seq", Json::Num(seq as f64)),
                ("batch", batch_json(&batch)),
            ]);
            live.outstanding = Some((seq, batch));
            Ok(resp)
        })
    }

    fn tell(
        &self,
        token: &str,
        seq: usize,
        results: &[MeasurementResult],
        eval: Option<EvaluatorState>,
    ) -> Result<String, ServeError> {
        self.with_live(token, |live| {
            let duplicate = |seq: usize| {
                Ok(ok_line(vec![
                    ("duplicate", Json::Bool(true)),
                    ("seq", Json::Num(seq as f64)),
                ]))
            };
            match &live.outstanding {
                Some((cur, batch)) if seq == *cur => {
                    if results.len() != batch.len() {
                        return Err(ServeError::Usage(format!(
                            "tell for seq {seq} carries {} results but the batch has {} \
                             requests",
                            results.len(),
                            batch.len()
                        )));
                    }
                    live.journal.record_tell(results, eval);
                    live.cell.session_mut().tell(results);
                    let digest = live.cell.session_mut().digest();
                    live.journal.after_apply(digest);
                    live.outstanding = None;
                    live.last_eval = eval;
                    live.done = live.cell.session_mut().state().done;
                    if let Some(e) = live.journal.error() {
                        return Err(ServeError::Trace(e.clone()));
                    }
                    Ok(ok_line(vec![
                        ("applied", Json::Bool(true)),
                        ("seq", Json::Num(seq as f64)),
                        ("done", Json::Bool(live.done)),
                    ]))
                }
                Some((cur, _)) if seq < *cur => duplicate(seq),
                Some((cur, _)) => Err(ServeError::UnknownRequest {
                    seq,
                    detail: format!("the outstanding batch is seq {cur}"),
                }),
                None if seq < live.journal.exchanges() => duplicate(seq),
                None => Err(ServeError::UnknownRequest {
                    seq,
                    detail: "no batch is outstanding".into(),
                }),
            }
        })
    }

    fn state(&self, token: &str) -> Result<String, ServeError> {
        self.with_live(token, |live| {
            let s = live.cell.session_mut().state();
            Ok(ok_line(vec![
                ("done", Json::Bool(live.done || s.done)),
                ("exchanges", Json::Num(live.journal.exchanges() as f64)),
                ("state", state_json(&s)),
            ]))
        })
    }

    fn finish(&self, token: &str) -> Result<String, ServeError> {
        validate_token(token)?;
        let arc = self.tenant_arc(token);
        let mut t = lock_tenant(&arc);
        t.last_used = Instant::now();
        let result_path = t.dir.join(RESULT_FILE);
        // idempotent repeat finish: answer from the sealed artifact
        if let Ok(text) = std::fs::read_to_string(&result_path) {
            let payload = json::parse(&text).map_err(|e| {
                ServeError::Trace(TraceError::Malformed(format!(
                    "corrupt {}: {e}",
                    result_path.display()
                )))
            })?;
            return Ok(ok_payload(payload));
        }
        if let Err(e) = self.ensure_live(&mut t) {
            drop(t);
            self.forget_if_unloaded(token, &e);
            return Err(e);
        }
        let live = t.live.as_mut().expect("ensure_live populated");
        if live.outstanding.is_some() {
            return Err(ServeError::NotDone(
                "cannot finish: the last asked batch has not been told yet".into(),
            ));
        }
        if !live.done {
            // the session may be complete without having issued its
            // empty ask yet; probe — and if it still wants work, keep
            // the freshly asked batch outstanding for the next ask
            let batch = live.cell.session_mut().try_ask().ok_or_else(|| {
                ServeError::Trace(TraceError::StateMismatch {
                    detail: "session has an untold batch the manager lost track of".into(),
                })
            })?;
            if batch.is_empty() {
                live.done = true;
            } else {
                let seq = live.journal.exchanges();
                live.journal.record_ask(&batch);
                if let Some(e) = live.journal.error() {
                    return Err(ServeError::Trace(e.clone()));
                }
                live.outstanding = Some((seq, batch));
                return Err(ServeError::NotDone(
                    "cannot finish: the session still needs measurements".into(),
                ));
            }
        }
        let out = live.cell.finish();
        let pool = live.cell.pool();
        let payload = Json::obj(vec![
            ("token", Json::Str(token.into())),
            ("best_idx", Json::Num(out.best_idx as f64)),
            (
                "best_config",
                Json::Str(pool.configs[out.best_idx].to_string()),
            ),
            ("best_truth", float_json(pool.truth_of(out.best_idx))),
            ("collection_cost", float_json(out.collection_cost)),
            ("workflow_runs", Json::Num(out.workflow_runs as f64)),
            ("failed_runs", Json::Num(out.failed_runs as f64)),
            ("measured", Json::Num(out.measured.len() as f64)),
        ]);
        fsio::atomic_write(&result_path, payload.compact().as_bytes()).map_err(|e| {
            ServeError::Trace(TraceError::Io(format!(
                "cannot write {}: {e}",
                result_path.display()
            )))
        })?;
        // unload: the journal and result stay on disk (reopenable by
        // token); the in-memory tenant is spent
        t.live = None;
        drop(t);
        self.forget(token);
        Ok(ok_payload(payload))
    }

    fn close(&self, token: &str) -> Result<String, ServeError> {
        validate_token(token)?;
        let dir = self.root.join(token);
        let arc = self.tenant_arc(token);
        let mut t = lock_tenant(&arc);
        let known = t.live.is_some() || checkpoint_exists(&dir) || dir.join(RESULT_FILE).is_file();
        t.live = None;
        drop(t);
        self.forget(token);
        if !known {
            return Err(ServeError::UnknownToken(token.into()));
        }
        Ok(ok_line(vec![
            ("closed", Json::Bool(true)),
            ("token", Json::Str(token.into())),
        ]))
    }

    // ---- eviction ---------------------------------------------------

    /// Evict every tenant idle for at least `ttl` (its in-memory half
    /// drops; the journal remains).  Busy tenants are skipped — a held
    /// lock means the tenant is anything but idle.  Returns the number
    /// evicted.
    pub fn evict_idle(&self, ttl: Duration) -> usize {
        let arcs: Vec<Arc<Mutex<Tenant>>> = {
            let map = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
            map.values().cloned().collect()
        };
        let mut evicted = 0;
        for arc in arcs {
            if let Ok(mut t) = arc.try_lock() {
                if t.live.is_some() && t.last_used.elapsed() >= ttl {
                    t.live = None;
                    evicted += 1;
                }
            }
        }
        evicted
    }

    /// One sweep at the configured TTL (no-op when eviction is off).
    pub fn sweep(&self) -> usize {
        match self.ttl {
            Some(ttl) => self.evict_idle(ttl),
            None => 0,
        }
    }

    /// Tenants currently resident in memory (diagnostic).
    pub fn live_sessions(&self) -> usize {
        let map = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        map.values()
            .filter(|arc| arc.try_lock().map(|t| t.live.is_some()).unwrap_or(true))
            .count()
    }

    // ---- internals --------------------------------------------------

    fn allocate_token(&self) -> String {
        loop {
            let n = self.next_token.fetch_add(1, Ordering::Relaxed) + 1;
            let token = format!("s{n:06}");
            let dir = self.root.join(&token);
            // skip tokens a previous daemon incarnation handed out:
            // restart on the same root must never clobber a session
            if !checkpoint_exists(&dir) && !dir.join(RESULT_FILE).is_file() {
                return token;
            }
        }
    }

    fn tenant_arc(&self, token: &str) -> Arc<Mutex<Tenant>> {
        let mut map = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        map.entry(token.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(Tenant::unloaded(self.root.join(token)))))
            .clone()
    }

    fn forget(&self, token: &str) {
        let mut map = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        map.remove(token);
    }

    /// Drop the placeholder a failed lookup left behind, so bad tokens
    /// don't accumulate map entries.
    fn forget_if_unloaded(&self, token: &str, e: &ServeError) {
        if matches!(e, ServeError::UnknownToken(_)) {
            let mut map = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(arc) = map.get(token) {
                if arc.try_lock().map(|t| t.live.is_none()).unwrap_or(false) {
                    map.remove(token);
                }
            }
        }
    }

    /// Run `f` with the tenant's live half, rehydrating from the
    /// journal first if it was evicted (or if this daemon just
    /// restarted and has never seen the token).
    fn with_live<F>(&self, token: &str, f: F) -> Result<String, ServeError>
    where
        F: FnOnce(&mut Live) -> Result<String, ServeError>,
    {
        validate_token(token)?;
        let arc = self.tenant_arc(token);
        let mut t = lock_tenant(&arc);
        t.last_used = Instant::now();
        if let Err(e) = self.ensure_live(&mut t) {
            drop(t);
            self.forget_if_unloaded(token, &e);
            return Err(e);
        }
        f(t.live.as_mut().expect("ensure_live populated"))
    }

    /// Rehydrate an evicted tenant: resume the journal, rebuild the
    /// cell, replay every journaled exchange, and re-materialize the
    /// in-flight ask (verified against the journal) if one was pending
    /// at eviction/crash time.
    fn ensure_live(&self, t: &mut Tenant) -> Result<(), ServeError> {
        if t.live.is_some() {
            return Ok(());
        }
        if !checkpoint_exists(&t.dir) {
            let token = t
                .dir
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            return Err(ServeError::UnknownToken(token));
        }
        let (mut journal, loaded) = SessionJournal::resume(&t.dir).map_err(ServeError::Trace)?;
        for note in &loaded.recovered {
            // crash residue (torn final record) — goes to the
            // tenant's own diag file, not the shared stderr
            append_diag(&t.dir, note);
        }
        let header = journal.header().clone();
        let mut cell = SessionCell::build(&header, journal.rep(), self.threads)?;
        cell.set_diag_sink(DiagSink::File(t.dir.join(DIAG_FILE)));
        cell.arm_from_header(&header);
        replay_into(cell.session_mut(), &mut RemoteEvaluator, &loaded)
            .map_err(ServeError::Trace)?;
        let done = cell.session_mut().state().done;
        let last_eval = loaded.eval();
        let mut outstanding = None;
        if journal.has_pending() {
            // the crash/eviction hit between an ask and its tell:
            // re-issue the batch now so a reconnecting client's tell
            // (or re-ask) finds it, and let record_ask verify it
            // against the journaled one
            let batch = cell.session_mut().try_ask().ok_or_else(|| {
                ServeError::Trace(TraceError::StateMismatch {
                    detail: "journal holds a pending ask but the rebuilt session has an \
                             untold batch"
                        .into(),
                })
            })?;
            let seq = journal.exchanges();
            journal.record_ask(&batch);
            if let Some(e) = journal.error() {
                return Err(ServeError::Trace(e.clone()));
            }
            outstanding = Some((seq, batch));
        }
        t.live = Some(Live {
            cell,
            journal,
            outstanding,
            done,
            last_eval,
        });
        Ok(())
    }
}

/// Append one warning line to the tenant's diag file (best-effort;
/// falls back to stderr like `DiagSink::File`).
fn append_diag(dir: &Path, msg: &str) {
    use std::io::Write as _;
    let ok = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join(DIAG_FILE))
        .and_then(|mut f| writeln!(f, "warning: {msg}"));
    if ok.is_err() {
        eprintln!("warning: {msg}");
    }
}

/// Tokens name directories under the serve root: constrain them to a
/// safe alphabet so a hostile token can never traverse outside it.
fn validate_token(token: &str) -> Result<(), ServeError> {
    let ok = !token.is_empty()
        && token.len() <= 64
        && !token.starts_with('.')
        && token
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        Err(ServeError::Usage(format!(
            "invalid token '{token}' (want 1-64 chars of [A-Za-z0-9._-], not starting \
             with '.')"
        )))
    }
}

/// Build the canonical journal header for a fresh open: names resolve
/// through the same registries as the CLI, so the header (and the
/// session it pins) is exactly what `ceal tune` would produce.
fn header_for(spec: &OpenSpec) -> Result<TraceHeader, ServeError> {
    let wf = crate::config::WorkflowId::from_name(&spec.workflow).ok_or_else(|| {
        ServeError::Usage(format!(
            "unknown workflow '{}' (registered: {})",
            spec.workflow,
            WorkflowRegistry::global().names().join(" | ")
        ))
    })?;
    let obj = Objective::from_name(&spec.objective).ok_or_else(|| {
        ServeError::Usage(format!("unknown objective '{}' (exec|comp)", spec.objective))
    })?;
    let algo = Algo::from_name(&spec.algo).ok_or_else(|| {
        ServeError::Usage(format!(
            "unknown algorithm '{}' (registered: {})",
            spec.algo,
            Algo::names().join(" | ")
        ))
    })?;
    let scorer = ScorerKind::from_name(&spec.scorer).ok_or_else(|| {
        ServeError::Usage(format!("unknown scorer '{}' (native|pjrt)", spec.scorer))
    })?;
    if spec.m == 0 {
        return Err(ServeError::Usage("'m' must be at least 1".into()));
    }
    if spec.pool_size == 0 {
        return Err(ServeError::Usage("'pool' must be at least 1".into()));
    }
    Ok(TraceHeader {
        algo: algo.name().into(),
        workflow: wf.name().into(),
        objective: obj.name().into(),
        m: spec.m,
        pool_size: spec.pool_size,
        seed: spec.seed,
        scorer: scorer.name().into(),
        ceal_params: None,
        faults: None,
    })
}

/// Wrap a payload object as a successful response (used by `finish`,
/// whose payload must round-trip through `result.json` verbatim).
fn ok_payload(payload: Json) -> String {
    let mut map = match payload {
        Json::Obj(map) => map,
        other => {
            let mut m = std::collections::BTreeMap::new();
            m.insert("result".to_string(), other);
            m
        }
    };
    map.insert("ok".to_string(), Json::Bool(true));
    map.insert(
        "v".to_string(),
        Json::Num(crate::serve::protocol::PROTO_VERSION as f64),
    );
    Json::Obj(map).compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ceal-serve-mgr-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn token_validation_rejects_traversal() {
        assert!(validate_token("s000001").is_ok());
        assert!(validate_token("retuned-cell_7.a").is_ok());
        assert!(validate_token("").is_err());
        assert!(validate_token("..").is_err());
        assert!(validate_token("a/b").is_err());
        assert!(validate_token("a\\b").is_err());
        assert!(validate_token(&"x".repeat(65)).is_err());
    }

    #[test]
    fn unknown_token_is_structured_and_leaves_no_placeholder() {
        let root = temp_root("unknown");
        let mgr = SessionManager::new(&root, 1, None).unwrap();
        let resp = mgr.handle_line(r#"{"verb":"ask","token":"s999999"}"#);
        assert!(resp.contains("\"ok\":false"), "{resp}");
        assert!(resp.contains("unknown-token"), "{resp}");
        assert_eq!(
            mgr.tenants.lock().unwrap().len(),
            0,
            "failed lookups must not leak map entries"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bad_requests_are_structured_usage_errors() {
        let root = temp_root("usage");
        let mgr = SessionManager::new(&root, 1, None).unwrap();
        for line in [
            "not json at all",
            r#"{"no":"verb"}"#,
            r#"{"verb":"warp","token":"s1"}"#,
            r#"{"verb":"tell","token":"s1"}"#,
            r#"{"verb":"open","token":"../escape"}"#,
        ] {
            let resp = mgr.handle_line(line);
            assert!(resp.contains("\"ok\":false"), "{line} -> {resp}");
            assert!(resp.contains("\"code\":1"), "{line} -> {resp}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn nonfinite_floats_survive_the_wire() {
        assert_eq!(float_json(2.5), Json::Num(2.5));
        let nan = float_json(f64::NAN);
        assert_eq!(nan, Json::Str("NaN".into()));
        let text = Json::obj(vec![("best_truth", nan)]).compact();
        let back = json::parse(&text).unwrap();
        let parsed: f64 = back
            .get("best_truth")
            .and_then(Json::as_str)
            .unwrap()
            .parse()
            .unwrap();
        assert!(parsed.is_nan());
    }
}
