//! The TCP front half of `ceal serve`: a zero-dependency
//! line-oriented listener over one [`SessionManager`].
//!
//! Transport is deliberately boring — `std::net`, one thread per
//! connection, blocking reads — because all the concurrency that
//! matters lives in the manager: connection threads only parse lines
//! and block on *their own tenant's* mutex, so a slow or stalled
//! client can never hold up another tenant's ask/tell.  Sessions are
//! not tied to connections at all (a token can be driven from many
//! connections, sequentially or concurrently), which is what makes
//! client crash/reconnect and daemon kill/restart symmetric.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::serve::manager::{SessionManager, DEFAULT_SESSION_TTL};
use crate::serve::protocol::{err_line, ServeError};
use crate::tuner::TraceError;

/// `ceal serve` settings (flag defaults live in `main.rs`).
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7433`; port 0 picks a free one.
    pub addr: String,
    /// Serve root: one journal directory per session token.
    pub root: PathBuf,
    /// Idle TTL before a session is evicted to disk (`None` disables).
    pub ttl: Option<Duration>,
    /// Worker threads for pool generation / scoring.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7433".into(),
            root: PathBuf::from("serve"),
            ttl: Some(DEFAULT_SESSION_TTL),
            threads: 0,
        }
    }
}

/// Answer one request line, translating a handler panic into a
/// structured `io` error response instead of a dropped connection.
/// Panics cannot corrupt sessions: the journal is write-ahead and the
/// poisoned tenant rehydrates from it on its next touch.
fn answer(mgr: &SessionManager, line: &str) -> String {
    std::panic::catch_unwind(AssertUnwindSafe(|| mgr.handle_line(line))).unwrap_or_else(|_| {
        err_line(&ServeError::Trace(TraceError::Io(
            "internal error while handling request (session state was rolled back to its \
             journal)"
                .into(),
        )))
    })
}

fn serve_connection(mgr: &SessionManager, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("serve: cannot clone stream for {peer}: {e}");
            return;
        }
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client went away mid-line
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = answer(mgr, &line);
        if writer
            .write_all(resp.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush())
            .is_err()
        {
            break; // client stopped reading; its session stays resumable
        }
    }
}

/// Run the daemon: bind, spawn the TTL sweeper, and serve connections
/// until the process dies.  Never returns except on bind failure.
pub fn serve(cfg: ServeConfig) -> Result<(), String> {
    let mgr = Arc::new(
        SessionManager::new(&cfg.root, cfg.threads, cfg.ttl)
            .map_err(|e| format!("cannot open serve root: {e}"))?,
    );
    let listener =
        TcpListener::bind(&cfg.addr).map_err(|e| format!("cannot bind {}: {e}", cfg.addr))?;
    let local = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| cfg.addr.clone());
    println!(
        "ceal serve: listening on {local} (root {}, ttl {})",
        cfg.root.display(),
        match cfg.ttl {
            Some(t) => format!("{}s", t.as_secs_f64()),
            None => "off".into(),
        }
    );
    if let Some(ttl) = cfg.ttl {
        let sweeper = Arc::clone(&mgr);
        // sweep a few times per TTL so eviction lag is bounded by a
        // fraction of the TTL, not a whole extra TTL
        let period = ttl.div_f64(4.0).max(Duration::from_millis(50));
        std::thread::spawn(move || loop {
            std::thread::sleep(period);
            let evicted = sweeper.sweep();
            if evicted > 0 {
                eprintln!("serve: evicted {evicted} idle session(s) to disk");
            }
        });
    }
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let mgr = Arc::clone(&mgr);
                std::thread::spawn(move || serve_connection(&mgr, stream));
            }
            Err(e) => eprintln!("serve: accept failed: {e}"),
        }
    }
    Ok(())
}
