//! `ceal serve` — the tuning daemon: every registered workflow ×
//! algorithm as a long-running, multi-tenant ask/tell service.
//!
//! Layering (one module per concern):
//!
//! * [`protocol`] — the versioned line-delimited JSON codec and the
//!   structured [`ServeError`](protocol::ServeError) taxonomy (shared
//!   with the CLI's exit codes);
//! * [`cell`] — one tenant's live session plus everything it borrows,
//!   stored as a single movable heap cell;
//! * [`manager`] — the token-keyed [`SessionManager`](manager::SessionManager):
//!   verb semantics, idempotent tells, lazy rehydration from the
//!   write-ahead journal, idle eviction;
//! * [`server`] — the `std::net` TCP front end (thread per
//!   connection, sessions independent of connections);
//! * [`client`] — the typed client over TCP or in-process loopback,
//!   used by `ceal client`, the soak tests and the benches.
//!
//! The invariant the whole subsystem is built around: a serve-hosted
//! session is **bit-identical** to `drive()` of the same (workflow,
//! algorithm, seed) — same pool, same RNG derivations, same journal
//! format — no matter how its exchanges are interleaved with other
//! tenants, split across connections, evicted and rehydrated, or
//! interrupted by a daemon SIGKILL.

pub mod cell;
pub mod client;
pub mod manager;
pub mod protocol;
pub mod server;

pub use client::{AskReply, LineTransport, Loopback, OpenInfo, ServeClient, TcpTransport};
pub use manager::{SessionManager, DEFAULT_SESSION_TTL};
pub use protocol::{OpenSpec, Request, ServeError, PROTO_VERSION};
pub use server::{serve, ServeConfig};
