//! The serve wire protocol: versioned, line-delimited compact JSON.
//!
//! One request per line, one response per line, always an object.
//! Every response carries `"v"` (the protocol version) and `"ok"`;
//! failures are *structured responses* — `{"ok":false,"err":{...}}`
//! with a stable error `kind` and the CLI's documented exit-code
//! taxonomy — never connection drops, so a scripted client can tell
//! "bad request" from "corrupt journal" from "infeasible space"
//! without parsing prose.
//!
//! Verbs (see the README "Serving" section for the message table):
//!
//! | verb     | direction of payload                                  |
//! |----------|-------------------------------------------------------|
//! | `open`   | cell spec (fresh) or `token` (resume by token)        |
//! | `ask`    | → next measurement batch (`reqs` carry full configs)  |
//! | `tell`   | ← outcomes for one asked batch (`seq`-keyed)          |
//! | `state`  | → progress snapshot                                   |
//! | `finish` | → best config / cost summary (idempotent)             |
//! | `close`  | evict the session to disk (reopenable by token)       |
//!
//! The codec is shared by the server, the in-process test client and
//! `ceal client`, so both directions round-trip through the same
//! functions.  Measurement outcomes reuse the session-trace encoding
//! (numbers for readings, stable fault names for failures) and
//! evaluator checkpoints reuse the journal's encoding, which is what
//! makes a daemon-side journal replayable against a client-side
//! evaluator.

use crate::config::Config;
use crate::tuner::journal::{eval_from_json, eval_json};
use crate::tuner::trace::{
    mode_from_name, mode_name, outcome_from_json, outcome_json, parse_outcomes,
};
use crate::tuner::{
    EvaluatorState, MeasurementBatch, MeasurementRequest, MeasurementResult, SessionState,
    TraceError,
};
use crate::util::json::{self, Json};

/// Wire protocol version.  Bumped on any incompatible change; an
/// `open` carrying a different version is refused with a structured
/// `usage` error naming both versions.
pub const PROTO_VERSION: u64 = 1;

/// Exit-code taxonomy shared with the CLI (`main.rs` module header):
/// corrupted/truncated/incompatible journal or protocol stream.
pub const CODE_TRACE: u8 = 2;
/// The requested configuration space admits no feasible configuration.
pub const CODE_INFEASIBLE: u8 = 3;

/// A structured protocol failure: every variant maps to a stable wire
/// `kind` plus the CLI exit-code taxonomy, so `ceal client` exits with
/// the same codes an equivalent `ceal tune` invocation would.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Malformed or unsupported request (bad JSON, unknown verb,
    /// missing field, version mismatch).  Exit code 1.
    Usage(String),
    /// No session with that token, in memory or on the serve root.
    UnknownToken(String),
    /// A `tell` whose `seq` names neither the outstanding batch nor an
    /// already-answered one.
    UnknownRequest { seq: usize, detail: String },
    /// `finish` before the session's last batch was told.
    NotDone(String),
    /// The cell's configuration space admits no feasible
    /// configuration.  Exit code 3.
    Infeasible(String),
    /// Journal/trace failure underneath the session (corrupt journal,
    /// divergence on rehydration, IO).  Exit code 2.
    Trace(TraceError),
    /// Client side only: a structured error decoded from a response —
    /// preserves the server's kind and exit code verbatim.
    Remote { kind: String, code: u8, msg: String },
}

impl ServeError {
    /// Stable wire identifier for this failure class.
    pub fn kind(&self) -> &str {
        match self {
            ServeError::Usage(_) => "usage",
            ServeError::UnknownToken(_) => "unknown-token",
            ServeError::UnknownRequest { .. } => "unknown-request",
            ServeError::NotDone(_) => "not-done",
            ServeError::Infeasible(_) => "infeasible",
            ServeError::Trace(e) => trace_error_kind(e),
            ServeError::Remote { kind, .. } => kind,
        }
    }

    /// The CLI exit code this failure maps to (1 usage, 2
    /// trace/journal, 3 infeasible).
    pub fn code(&self) -> u8 {
        match self {
            ServeError::Infeasible(_) => CODE_INFEASIBLE,
            ServeError::Trace(_) => CODE_TRACE,
            ServeError::Remote { code, .. } => *code,
            _ => 1,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Usage(msg) => write!(f, "{msg}"),
            ServeError::UnknownToken(token) => write!(f, "unknown session token '{token}'"),
            ServeError::UnknownRequest { seq, detail } => {
                write!(f, "tell for unknown request seq {seq}: {detail}")
            }
            ServeError::NotDone(msg) => write!(f, "{msg}"),
            ServeError::Infeasible(msg) => write!(f, "{msg}"),
            ServeError::Trace(e) => write!(f, "{e}"),
            ServeError::Remote { kind, msg, .. } => write!(f, "{kind}: {msg}"),
        }
    }
}

/// The stable wire `kind` of each [`TraceError`] variant.
pub fn trace_error_kind(e: &TraceError) -> &'static str {
    match e {
        TraceError::Io(_) => "io",
        TraceError::NotATrace(_) => "not-a-trace",
        TraceError::Version(_) => "version",
        TraceError::Malformed(_) => "malformed",
        TraceError::Exhausted { .. } => "exhausted",
        TraceError::Divergence { .. } => "divergence",
        TraceError::Crc { .. } => "crc",
        TraceError::StateMismatch { .. } => "state-mismatch",
    }
}

/// The cell parameters of a fresh `open` (what `ceal tune` takes from
/// flags).  `ceal_params`/`faults` overrides are deliberately not on
/// the wire: the daemon serves registered cells at their registered
/// defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct OpenSpec {
    pub workflow: String,
    pub objective: String,
    pub algo: String,
    pub m: usize,
    pub pool_size: usize,
    pub seed: u64,
    pub scorer: String,
}

impl Default for OpenSpec {
    fn default() -> Self {
        OpenSpec {
            workflow: "LV".into(),
            objective: "comp".into(),
            algo: "ceal".into(),
            m: 50,
            pool_size: 2000,
            seed: 0xCEA1,
            scorer: "native".into(),
        }
    }
}

/// A decoded request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Fresh session (`spec`) or resume by token (`token`) — never
    /// both: a token pins the cell settings in its journal header,
    /// exactly like `ceal tune --resume` refuses contradicting flags.
    Open {
        token: Option<String>,
        spec: Option<OpenSpec>,
    },
    Ask {
        token: String,
    },
    Tell {
        token: String,
        seq: usize,
        results: Vec<MeasurementResult>,
        eval: Option<EvaluatorState>,
    },
    State {
        token: String,
    },
    Finish {
        token: String,
    },
    Close {
        token: String,
    },
}

fn required_token(v: &Json, verb: &str) -> Result<String, ServeError> {
    v.get("token")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ServeError::Usage(format!("'{verb}' needs a string 'token'")))
}

/// Accept a u64 as a JSON number or (for values beyond 2^53) a decimal
/// string — the same latitude the journal header gives seeds.
fn u64_field(v: &Json, key: &str) -> Result<Option<u64>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Ok(Some(*x as u64)),
        Some(Json::Str(s)) => s
            .parse()
            .map(Some)
            .map_err(|e| ServeError::Usage(format!("bad '{key}' '{s}': {e}"))),
        Some(_) => Err(ServeError::Usage(format!(
            "'{key}' must be a non-negative integer"
        ))),
    }
}

fn usize_field(v: &Json, key: &str) -> Result<Option<usize>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Ok(Some(*x as usize)),
        Some(_) => Err(ServeError::Usage(format!(
            "'{key}' must be a non-negative integer"
        ))),
    }
}

fn str_field(v: &Json, key: &str) -> Result<Option<String>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(ServeError::Usage(format!("'{key}' must be a string"))),
    }
}

/// Decode one request line.  Protocol-version enforcement happens here
/// for `open` (the verb that establishes a conversation); other verbs
/// tolerate an absent `v` since their token already names a session.
pub fn parse_request(line: &str) -> Result<Request, ServeError> {
    let v = json::parse(line).map_err(|e| ServeError::Usage(format!("bad request JSON: {e}")))?;
    let verb = v
        .get("verb")
        .and_then(Json::as_str)
        .ok_or_else(|| ServeError::Usage("request needs a string 'verb'".into()))?;
    if let Some(pv) = u64_field(&v, "v")? {
        if pv != PROTO_VERSION {
            return Err(ServeError::Usage(format!(
                "protocol version {pv} unsupported (this daemon speaks {PROTO_VERSION})"
            )));
        }
    }
    match verb {
        "open" => {
            let token = str_field(&v, "token")?;
            if token.is_some() {
                for key in ["workflow", "objective", "algo", "m", "pool", "seed", "scorer"] {
                    if v.get(key).is_some() {
                        return Err(ServeError::Usage(format!(
                            "'{key}' conflicts with 'token': resuming by token pins the cell \
                             settings from its journal header"
                        )));
                    }
                }
                return Ok(Request::Open { token, spec: None });
            }
            let d = OpenSpec::default();
            let spec = OpenSpec {
                workflow: str_field(&v, "workflow")?.unwrap_or(d.workflow),
                objective: str_field(&v, "objective")?.unwrap_or(d.objective),
                algo: str_field(&v, "algo")?.unwrap_or(d.algo),
                m: usize_field(&v, "m")?.unwrap_or(d.m),
                pool_size: usize_field(&v, "pool")?.unwrap_or(d.pool_size),
                seed: u64_field(&v, "seed")?.unwrap_or(d.seed),
                scorer: str_field(&v, "scorer")?.unwrap_or(d.scorer),
            };
            Ok(Request::Open {
                token: None,
                spec: Some(spec),
            })
        }
        "ask" => Ok(Request::Ask {
            token: required_token(&v, "ask")?,
        }),
        "tell" => {
            let token = required_token(&v, "tell")?;
            let seq = usize_field(&v, "seq")?
                .ok_or_else(|| ServeError::Usage("'tell' needs an integer 'seq'".into()))?;
            let outcomes = parse_outcomes(v.get("ys"))
                .map_err(|e| ServeError::Usage(format!("bad 'ys': {e}")))?;
            let results = outcomes
                .into_iter()
                .map(|outcome| MeasurementResult { outcome })
                .collect();
            let eval = match v.get("eval") {
                None | Some(Json::Null) => None,
                Some(e) => Some(
                    eval_from_json(e, "tell eval state").map_err(ServeError::Trace)?,
                ),
            };
            Ok(Request::Tell {
                token,
                seq,
                results,
                eval,
            })
        }
        "state" => Ok(Request::State {
            token: required_token(&v, "state")?,
        }),
        "finish" => Ok(Request::Finish {
            token: required_token(&v, "finish")?,
        }),
        "close" => Ok(Request::Close {
            token: required_token(&v, "close")?,
        }),
        other => Err(ServeError::Usage(format!(
            "unknown verb '{other}' (open|ask|tell|state|finish|close)"
        ))),
    }
}

// ---- request encoding (client side) --------------------------------

pub fn open_line(spec: &OpenSpec) -> String {
    Json::obj(vec![
        ("verb", Json::Str("open".into())),
        ("v", Json::Num(PROTO_VERSION as f64)),
        ("workflow", Json::Str(spec.workflow.clone())),
        ("objective", Json::Str(spec.objective.clone())),
        ("algo", Json::Str(spec.algo.clone())),
        ("m", Json::Num(spec.m as f64)),
        ("pool", Json::Num(spec.pool_size as f64)),
        ("seed", Json::Str(spec.seed.to_string())),
        ("scorer", Json::Str(spec.scorer.clone())),
    ])
    .compact()
}

pub fn reopen_line(token: &str) -> String {
    Json::obj(vec![
        ("verb", Json::Str("open".into())),
        ("v", Json::Num(PROTO_VERSION as f64)),
        ("token", Json::Str(token.into())),
    ])
    .compact()
}

fn token_verb_line(verb: &str, token: &str) -> String {
    Json::obj(vec![
        ("verb", Json::Str(verb.into())),
        ("token", Json::Str(token.into())),
    ])
    .compact()
}

pub fn ask_line(token: &str) -> String {
    token_verb_line("ask", token)
}

pub fn state_line(token: &str) -> String {
    token_verb_line("state", token)
}

pub fn finish_line(token: &str) -> String {
    token_verb_line("finish", token)
}

pub fn close_line(token: &str) -> String {
    token_verb_line("close", token)
}

pub fn tell_line(
    token: &str,
    seq: usize,
    results: &[MeasurementResult],
    eval: Option<&EvaluatorState>,
) -> String {
    let ys = Json::Arr(results.iter().map(|r| outcome_json(&r.outcome)).collect());
    let mut pairs = vec![
        ("verb", Json::Str("tell".into())),
        ("token", Json::Str(token.into())),
        ("seq", Json::Num(seq as f64)),
        ("ys", ys),
    ];
    if let Some(e) = eval {
        pairs.push(("eval", eval_json(e)));
    }
    Json::obj(pairs).compact()
}

// ---- batch / state / response encoding (server side) ---------------

/// Encode a measurement batch for the wire.  Unlike the journal's
/// recorded form, workflow requests carry their full configuration
/// values — the client measures without any pool access.
pub fn batch_json(batch: &MeasurementBatch) -> Json {
    let reqs = batch
        .requests
        .iter()
        .map(|r| match r {
            MeasurementRequest::Workflow { pool_idx, config } => Json::obj(vec![
                ("pool", Json::Num(*pool_idx as f64)),
                (
                    "cfg",
                    Json::Arr(config.0.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
            ]),
            MeasurementRequest::Component { comp, config } => Json::obj(vec![
                ("comp", Json::Num(*comp as f64)),
                (
                    "cfg",
                    Json::Arr(config.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
            ]),
        })
        .collect();
    Json::obj(vec![
        ("mode", Json::Str(mode_name(batch.mode).into())),
        ("reqs", Json::Arr(reqs)),
    ])
}

fn cfg_values(r: &Json) -> Result<Vec<i64>, ServeError> {
    r.get("cfg")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).map(|x| x as i64).collect())
        .ok_or_else(|| ServeError::Usage("request missing 'cfg' values".into()))
}

/// Decode a wire batch back into live measurement requests.
pub fn batch_from_json(v: &Json) -> Result<MeasurementBatch, ServeError> {
    let mode = mode_from_name(v.get("mode").and_then(Json::as_str))
        .map_err(ServeError::Usage)?;
    let reqs = v
        .get("reqs")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServeError::Usage("batch missing 'reqs'".into()))?;
    let mut requests = Vec::with_capacity(reqs.len());
    for r in reqs {
        if let Some(pool_idx) = r.get("pool").and_then(Json::as_usize) {
            requests.push(MeasurementRequest::Workflow {
                pool_idx,
                config: Config(cfg_values(r)?),
            });
        } else if let Some(comp) = r.get("comp").and_then(Json::as_usize) {
            requests.push(MeasurementRequest::Component {
                comp,
                config: cfg_values(r)?,
            });
        } else {
            return Err(ServeError::Usage(
                "request is neither workflow ('pool') nor component ('comp')".into(),
            ));
        }
    }
    Ok(MeasurementBatch { mode, requests })
}

/// Encode a progress snapshot for the `state` response.
pub fn state_json(s: &SessionState) -> Json {
    Json::obj(vec![
        ("phase", Json::Str(s.phase.into())),
        ("done", Json::Bool(s.done)),
        ("asked", Json::Num(s.asked_batches as f64)),
        ("told", Json::Num(s.told_batches as f64)),
        ("workflow_runs", Json::Num(s.workflow_runs as f64)),
        ("component_runs", Json::Num(s.component_runs as f64)),
        ("cost", Json::Num(s.collection_cost)),
        ("failed_runs", Json::Num(s.failed_runs as f64)),
        ("refits", Json::Num(s.model_refits as f64)),
        (
            "using_hifi",
            match s.using_hifi {
                Some(b) => Json::Bool(b),
                None => Json::Null,
            },
        ),
    ])
}

/// A successful response line: `pairs` plus the protocol preamble.
pub fn ok_line(mut pairs: Vec<(&str, Json)>) -> String {
    let mut all = vec![
        ("ok", Json::Bool(true)),
        ("v", Json::Num(PROTO_VERSION as f64)),
    ];
    all.append(&mut pairs);
    Json::obj(all).compact()
}

/// A structured failure response line.
pub fn err_line(e: &ServeError) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("v", Json::Num(PROTO_VERSION as f64)),
        (
            "err",
            Json::obj(vec![
                ("kind", Json::Str(e.kind().into())),
                ("code", Json::Num(e.code() as f64)),
                ("msg", Json::Str(e.to_string())),
            ]),
        ),
    ])
    .compact()
}

/// Client side: parse a response line, turning `{"ok":false}` into the
/// structured error it carries.
pub fn parse_response(line: &str) -> Result<Json, ServeError> {
    let v = json::parse(line)
        .map_err(|e| ServeError::Usage(format!("bad response JSON: {e}")))?;
    match v.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(v),
        Some(false) => {
            let err = v.get("err");
            let kind = err
                .and_then(|e| e.get("kind"))
                .and_then(Json::as_str)
                .unwrap_or("usage")
                .to_string();
            let code = err
                .and_then(|e| e.get("code"))
                .and_then(Json::as_usize)
                .unwrap_or(1) as u8;
            let msg = err
                .and_then(|e| e.get("msg"))
                .and_then(Json::as_str)
                .unwrap_or("unspecified server error")
                .to_string();
            Err(ServeError::Remote { kind, code, msg })
        }
        None => Err(ServeError::Usage(
            "response missing boolean 'ok'".into(),
        )),
    }
}

/// Decode the `ys` of a tell (also used by tests to build results from
/// raw outcome JSON).
pub fn outcome_from_wire(v: &Json) -> Option<MeasurementResult> {
    outcome_from_json(v).map(|outcome| MeasurementResult { outcome })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::FailureKind;
    use crate::tuner::MeasurementOutcome;

    #[test]
    fn request_lines_roundtrip() {
        let spec = OpenSpec {
            workflow: "HS".into(),
            objective: "exec".into(),
            algo: "al+h".into(),
            m: 12,
            pool_size: 300,
            seed: u64::MAX,
            scorer: "native".into(),
        };
        match parse_request(&open_line(&spec)).unwrap() {
            Request::Open { token: None, spec: Some(got) } => assert_eq!(got, spec),
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse_request(&reopen_line("s000007")).unwrap() {
            Request::Open { token: Some(t), spec: None } => assert_eq!(t, "s000007"),
            other => panic!("unexpected parse: {other:?}"),
        }
        let results = vec![
            MeasurementResult::ok(1.25),
            MeasurementResult {
                outcome: MeasurementOutcome::Failed(FailureKind::Crash),
            },
            MeasurementResult {
                outcome: MeasurementOutcome::TimedOut,
            },
        ];
        let eval = EvaluatorState {
            rng: crate::util::rng::Pcg32::new(5, 9).snapshot(),
        };
        let line = tell_line("s000001", 3, &results, Some(&eval));
        match parse_request(&line).unwrap() {
            Request::Tell {
                token,
                seq,
                results: got,
                eval: got_eval,
            } => {
                assert_eq!(token, "s000001");
                assert_eq!(seq, 3);
                assert_eq!(got, results);
                assert_eq!(got_eval, Some(eval));
            }
            other => panic!("unexpected parse: {other:?}"),
        }
    }

    #[test]
    fn open_with_token_refuses_cell_flags() {
        let line = r#"{"verb":"open","token":"s000001","m":10}"#;
        match parse_request(line) {
            Err(ServeError::Usage(msg)) => assert!(msg.contains("conflicts"), "{msg}"),
            other => panic!("want usage error, got {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_is_usage_error() {
        let line = r#"{"verb":"open","v":99,"workflow":"LV"}"#;
        match parse_request(line) {
            Err(ServeError::Usage(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("want usage error, got {other:?}"),
        }
    }

    #[test]
    fn batch_roundtrips_with_full_configs() {
        let batch = MeasurementBatch::fan_out(vec![
            MeasurementRequest::Workflow {
                pool_idx: 4,
                config: Config(vec![8, 2, 1, 100, 4, 2, 1]),
            },
            MeasurementRequest::Component {
                comp: 1,
                config: vec![16, 4],
            },
        ]);
        let back = batch_from_json(&batch_json(&batch)).unwrap();
        assert_eq!(back, batch);
    }

    #[test]
    fn error_lines_carry_kind_and_code() {
        let e = ServeError::Trace(TraceError::Crc {
            context: "journal line 3".into(),
        });
        let line = err_line(&e);
        match parse_response(&line) {
            Err(ServeError::Remote { kind, code, .. }) => {
                assert_eq!(kind, "crc");
                assert_eq!(code, CODE_TRACE);
            }
            other => panic!("want remote error, got {other:?}"),
        }
        let ok = ok_line(vec![("token", Json::Str("s1".into()))]);
        let v = parse_response(&ok).unwrap();
        assert_eq!(v.get("token").and_then(Json::as_str), Some("s1"));
    }
}
