//! A heap cell that owns one live [`TunerSession`] *together with*
//! everything the session borrows.
//!
//! [`Tuner::session`] hands back `Box<dyn TunerSession + 'a>` — the
//! session borrows the tuner, problem, pool and scorer for its whole
//! life.  That contract is perfect for `drive()`-style scoped callers
//! and unusable for a daemon, whose sessions outlive every stack
//! frame.  [`SessionCell`] closes the gap without changing the session
//! API: it boxes the borrowed-from values so their heap addresses are
//! stable, builds the session against those allocations, and erases
//! the borrow lifetime so the pair can be stored in a map.
//!
//! Safety rests on two structural facts, both local to this file:
//!
//! 1. every borrowed-from value is behind a `Box`/`Arc` whose heap
//!    allocation never moves when the `SessionCell` itself moves, and
//!    none of them is touched (mutated, replaced or dropped) while the
//!    session is alive;
//! 2. `session` is declared *first*, and Rust drops struct fields in
//!    declaration order — the session is gone before any allocation it
//!    borrows from is freed.

use std::sync::Arc;

use crate::config::WorkflowId;
use crate::coordinator::{session_rng, tuner_for, Algo, PoolCache, ScorerKind};
use crate::serve::protocol::ServeError;
use crate::sim::{Objective, WorkflowRegistry};
use crate::surrogate::Scorer;
use crate::tuner::{
    DiagSink, FailurePolicy, Pool, Problem, TraceHeader, Tuner, TunerOutput, TunerSession,
};

/// Resolve a journal/open header's cell names against the registries
/// (the serve-side twin of the CLI's resolver, with structured
/// errors).
pub(crate) fn resolve_header(
    header: &TraceHeader,
) -> Result<(WorkflowId, Objective, Algo), ServeError> {
    let wf = WorkflowId::from_name(&header.workflow).ok_or_else(|| {
        ServeError::Usage(format!(
            "workflow '{}' is not registered (registered: {})",
            header.workflow,
            WorkflowRegistry::global().names().join(" | ")
        ))
    })?;
    let obj = Objective::from_name(&header.objective).ok_or_else(|| {
        ServeError::Usage(format!("objective '{}' unknown (exec|comp)", header.objective))
    })?;
    let algo = Algo::from_name(&header.algo).ok_or_else(|| {
        ServeError::Usage(format!(
            "algorithm '{}' is not registered (registered: {})",
            header.algo,
            Algo::names().join(" | ")
        ))
    })?;
    Ok((wf, obj, algo))
}

/// One tenant's live session plus the cell state it borrows.  Field
/// order is load-bearing: see the module header.
pub(crate) struct SessionCell {
    /// `'static` is a lie told only inside this struct: the session
    /// really borrows the four fields below.  `None` once finished.
    session: Option<Box<dyn TunerSession + 'static>>,
    #[allow(dead_code)] // owned for the session's borrows, never read
    tuner: Box<dyn Tuner>,
    #[allow(dead_code)]
    scorer: Box<Scorer>,
    pool: Arc<Pool>,
    #[allow(dead_code)]
    prob: Box<Problem>,
}

impl SessionCell {
    /// Construct the cell for a header exactly as `ceal tune
    /// --checkpoint-dir` constructs its session: same pool cache key,
    /// same tuner, same RNG derivations — a serve-hosted session is
    /// bit-identical to a CLI-driven one by construction.
    pub(crate) fn build(
        header: &TraceHeader,
        rep: usize,
        threads: usize,
    ) -> Result<SessionCell, ServeError> {
        let (wf, obj, algo) = resolve_header(header)?;
        let prob = Box::new(Problem::new(wf, obj));
        let pool = PoolCache::global()
            .try_get_or_generate(&prob, header.pool_size, header.seed, threads)
            .map_err(|e| ServeError::Infeasible(format!("cannot build pool for {wf}: {e}")))?;
        let scorer = Box::new(
            ScorerKind::from_name(&header.scorer)
                .ok_or_else(|| {
                    ServeError::Usage(format!(
                        "scorer '{}' unknown (native|pjrt)",
                        header.scorer
                    ))
                })?
                .build(),
        );
        let tuner = tuner_for(algo, &prob, header.seed, header.ceal_params);
        let mut rng = session_rng(header.seed, algo, rep);
        let session: Box<dyn TunerSession + '_> =
            tuner.session(&prob, &pool, &scorer, header.m, &mut rng);
        // SAFETY: the session borrows `tuner`, `prob`, `pool` and
        // `scorer` — all heap allocations behind Box/Arc moved into
        // the same struct below, so their addresses outlive the
        // session: the struct never exposes them, never mutates them,
        // and drops `session` first (declaration order).  Erasing the
        // lifetime is therefore sound for every use reachable through
        // this struct's API.  Same pattern as the scoped-pointer
        // erasure in `util::parallel`.
        let session: Box<dyn TunerSession + 'static> = unsafe {
            std::mem::transmute::<Box<dyn TunerSession + '_>, Box<dyn TunerSession + 'static>>(
                session,
            )
        };
        Ok(SessionCell {
            session: Some(session),
            tuner,
            scorer,
            pool,
            prob,
        })
    }

    /// The live session.  Panics only if called after `finish`, which
    /// the manager's state machine rules out (finish unloads the
    /// tenant).
    pub(crate) fn session_mut(&mut self) -> &mut dyn TunerSession {
        self.session
            .as_mut()
            .expect("session already finished")
            .as_mut()
    }

    /// Route the session's library warnings into `sink` (the manager
    /// points this at the tenant's `diag.log`).
    pub(crate) fn set_diag_sink(&mut self, sink: DiagSink) {
        self.session_mut().set_diag_sink(sink);
    }

    /// Arm the fault-tolerant policy when the header calls for it.
    pub(crate) fn arm_from_header(&mut self, header: &TraceHeader) {
        if header.faults.is_some() {
            self.session_mut()
                .set_failure_policy(FailurePolicy::fault_tolerant());
        }
    }

    /// Consume the session into its output (panics if the session is
    /// not done — callers check first).
    pub(crate) fn finish(&mut self) -> TunerOutput {
        self.session
            .take()
            .expect("session already finished")
            .finish()
    }

    pub(crate) fn pool(&self) -> &Pool {
        &self.pool
    }
}
