//! Client half of the serve protocol: a typed wrapper over any
//! line-oriented transport.
//!
//! The same [`ServeClient`] drives a real daemon over TCP
//! ([`TcpTransport`], used by `ceal client`) or an in-process
//! [`SessionManager`] ([`Loopback`], used by the soak tests and the
//! `serve/ask_tell_roundtrip` bench) — both paths go through the
//! identical line codec, so the tests exercise exactly what the wire
//! carries.
//!
//! Measurement happens on *this* side: the server's `ask` batches
//! carry full configuration values, the client evaluates them with its
//! own [`Evaluator`] (typically a `Collector` seeded exactly like
//! `ceal tune`'s), and each `tell` ships the outcomes together with
//! the evaluator's noise-stream checkpoint, which is what lets a
//! crashed-and-restarted client resume bit-identically by token.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::serve::manager::SessionManager;
use crate::serve::protocol::{
    ask_line, batch_from_json, close_line, finish_line, open_line, parse_response, reopen_line,
    state_line, tell_line, OpenSpec, ServeError,
};
use crate::tuner::journal::eval_from_json;
use crate::tuner::{
    Evaluator, EvaluatorState, MeasurementBatch, MeasurementResult, TraceError, TraceHeader,
};
use crate::util::json::Json;

fn io_err(msg: String) -> ServeError {
    ServeError::Trace(TraceError::Io(msg))
}

/// One request line out, one response line back.
pub trait LineTransport {
    fn exchange(&mut self, line: &str) -> Result<String, ServeError>;
}

/// Blocking TCP transport for a remote daemon.
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpTransport {
    pub fn connect(addr: &str) -> Result<TcpTransport, ServeError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| io_err(format!("cannot connect to {addr}: {e}")))?;
        let writer = stream
            .try_clone()
            .map_err(|e| io_err(format!("cannot clone connection: {e}")))?;
        Ok(TcpTransport {
            reader: BufReader::new(stream),
            writer,
        })
    }
}

impl LineTransport for TcpTransport {
    fn exchange(&mut self, line: &str) -> Result<String, ServeError> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"))
            .and_then(|()| self.writer.flush())
            .map_err(|e| io_err(format!("send failed: {e}")))?;
        let mut resp = String::new();
        let n = self
            .reader
            .read_line(&mut resp)
            .map_err(|e| io_err(format!("receive failed: {e}")))?;
        if n == 0 {
            return Err(io_err("server closed the connection".into()));
        }
        Ok(resp.trim_end().to_string())
    }
}

/// In-process transport: drives a [`SessionManager`] directly through
/// the same line codec the TCP path uses.
pub struct Loopback<'m>(pub &'m SessionManager);

impl LineTransport for Loopback<'_> {
    fn exchange(&mut self, line: &str) -> Result<String, ServeError> {
        Ok(self.0.handle_line(line))
    }
}

/// Decoded `open` response.
#[derive(Clone, Debug)]
pub struct OpenInfo {
    pub token: String,
    pub resumed: bool,
    pub done: bool,
    pub exchanges: usize,
    /// The session's pinned cell settings (journal header) — a
    /// resuming client rebuilds its evaluator from these.
    pub header: TraceHeader,
    /// Last journaled evaluator checkpoint (resume only): restore it
    /// into the client-side evaluator to continue the noise stream
    /// where the journal left it.
    pub eval: Option<EvaluatorState>,
}

/// Decoded `ask` response.
#[derive(Clone, Debug)]
pub struct AskReply {
    pub done: bool,
    pub seq: usize,
    /// Present iff `!done`.
    pub batch: Option<MeasurementBatch>,
}

/// Decoded `tell` response.
#[derive(Clone, Copy, Debug)]
pub struct TellReply {
    pub applied: bool,
    pub duplicate: bool,
    pub done: bool,
}

fn bool_field(v: &Json, key: &str) -> bool {
    v.get(key).and_then(Json::as_bool).unwrap_or(false)
}

fn usize_field(v: &Json, key: &str, what: &str) -> Result<usize, ServeError> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| io_err(format!("{what} response missing integer '{key}'")))
}

/// Typed protocol client over any transport.
pub struct ServeClient<T: LineTransport> {
    transport: T,
    token: Option<String>,
}

impl<T: LineTransport> ServeClient<T> {
    pub fn new(transport: T) -> ServeClient<T> {
        ServeClient {
            transport,
            token: None,
        }
    }

    /// The session token, once `open`/`reopen` succeeded.
    pub fn token(&self) -> Option<&str> {
        self.token.as_deref()
    }

    fn require_token(&self) -> Result<&str, ServeError> {
        self.token
            .as_deref()
            .ok_or_else(|| ServeError::Usage("no session open on this client".into()))
    }

    fn roundtrip(&mut self, line: &str) -> Result<Json, ServeError> {
        let resp = self.transport.exchange(line)?;
        parse_response(&resp)
    }

    fn decode_open(&mut self, v: &Json) -> Result<OpenInfo, ServeError> {
        let token = v
            .get("token")
            .and_then(Json::as_str)
            .ok_or_else(|| io_err("open response missing 'token'".into()))?
            .to_string();
        let header = v
            .get("header")
            .ok_or_else(|| io_err("open response missing 'header'".into()))
            .and_then(|h| TraceHeader::from_json(h).map_err(ServeError::Trace))?;
        let eval = match v.get("eval") {
            None | Some(Json::Null) => None,
            Some(e) => Some(eval_from_json(e, "open eval state").map_err(ServeError::Trace)?),
        };
        let info = OpenInfo {
            token: token.clone(),
            resumed: bool_field(v, "resumed"),
            done: bool_field(v, "done"),
            exchanges: usize_field(v, "exchanges", "open")?,
            header,
            eval,
        };
        self.token = Some(token);
        Ok(info)
    }

    /// Open a fresh session for `spec`.
    pub fn open(&mut self, spec: &OpenSpec) -> Result<OpenInfo, ServeError> {
        let v = self.roundtrip(&open_line(spec))?;
        self.decode_open(&v)
    }

    /// Resume an existing session by token (across client restarts,
    /// daemon restarts, or both).
    pub fn reopen(&mut self, token: &str) -> Result<OpenInfo, ServeError> {
        let v = self.roundtrip(&reopen_line(token))?;
        self.decode_open(&v)
    }

    pub fn ask(&mut self) -> Result<AskReply, ServeError> {
        let line = ask_line(self.require_token()?);
        let v = self.roundtrip(&line)?;
        let done = bool_field(&v, "done");
        let seq = usize_field(&v, "seq", "ask")?;
        let batch = if done {
            None
        } else {
            let b = v
                .get("batch")
                .ok_or_else(|| io_err("ask response missing 'batch'".into()))?;
            Some(batch_from_json(b)?)
        };
        Ok(AskReply { done, seq, batch })
    }

    pub fn tell(
        &mut self,
        seq: usize,
        results: &[MeasurementResult],
        eval: Option<&EvaluatorState>,
    ) -> Result<TellReply, ServeError> {
        let line = tell_line(self.require_token()?, seq, results, eval);
        let v = self.roundtrip(&line)?;
        Ok(TellReply {
            applied: bool_field(&v, "applied"),
            duplicate: bool_field(&v, "duplicate"),
            done: bool_field(&v, "done"),
        })
    }

    /// Raw progress snapshot (the `state` object plus `done` and
    /// `exchanges`).
    pub fn state(&mut self) -> Result<Json, ServeError> {
        let line = state_line(self.require_token()?);
        self.roundtrip(&line)
    }

    /// Finish the session, returning the result payload (idempotent on
    /// the server: repeat calls answer from `result.json`).
    pub fn finish(&mut self) -> Result<Json, ServeError> {
        let line = finish_line(self.require_token()?);
        self.roundtrip(&line)
    }

    /// Evict the session to disk (it stays resumable by token).
    pub fn close(&mut self) -> Result<(), ServeError> {
        let line = close_line(self.require_token()?);
        self.roundtrip(&line)?;
        Ok(())
    }

    /// Drive the open session to completion with a client-side
    /// evaluator: ask, measure locally, tell (shipping the evaluator
    /// checkpoint), repeat; then finish.  `throttle` inserts a sleep
    /// after each tell — the CI kill-resume cell uses it to widen the
    /// SIGKILL window.
    pub fn drive(
        &mut self,
        evaluator: &mut dyn Evaluator,
        throttle: Option<Duration>,
    ) -> Result<Json, ServeError> {
        loop {
            let ask = self.ask()?;
            if ask.done {
                break;
            }
            let batch = ask
                .batch
                .expect("ask replies carry a batch unless done");
            let results = evaluator.evaluate(&batch);
            let eval = evaluator.checkpoint_state();
            let reply = self.tell(ask.seq, &results, eval.as_ref())?;
            if let Some(d) = throttle {
                std::thread::sleep(d);
            }
            if reply.done {
                break;
            }
        }
        self.finish()
    }
}
