//! Evaluation metrics (paper §7.2): recall score, median absolute
//! percentage error, and the least-number-of-uses payoff metric.

use crate::util::stats;

/// Recall score S_r(n) (Eqn 3): the fraction of the model's top-n
/// configurations that are also in the measured top-n.  Both inputs are
/// "lower is better" (times); "top" = smallest.
pub fn recall_score(n: usize, predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    assert!(n >= 1, "recall needs n >= 1");
    let n = n.min(predicted.len());
    let top_pred = stats::bottom_k_indices(predicted, n);
    let top_act = stats::bottom_k_indices(actual, n);
    let act_set: std::collections::HashSet<usize> = top_act.into_iter().collect();
    let hits = top_pred.iter().filter(|i| act_set.contains(i)).count();
    hits as f64 / n as f64
}

/// Sum of top-1..3 recalls — the model-switch statistic of Alg. 1
/// lines 17-19.
pub fn recall_sum_123(predicted: &[f64], actual: &[f64]) -> f64 {
    (1..=3).map(|n| recall_score(n, predicted, actual)).sum()
}

/// Absolute percentage error of one prediction.
pub fn ape(actual: f64, predicted: f64) -> f64 {
    ((actual - predicted) / actual).abs()
}

/// Median APE over a sample set (paper §7.4.2).
pub fn mdape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    assert!(!actual.is_empty());
    let apes: Vec<f64> = actual
        .iter()
        .zip(predicted)
        .map(|(&a, &p)| ape(a, p))
        .collect();
    stats::median(&apes)
}

/// MdAPE restricted to the actually-best `frac` fraction of samples
/// (paper Fig. 6 uses the top 2%).
pub fn mdape_top_fraction(actual: &[f64], predicted: &[f64], frac: f64) -> f64 {
    assert!((0.0..=1.0).contains(&frac));
    let k = ((actual.len() as f64 * frac).ceil() as usize).max(1);
    let idx = stats::bottom_k_indices(actual, k);
    let a: Vec<f64> = idx.iter().map(|&i| actual[i]).collect();
    let p: Vec<f64> = idx.iter().map(|&i| predicted[i]).collect();
    mdape(&a, &p)
}

/// Least number of uses (paper §7.2.3): N = c / Δp, where `cost` is the
/// total collection cost (sum of objective values over all training
/// runs) and Δp is the per-run improvement of the tuned configuration
/// over the expert recommendation.  Returns None when the tuned config
/// is no better than the expert (the auto-tuner never pays off).
pub fn least_number_of_uses(cost: f64, expert_value: f64, tuned_value: f64) -> Option<f64> {
    let delta = expert_value - tuned_value;
    if delta <= 0.0 {
        None
    } else {
        Some(cost / delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recall_perfect_and_disjoint() {
        let actual = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(recall_score(3, &actual, &actual), 1.0);
        let anti = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(recall_score(2, &anti, &actual), 0.0);
        // top-3 of anti = {4,3,2 indices} vs actual {0,1,2}: overlap {2}
        assert!((recall_score(3, &anti, &actual) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn recall_top1_is_probability_of_best() {
        let actual = [3.0, 1.0, 2.0];
        let good = [0.9, 0.1, 0.5];
        let bad = [0.1, 0.9, 0.5];
        assert_eq!(recall_score(1, &good, &actual), 1.0);
        assert_eq!(recall_score(1, &bad, &actual), 0.0);
    }

    #[test]
    fn recall_sum_bounds() {
        let actual = [1.0, 2.0, 3.0, 4.0];
        let s = recall_sum_123(&actual, &actual);
        assert!((s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mdape_basics() {
        let actual = [100.0, 200.0, 400.0];
        let pred = [110.0, 180.0, 400.0];
        // APEs: 0.10, 0.10, 0.0 -> median 0.10
        assert!((mdape(&actual, &pred) - 0.10).abs() < 1e-12);
    }

    #[test]
    fn mdape_top_fraction_restricts() {
        // best 2 samples predicted perfectly; worst predicted terribly
        let actual = [1.0, 2.0, 100.0, 200.0];
        let pred = [1.0, 2.0, 500.0, 900.0];
        assert_eq!(mdape_top_fraction(&actual, &pred, 0.5), 0.0);
        assert!(mdape(&actual, &pred) > 1.0);
    }

    #[test]
    fn payoff_math() {
        // paper §7.4.4: cost c, improvement Δp per run
        let n = least_number_of_uses(864.0 * 0.5, 4.0, 3.5).unwrap();
        assert!((n - 864.0).abs() < 1e-9);
        assert!(least_number_of_uses(10.0, 3.0, 3.5).is_none());
    }
}
