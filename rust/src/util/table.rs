//! ASCII table rendering for the experiment harness (paper-style rows
//! printed to the terminal).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// Simple ASCII table with a header row and per-column alignment.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            aligns: header
                .iter()
                .map(|_| Align::Right)
                .collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Left-align the given column indices (defaults are right-aligned).
    pub fn align_left(mut self, cols: &[usize]) -> Self {
        for &c in cols {
            self.aligns[c] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let parts: Vec<String> = widths.iter().map(|w| "-".repeat(w + 2)).collect();
            format!("+{}+", parts.join("+"))
        };
        let fmt_row = |cells: &[String]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let pad = widths[i] - c.chars().count();
                    match self.aligns[i] {
                        Align::Left => format!(" {}{} ", c, " ".repeat(pad)),
                        Align::Right => format!(" {}{} ", " ".repeat(pad), c),
                    }
                })
                .collect();
            format!("|{}|", parts.join("|"))
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with `prec` significant-looking decimals, trimming wide
/// magnitudes sensibly (used all over the experiment printouts).
pub fn fnum(x: f64, prec: usize) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x != 0.0 && x.abs() < 10f64.powi(-(prec as i32)) {
        return format!("{x:.2e}");
    }
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]).align_left(&[0]);
        t.row(&["a".into(), "1.5".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| name   | val |"), "got:\n{s}");
        assert!(s.contains("| a      | 1.5 |"), "got:\n{s}");
        assert!(s.contains("| longer |  22 |"), "got:\n{s}");
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fnum(0.00001, 3), "1.00e-5");
        assert_eq!(fnum(f64::INFINITY, 2), "inf");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a"]);
        t.row(&["x".into(), "y".into()]);
    }
}
