//! Self-built substrates.
//!
//! The offline vendor set ships only the `xla` crate's dependency
//! closure plus `anyhow`/`thiserror`, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `criterion`, `proptest`, `rayon`) are
//! implemented here at the scale this project needs: a counter-based
//! PCG RNG with keyed substreams, descriptive statistics, minimal
//! JSON/CSV I/O, ASCII tables, a CLI argument parser, a micro-benchmark
//! harness, a property-testing helper, and a deterministic fork-join
//! worker pool ([`parallel`]).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fsio;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
