//! Durable file I/O for crash-safe artifacts: atomic whole-file writes
//! (temp file + fsync + rename) and the CRC-32 used to seal journal and
//! snapshot records.
//!
//! Everything that must never be observed torn — the measurement
//! journal, session snapshots, recorded traces, and results CSVs —
//! goes through [`atomic_write`]: readers either see the previous
//! complete file or the new complete one, never a prefix.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// CRC-32 (IEEE 802.3, the zlib polynomial) lookup table, built at
/// compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 checksum (IEEE polynomial, reflected, zlib-compatible).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Replace `path` atomically with `bytes`: write a sibling temp file,
/// fsync it, and rename it over the destination.  Parent directories
/// are created as needed; on any failure the destination is untouched
/// (the temp file is cleaned up best-effort).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let parent = path.parent().filter(|p| !p.as_os_str().is_empty());
    if let Some(dir) = parent {
        fs::create_dir_all(dir)?;
    }
    let mut tmp_name = path
        .file_name()
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("atomic_write: no file name in {}", path.display()),
            )
        })?
        .to_os_string();
    // per-process suffix so concurrent writers of *different* files in
    // one directory can never collide on temp names
    tmp_name.push(format!(".tmp{}", std::process::id()));
    let tmp = path.with_file_name(tmp_name);
    let write = (|| {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, path)
    })();
    if write.is_err() {
        let _ = fs::remove_file(&tmp);
        return write;
    }
    // make the rename itself durable; not all platforms support
    // fsyncing a directory handle, so this is best-effort
    if let Some(dir) = parent {
        if let Ok(d) = fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ceal_fsio_{}_{tag}", std::process::id()))
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // reference values from the zlib crc32() function
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn atomic_write_roundtrips_and_replaces() {
        let path = temp_path("roundtrip.txt");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer content").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second, longer content");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn atomic_write_creates_parent_dirs_and_leaves_no_temp() {
        let dir = temp_path("nested");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("a/b/out.csv");
        atomic_write(&path, b"x,y\n").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"x,y\n");
        let entries: Vec<_> = fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert_eq!(entries, vec![std::ffi::OsString::from("out.csv")]);
        let _ = fs::remove_dir_all(&dir);
    }
}
