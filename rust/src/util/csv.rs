//! Tiny CSV writer for experiment outputs under `results/`.

use std::path::Path;

/// In-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row of display-able values.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize with RFC-4180 quoting where needed.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let emit = |out: &mut String, cells: &[String]| {
            let encoded: Vec<String> = cells.iter().map(|c| quote(c)).collect();
            out.push_str(&encoded.join(","));
            out.push('\n');
        };
        emit(&mut out, &self.header);
        for row in &self.rows {
            emit(&mut out, row);
        }
        out
    }

    /// Write to a file atomically (temp file + rename), creating
    /// parent directories — a crash mid-save can never leave a torn
    /// results artifact.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        super::fsio::atomic_write(path, self.to_string().as_bytes())
    }
}

fn quote(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "2".into()]);
        w.row_display(&[&3.5, &"x"]);
        assert_eq!(w.to_string(), "a,b\n1,2\n3.5,x\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new(&["v"]);
        w.row(&["has,comma".into()]);
        w.row(&["has \"quote\"".into()]);
        assert_eq!(w.to_string(), "v\n\"has,comma\"\n\"has \"\"quote\"\"\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".into()]);
    }
}
