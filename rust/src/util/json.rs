//! Minimal JSON: a value model, a writer, and a recursive-descent parser
//! (enough for `artifacts/meta.json` and result emission; no serde in
//! the offline vendor set).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value. Numbers are f64 (adequate for manifests and results).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Serialize on one line with no whitespace — the JSON-lines form
    /// used by the session trace files.  Numbers round-trip exactly:
    /// integral values print as integers, everything else through
    /// Rust's shortest-round-trip float formatting.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) => self.write(out, 0),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad1);
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a readable error with byte offset.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::Str("LV".into())),
            ("pool", Json::Num(2000.0)),
            ("vals", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_meta_like() {
        let text = r#"{"pool_n": 2048, "artifacts": ["a.hlo.txt", "b.hlo.txt"], "depth": 6}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("pool_n").unwrap().as_usize(), Some(2048));
        assert_eq!(v.get("artifacts").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn bool_accessor() {
        let v = parse(r#"{"ok": true, "dup": false, "n": 1}"#).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("dup").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("n").unwrap().as_bool(), None);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\nb\t\"q\" A"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn nested_structures() {
        let text = r#"[{"a": [1, [2, {"b": null}]]}, false]"#;
        let v = parse(text).unwrap();
        let reparsed = parse(&v.pretty()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn compact_roundtrips_and_is_one_line() {
        let v = Json::obj(vec![
            ("ys", Json::arr_f64(&[1.5, 3.0, -0.0625])),
            ("mode", Json::Str("seq".into())),
            ("batch", Json::Num(0.0)),
        ]);
        let text = v.compact();
        assert!(!text.contains('\n'));
        assert!(!text.contains(' '));
        assert_eq!(parse(&text).unwrap(), v);
        // key order is BTreeMap-alphabetical, so the encoding is stable
        assert_eq!(text, r#"{"batch":0,"mode":"seq","ys":[1.5,3,-0.0625]}"#);
    }

    #[test]
    fn number_formats() {
        let v = parse("[-1.5e3, 0.25, 7]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(-1500.0));
        assert_eq!(arr[1].as_f64(), Some(0.25));
        assert_eq!(arr[2].as_f64(), Some(7.0));
    }
}
