//! Micro-benchmark harness (criterion is not in the offline vendor
//! set).  Measures wall-clock over warmup + measured iterations and
//! prints mean / median / p10 / p90 plus optional throughput.  Used by
//! every target under `rust/benches/`.

use std::time::Instant;

use super::stats;

/// One benchmark measurement summary (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    /// optional items-per-iteration for throughput reporting
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10}/iter  median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            human_time(self.mean_s),
            human_time(self.median_s),
            human_time(self.p10_s),
            human_time(self.p90_s),
            self.iters
        );
        if let Some(items) = self.items {
            let rate = items / self.mean_s;
            s.push_str(&format!("  [{} items/s]", human_rate(rate)));
        }
        s
    }
}

/// Format seconds with an adaptive unit.
pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

fn human_rate(r: f64) -> String {
    if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.1}k", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

/// Benchmark runner: fixed warmup iterations then `iters` timed runs.
pub struct Bencher {
    warmup: usize,
    iters: usize,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// From env: CEAL_BENCH_ITERS / CEAL_BENCH_WARMUP override defaults —
    /// lets CI shrink runs.
    pub fn from_env(default_warmup: usize, default_iters: usize) -> Self {
        let get = |k: &str, d: usize| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(d)
        };
        Bencher::new(
            get("CEAL_BENCH_WARMUP", default_warmup),
            get("CEAL_BENCH_ITERS", default_iters),
        )
    }

    /// Time `f`, which should return something opaque to keep the work
    /// observable (black-box by return value).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Time `f` and report `items`-per-second throughput.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            let out = f();
            std::hint::black_box(&out);
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            let out = f();
            std::hint::black_box(&out);
            times.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            mean_s: stats::mean(&times),
            median_s: stats::median(&times),
            p10_s: stats::quantile(&times, 0.1),
            p90_s: stats::quantile(&times, 0.9),
            items,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new(1, 5);
        let r = b
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..10_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .clone();
        assert!(r.mean_s > 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.p10_s <= r.p90_s);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2e-9).contains("ns"));
        assert!(human_time(2e-6).contains("µs"));
        assert!(human_time(2e-3).contains("ms"));
        assert!(human_time(2.0).contains(" s"));
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::new(0, 2);
        let r = b.bench_items("noop", 100.0, || 1).clone();
        assert_eq!(r.items, Some(100.0));
        assert!(r.report().contains("items/s"));
    }
}
