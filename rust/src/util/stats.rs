//! Descriptive statistics used across metrics, benches and experiments.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0 when n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated quantile, `q` in [0, 1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (0.5 quantile).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Index of the minimum value (first on ties). None for empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).expect("NaN in argmin"))
        .map(|(i, _)| i)
}

/// Index of the maximum value (first on ties). None for empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, x) in xs.iter().enumerate() {
        match best {
            None => best = Some(i),
            Some(b) => {
                if x.partial_cmp(&xs[b]).expect("NaN in argmax") == std::cmp::Ordering::Greater {
                    best = Some(i);
                }
            }
        }
    }
    best
}

/// Indices of the `k` smallest values, ascending (stable order on ties).
pub fn bottom_k_indices(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[a]
            .partial_cmp(&xs[b])
            .expect("NaN in bottom_k")
            .then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

/// Indices of the `k` largest values, descending (stable order on ties).
pub fn top_k_indices(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b]
            .partial_cmp(&xs[a])
            .expect("NaN in top_k")
            .then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

/// Rank positions (0 = smallest) of each element.
pub fn ranks_ascending(xs: &[f64]) -> Vec<usize> {
    let order = bottom_k_indices(xs, xs.len());
    let mut ranks = vec![0usize; xs.len()];
    for (rank, &i) in order.iter().enumerate() {
        ranks[i] = rank;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argmin_argmax_ties_first() {
        let xs = [3.0, 1.0, 1.0, 5.0, 5.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(3));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn bottom_top_k() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(bottom_k_indices(&xs, 3), vec![1, 3, 4]);
        assert_eq!(top_k_indices(&xs, 2), vec![0, 2]);
        assert_eq!(bottom_k_indices(&xs, 99).len(), 5);
    }

    #[test]
    fn ranks() {
        let xs = [10.0, 0.0, 5.0];
        assert_eq!(ranks_ascending(&xs), vec![2, 0, 1]);
    }
}
