//! Property-testing helper (proptest is not in the offline vendor set).
//!
//! [`check`] runs a property over `cases` generated inputs from a seeded
//! [`Pcg32`]; on failure it panics with the case index and the derived
//! seed so the exact failing input can be replayed:
//!
//! ```no_run
//! use ceal::util::{prop, rng::Pcg32};
//! prop::check("sorted idempotent", 64, |rng| {
//!     let mut v: Vec<u32> = (0..rng.gen_range(20)).map(|_| rng.next_u32()).collect();
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     prop::assert_prop(v == w, "double sort changed order")
//! });
//! ```

use super::rng::Pcg32;

/// Property outcome: Ok to pass, Err(message) to fail the case.
pub type PropResult = Result<(), String>;

/// Convenience constructor for property assertions.
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64s are within tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let denom = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` over `cases` seeded inputs. The RNG handed to each case is
/// derived from a fixed root and the case index, so failures reproduce.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Pcg32) -> PropResult) {
    check_seeded(name, 0xCEA1_0001, cases, prop)
}

/// Like [`check`] with an explicit root seed (replay a failure).
pub fn check_seeded(
    name: &str,
    root_seed: u64,
    cases: usize,
    prop: impl Fn(&mut Pcg32) -> PropResult,
) {
    let root = Pcg32::new(root_seed, 0);
    for case in 0..cases {
        let mut rng = root.derive(case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (root_seed={root_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("count", 10, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_context() {
        check("fails", 5, |rng| {
            assert_prop(rng.f64() < 2.0, "impossible")?;
            Err("always".into())
        });
    }

    #[test]
    fn assert_close_relative() {
        assert!(assert_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(assert_close(1.0, 1.5, 1e-3, "x").is_err());
    }
}
