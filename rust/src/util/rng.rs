//! Deterministic pseudo-random numbers: SplitMix64 seeding + PCG32 core.
//!
//! Every stochastic choice in the repository flows from a [`Pcg32`]
//! derived from an experiment-level seed via [`Pcg32::derive`], so all
//! campaigns, simulator runs and property tests are exactly
//! reproducible (no wall-clock, no global state).

/// SplitMix64 step — used to expand seeds into well-mixed state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// cached second normal variate from Box-Muller
    spare_normal: Option<f64>,
}

/// A raw [`Pcg32`] position, capturable with [`Pcg32::snapshot`] and
/// restorable with [`Pcg32::from_snapshot`].  Includes the pending
/// Box-Muller spare: two generators at the same `(state, inc)` but with
/// different cached spares would diverge on their next [`Pcg32::normal`]
/// draw, so the spare is part of the position.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngSnapshot {
    pub state: u64,
    pub inc: u64,
    pub spare_normal: Option<f64>,
}

impl Pcg32 {
    /// Construct from a seed and a stream id; distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let mut rng = Pcg32 {
            state: 0,
            inc: (splitmix64(&mut sm) ^ stream).wrapping_shl(1) | 1,
            spare_normal: None,
        };
        rng.state = s0.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Capture the raw generator position for checkpointing; a
    /// generator rebuilt with [`Pcg32::from_snapshot`] continues the
    /// stream bit-exactly.
    pub fn snapshot(&self) -> RngSnapshot {
        RngSnapshot {
            state: self.state,
            inc: self.inc,
            spare_normal: self.spare_normal,
        }
    }

    /// Rebuild a generator at a snapshotted position (no reseeding, no
    /// warm-up draw — the stream resumes exactly where it was).
    pub fn from_snapshot(s: RngSnapshot) -> Pcg32 {
        Pcg32 {
            state: s.state,
            inc: s.inc,
            spare_normal: s.spare_normal,
        }
    }

    /// Derive an independent child generator keyed by `key` — used to
    /// give each (experiment, repetition, purpose) its own stream.
    pub fn derive(&self, key: u64) -> Pcg32 {
        let mut sm = self.state ^ key.wrapping_mul(0xA076_1D64_78BD_642F);
        let seed = splitmix64(&mut sm);
        let stream = splitmix64(&mut sm);
        Pcg32::new(seed, stream)
    }

    /// Derive a child generator keyed by a string label.
    pub fn derive_str(&self, label: &str) -> Pcg32 {
        self.derive(fnv1a(label.as_bytes()))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        if bound == 1 {
            return 0;
        }
        // rejection sampling on the top bits to stay unbiased
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn gen_range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Lognormal multiplicative noise factor with median 1.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// [`sample_indices`](Self::sample_indices) with O(k) bookkeeping
    /// instead of materializing the `n`-element index array: the swaps
    /// of the virtual array are tracked sparsely.  Same draw sequence,
    /// same result, for callers where `k ≪ n` (pinned by a test below).
    pub fn sample_indices_sparse(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // swaps[p] = value currently at virtual position p (positions
        // absent from the map still hold their own index).
        let mut swaps: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            let vi = *swaps.get(&i).unwrap_or(&i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            swaps.insert(j, vi);
            // position i is never revisited (later draws touch j >= i+1),
            // so vj is this slot's final value
            out.push(vj);
        }
        out
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// FNV-1a hash — stable label hashing for [`Pcg32::derive_str`].
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be nearly disjoint, got {same}");
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let root = Pcg32::new(7, 0);
        let mut c1 = root.derive(10);
        let mut c1b = root.derive(10);
        let mut c2 = root.derive(11);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(1, 1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_unbiased_small_bound() {
        let mut r = Pcg32::new(3, 3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.gen_range(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 5;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 10,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(9, 9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg32::new(5, 5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_sparse_matches_dense() {
        // the sparse variant must stay draw-for-draw identical to the
        // dense one — other modules rely on the shared RNG stream
        let mut root = Pcg32::new(44, 4);
        for _ in 0..50 {
            let n = 1 + root.gen_range(200) as usize;
            let k = root.gen_range(n as u64 + 1) as usize;
            let mut dense_rng = root.derive(n as u64 ^ (k as u64) << 32);
            let mut sparse_rng = dense_rng.clone();
            assert_eq!(
                dense_rng.sample_indices(n, k),
                sparse_rng.sample_indices_sparse(n, k),
                "n={n} k={k}"
            );
            // identical RNG consumption too
            assert_eq!(dense_rng.next_u64(), sparse_rng.next_u64(), "n={n} k={k}");
        }
    }

    #[test]
    fn snapshot_resumes_stream_bit_exactly() {
        let mut r = Pcg32::new(0xC0C0, 3);
        // draw one normal so a Box-Muller spare is pending
        let _ = r.normal();
        let snap = r.snapshot();
        assert!(snap.spare_normal.is_some(), "spare must be captured");
        let mut resumed = Pcg32::from_snapshot(snap);
        for _ in 0..50 {
            assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(6, 6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Pcg32::new(8, 8);
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.lognormal_factor(0.3)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 1.0).abs() < 0.03, "median {med}");
    }
}
