//! Minimal CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [positionals...] [--key value | --flag]`.
//! Flags may appear anywhere after the subcommand.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn parse_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects a number, got '{v}': {e}")),
        }
    }

    /// Path-valued option (`--record trace.jsonl`), `None` when absent.
    pub fn opt_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.opt(name).map(std::path::PathBuf::from)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name} expects an integer, got '{v}': {e}")),
        }
    }

    /// Duration-valued option in (possibly fractional) seconds
    /// (`--session-ttl 900`, `--measure-deadline 0.5`), `None` when
    /// absent; zero and negative values are rejected.
    pub fn opt_secs(&self, name: &str) -> Result<Option<std::time::Duration>, String> {
        let Some(s) = self.opt(name) else {
            return Ok(None);
        };
        let secs: f64 = s
            .parse()
            .map_err(|e| format!("bad --{name} '{s}': {e}"))?;
        if !(secs > 0.0) {
            return Err(format!("--{name} must be a positive number of seconds"));
        }
        Ok(Some(std::time::Duration::from_secs_f64(secs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["fig", "5", "--reps", "10"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig"));
        assert_eq!(a.positionals, vec!["5"]);
        assert_eq!(a.opt_usize("reps", 1).unwrap(), 10);
    }

    #[test]
    fn key_equals_value_and_flags() {
        let a = parse(&["tune", "--workflow=LV", "--verbose", "--m", "50"]);
        assert_eq!(a.opt("workflow"), Some("LV"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("m", 0).unwrap(), 50);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_end() {
        let a = parse(&["x", "--dry-run", "--out", "results"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.opt("out"), Some("results"));
    }

    #[test]
    fn secs_option_parses_and_rejects_nonpositive() {
        let a = parse(&["x", "--session-ttl", "1.5"]);
        assert_eq!(
            a.opt_secs("session-ttl").unwrap(),
            Some(std::time::Duration::from_millis(1500))
        );
        assert_eq!(a.opt_secs("absent").unwrap(), None);
        assert!(parse(&["x", "--ttl", "0"]).opt_secs("ttl").is_err());
        assert!(parse(&["x", "--ttl", "-3"]).opt_secs("ttl").is_err());
        assert!(parse(&["x", "--ttl", "soon"]).opt_secs("ttl").is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--m", "abc"]);
        assert!(a.opt_usize("m", 1).is_err());
        assert!(a.opt_f64("m", 1.0).is_err());
    }

    #[test]
    fn path_options() {
        let a = parse(&["tune", "--record", "t.jsonl"]);
        assert_eq!(
            a.opt_path("record"),
            Some(std::path::PathBuf::from("t.jsonl"))
        );
        assert_eq!(a.opt_path("replay"), None);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.opt_or("out", "results"), "results");
        assert_eq!(a.opt_f64("sigma", 0.5).unwrap(), 0.5);
    }
}
