//! Zero-dependency process-wide fork-join worker pool.
//!
//! One set of persistent workers serves every parallel hot path in the
//! crate — GBT training ([`crate::gbt::train`]), batched ensemble
//! scoring (`predict_batch`), pool ground-truth measurement, the CEAL
//! inner loop's batch measurements, and campaign repetitions — so
//! nested parallelism composes instead of oversubscribing: an outer
//! fork-join (campaign reps) and the inner fork-joins issued from
//! inside its tasks (model training, pool scoring) all draw from the
//! same workers, and reps < cores no longer strands cores.
//!
//! ## Determinism: the ordered-reduction argument
//!
//! Every entry point obeys one contract — **bitwise thread-count
//! invariance**: the result is byte-identical for any worker count,
//! including one.  The construction is uniform:
//!
//! 1. Work is split into tasks whose *boundaries depend only on the
//!    input* (a fixed chunk size, one task per feature, one task per
//!    repetition) — never on the worker count.  Scheduling decides
//!    only *when* a task runs, not *what* it computes.
//! 2. Each task writes exclusively to its own output slot(s) — a
//!    disjoint chunk of a result buffer, one feature's histogram
//!    columns, one repetition's row.  No cell has two writers, so no
//!    merge step exists that could reorder floating-point reductions.
//! 3. Any cross-task reduction (folding costs, picking the best
//!    split) happens *after* the join, sequentially, in task-index
//!    order — the same order a single thread would produce.
//!
//! Under this contract a data race is impossible by construction and
//! the parallel result equals the sequential one bit for bit, which is
//! what `tests/parallel_invariance.rs` pins for threads ∈ {1, 2, 5, 8}.
//!
//! ## Sizing
//!
//! The worker pool itself is sized once from the hardware
//! ([`hardware_threads`], capped at 16).  How many workers may join a
//! given fork-join is the *width* passed per call; hot paths default it
//! to [`current_threads`], which resolves, in precedence order:
//! `--threads N` (the CLI calls [`set_threads`]) > the `CEAL_THREADS`
//! environment variable > `available_parallelism`.  [`with_threads`]
//! scopes an override for tests and benches.
//!
//! ## Nesting and deadlock-freedom
//!
//! `run` called from inside a pool task pushes a new job and the
//! calling task participates in it; idle workers help, busy workers
//! don't.  A waiting caller only ever waits on tasks of its *own* job,
//! and tasks only wait on jobs strictly below them, so the wait graph
//! is acyclic.  In the degenerate case (all workers busy) the caller
//! simply executes all of its tasks itself.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Width selection
// ---------------------------------------------------------------------------

/// Usable hardware parallelism, capped at 16 (the coordinator's
/// historical ceiling — beyond it the simulator's memory traffic, not
/// compute, dominates).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Default fork-join width: the `CEAL_THREADS` environment variable
/// when set to a positive integer, otherwise [`hardware_threads`].
/// Resolved once per process.
pub fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("CEAL_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(hardware_threads)
    })
}

/// Process-wide width override; 0 = unset (fall back to
/// [`default_threads`]).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Effective fork-join width for hot paths that take no explicit
/// width: the [`set_threads`]/[`with_threads`] override when present,
/// else [`default_threads`].
pub fn current_threads() -> usize {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Install a process-wide width (the CLI's `--threads`).  Passing 0
/// clears the override.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// Run `f` with [`current_threads`] pinned to `n`, restoring the
/// previous override afterwards.  Results never depend on the width
/// (see the module docs), so concurrent `with_threads` scopes from
/// different threads can only perturb performance, not outputs —
/// which is why the invariance tests may run under a parallel test
/// harness.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(OVERRIDE.swap(n, Ordering::Relaxed));
    f()
}

// ---------------------------------------------------------------------------
// Shared-pointer building block
// ---------------------------------------------------------------------------

/// A raw pointer that asserts `Send + Sync` so disjoint-slot writers
/// can share one output buffer across tasks.  Crate-internal building
/// block: every use site must guarantee that concurrent tasks touch
/// non-overlapping elements.
pub struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: callers uphold the disjoint-writes contract documented above.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// One fork-join in flight.  Workers claim task indices from `next`;
/// `pending` counts unfinished tasks; the submitting caller blocks on
/// `done_cv` until the last task signals.
struct Job {
    /// Lifetime-erased pointer to the caller's task closure.  Valid for
    /// the whole job: `ThreadPool::run` does not return (or unwind)
    /// before `pending` reaches zero, i.e. before the last dereference.
    task: *const (dyn Fn(usize) + Sync),
    n_tasks: usize,
    /// Next unclaimed task index (monotone; may run past `n_tasks`).
    next: AtomicUsize,
    /// Tasks not yet finished executing.
    pending: AtomicUsize,
    /// How many pool workers may join (the caller participates on top
    /// of these, so a width-`w` job has `w - 1` helper slots).
    max_helpers: usize,
    helpers: AtomicUsize,
    state: Mutex<JobState>,
    done_cv: Condvar,
}

struct JobState {
    finished: bool,
    /// First captured panic payload, re-thrown by the caller.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

// SAFETY: `task` is only dereferenced while the job is in flight (see
// the field docs); all other fields are sync primitives.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Erase the task reference's lifetime for storage in a [`Job`].
fn erase<'a>(task: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync) {
    let ptr: *const (dyn Fn(usize) + Sync + 'a) = task;
    // SAFETY: `ThreadPool::run` joins the job (pending == 0) before
    // returning, so the pointee outlives every dereference even though
    // the stored type claims 'static.
    unsafe {
        std::mem::transmute::<*const (dyn Fn(usize) + Sync + 'a), *const (dyn Fn(usize) + Sync)>(
            ptr,
        )
    }
}

struct Shared {
    /// Jobs with unclaimed tasks, oldest first.
    queue: Mutex<Vec<Arc<Job>>>,
    /// Signalled when a job is pushed.
    ready: Condvar,
}

/// Persistent fork-join worker pool; see the module docs.  Use the
/// process-wide instance via [`pool`] (or the free-function wrappers).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: usize,
}

impl ThreadPool {
    fn with_workers(n: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            ready: Condvar::new(),
        });
        for w in 0..n {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("ceal-par-{w}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        ThreadPool { shared, workers: n }
    }

    /// Number of persistent workers (the caller of a job participates
    /// on top of these).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fork-join: run `task(0..n_tasks)` across at most `width` threads
    /// (the caller plus up to `width - 1` pool workers) and return when
    /// every task has finished.  A panicking task is captured and
    /// re-thrown here after the join, so borrowed task state is never
    /// observed after an unwind.  `width <= 1` (or an empty pool)
    /// executes inline, in index order — the reference the parallel
    /// schedule is bit-equal to.
    pub fn run(&self, width: usize, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let width = width.clamp(1, n_tasks);
        if width == 1 || self.workers == 0 {
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        let job = Arc::new(Job {
            task: erase(task),
            n_tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_tasks),
            max_helpers: width - 1,
            helpers: AtomicUsize::new(0),
            state: Mutex::new(JobState {
                finished: false,
                panic: None,
            }),
            done_cv: Condvar::new(),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Arc::clone(&job));
        }
        self.shared.ready.notify_all();
        // The caller is a full participant — in the degenerate case
        // (every worker busy) it executes all tasks itself.
        execute_tasks(&job);
        let panic = {
            let mut st = job.state.lock().unwrap();
            while !st.finished {
                st = job.done_cv.wait(st).unwrap();
            }
            st.panic.take()
        };
        // Drop our queue entry if no worker pruned it already.
        {
            let mut q = self.shared.queue.lock().unwrap();
            if let Some(pos) = q.iter().position(|j| Arc::ptr_eq(j, &job)) {
                q.remove(pos);
            }
        }
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }
}

/// Claim-and-execute loop shared by the caller and helpers.
fn execute_tasks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_tasks {
            break;
        }
        // SAFETY: the job is in flight (we hold an unfinished task).
        let task = unsafe { &*job.task };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i)));
        if let Err(payload) = result {
            let mut st = job.state.lock().unwrap();
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        // AcqRel: the final decrement acquires every earlier task's
        // writes, so the caller's join observes all output slots.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut st = job.state.lock().unwrap();
            st.finished = true;
            job.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                q.retain(|j| j.next.load(Ordering::Relaxed) < j.n_tasks);
                let open = q
                    .iter()
                    .find(|j| j.helpers.load(Ordering::Relaxed) < j.max_helpers);
                if let Some(j) = open {
                    j.helpers.fetch_add(1, Ordering::Relaxed);
                    break Arc::clone(j);
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        execute_tasks(&job);
    }
}

/// The process-wide pool, spawned on first use with
/// `hardware_threads() - 1` workers (the submitting thread supplies
/// the last lane of any job).
pub fn pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::with_workers(hardware_threads().saturating_sub(1)))
}

// ---------------------------------------------------------------------------
// Fork-join helpers (the shapes the hot paths actually use)
// ---------------------------------------------------------------------------

/// Gate helper shared by the hot paths: the requested fork-join width
/// when the pass touches at least `gate` work items, else 1 (inline).
/// Centralized so every site resolves width the same way.
pub fn width_for(items: usize, gate: usize) -> usize {
    if items >= gate {
        current_threads()
    } else {
        1
    }
}

/// [`ThreadPool::run`] on the process-wide pool.  Serial calls
/// (`width <= 1` or a single task) execute inline without touching —
/// or lazily spawning — the pool, so fully sequential runs never pay
/// for idle worker threads.
pub fn run(width: usize, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
    if width <= 1 || n_tasks <= 1 {
        for i in 0..n_tasks {
            task(i);
        }
        return;
    }
    pool().run(width, n_tasks, task);
}

/// Ordered chunk map: split `out` into fixed-size chunks (boundaries
/// depend only on `chunk`, never on `width`) and run
/// `f(chunk_index, out_chunk)` across the pool.  Each chunk has exactly
/// one writer, so the result is bit-identical for every width.
pub fn for_each_chunk_mut<T: Send>(
    width: usize,
    chunk: usize,
    out: &mut [T],
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let n = out.len();
    if n == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = (n + chunk - 1) / chunk;
    let ptr = SendPtr::new(out.as_mut_ptr());
    run(width, n_chunks, &move |ci| {
        let start = ci * chunk;
        let len = chunk.min(n - start);
        // SAFETY: chunk `ci` owns elements [start, start + len), and
        // chunks are pairwise disjoint.
        let slice = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(start), len) };
        f(ci, slice);
    });
}

/// Ordered parallel map: `out[i] = f(i)` with one task per index; the
/// returned vector is in index order regardless of schedule.  Slots
/// are `Option<R>` internally, so if a task panics (re-thrown after
/// the join) every already-computed result still drops normally —
/// nothing leaks on the unwind path.
pub fn map_indexed<R: Send>(width: usize, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let ptr = SendPtr::new(out.as_mut_ptr());
    run(width, n, &move |i| {
        // SAFETY: slot `i` is written exactly once, by task `i`; the
        // overwritten value is the `None` it was initialized with.
        unsafe {
            *ptr.get().add(i) = Some(f(i));
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("every map_indexed slot is written by its task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_executes_each_task_exactly_once() {
        for width in [1usize, 2, 5, 8] {
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            run(width, hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} at width {width}");
            }
        }
    }

    #[test]
    fn chunk_map_writes_disjoint_slots() {
        for width in [1usize, 3, 8] {
            let mut out = vec![0usize; 1000];
            for_each_chunk_mut(width, 64, &mut out, |ci, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = ci * 64 + k;
                }
            });
            let want: Vec<usize> = (0..1000).collect();
            assert_eq!(out, want, "width {width}");
        }
    }

    #[test]
    fn map_indexed_preserves_order() {
        for width in [1usize, 2, 7] {
            let got = map_indexed(width, 321, |i| i * i);
            let want: Vec<usize> = (0..321).map(|i| i * i).collect();
            assert_eq!(got, want, "width {width}");
        }
    }

    #[test]
    fn map_indexed_handles_empty_and_single() {
        assert_eq!(map_indexed(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(map_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn nested_fork_join_completes() {
        // Outer tasks each fork an inner job on the same pool; the sums
        // must come out exact for any schedule.
        let totals: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
        run(4, totals.len(), &|outer| {
            run(4, 50, &|inner| {
                totals[outer].fetch_add(inner + 1, Ordering::Relaxed);
            });
        });
        let want = (1..=50).sum::<usize>();
        for (i, t) in totals.iter().enumerate() {
            assert_eq!(t.load(Ordering::Relaxed), want, "outer task {i}");
        }
    }

    #[test]
    #[should_panic(expected = "boom from task")]
    fn task_panic_propagates_to_caller() {
        run(4, 16, &|i| {
            if i == 7 {
                panic!("boom from task {i}");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let caught = std::panic::catch_unwind(|| {
            run(4, 8, &|i| {
                if i % 2 == 0 {
                    panic!("transient");
                }
            });
        });
        assert!(caught.is_err());
        // the pool still works afterwards
        let got = map_indexed(4, 100, |i| i + 1);
        assert_eq!(got.iter().sum::<usize>(), (1..=100).sum::<usize>());
    }

    #[test]
    fn with_threads_overrides_and_restores() {
        let before = current_threads();
        let inside = with_threads(3, current_threads);
        assert_eq!(inside, 3);
        assert_eq!(current_threads(), before);
        assert!(current_threads() >= 1);
    }
}
