//! Expert-recommended configurations — the baseline the
//! least-number-of-uses metric measures improvement against.  Resolved
//! through the workflow registry: each [`WorkflowDef`] table carries
//! its per-objective expert pick (paper Table 2 for the LV/HS/GP trio;
//! hand-picked mid-range configurations for synthetic scenarios).
//!
//! [`WorkflowDef`]: crate::sim::WorkflowDef

use crate::config::{Config, WorkflowId};
use crate::sim::Objective;

/// The registered expert recommendation for (workflow, objective).
pub fn expert_config(id: WorkflowId, objective: Objective) -> Config {
    let def = id.def();
    Config(match objective {
        Objective::ExecTime => def.expert_exec.clone(),
        Objective::CompTime => def.expert_comp.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::WorkflowRegistry;
    use crate::tuner::Problem;

    #[test]
    fn expert_configs_valid_and_feasible() {
        // every *registered* workflow, not just the paper trio
        for id in WorkflowRegistry::global().ids() {
            for obj in Objective::ALL {
                let prob = Problem::new(id, obj);
                let cfg = expert_config(id, obj);
                assert!(
                    prob.sim.spec.validate(&cfg).is_ok(),
                    "{id}/{obj}: {cfg} invalid"
                );
                assert!(prob.sim.feasible(&cfg), "{id}/{obj}: {cfg} infeasible");
                let m = prob.sim.expected(&cfg);
                assert!(obj.value(&m) > 0.0);
            }
        }
    }
}
