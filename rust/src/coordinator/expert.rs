//! Expert-recommended configurations (paper Table 2) — the baseline the
//! least-number-of-uses metric measures improvement against.

use crate::config::{Config, WorkflowId};
use crate::sim::Objective;

/// The Table 2 expert recommendation for (workflow, objective).
pub fn expert_config(id: WorkflowId, objective: Objective) -> Config {
    match (id, objective) {
        (WorkflowId::Lv, Objective::ExecTime) => {
            Config(vec![288, 18, 2, 400, 288, 18, 2])
        }
        (WorkflowId::Lv, Objective::CompTime) => Config(vec![18, 18, 2, 400, 18, 18, 2]),
        (WorkflowId::Hs, Objective::ExecTime) => {
            Config(vec![32, 17, 34, 4, 20, 560, 35])
        }
        (WorkflowId::Hs, Objective::CompTime) => Config(vec![8, 4, 32, 4, 20, 35, 35]),
        // Table 2 lists PDF procs = 525, but Table 1 bounds the PDF
        // calculator at 512 processes — we clamp to the space.
        (WorkflowId::Gp, Objective::ExecTime) => Config(vec![525, 35, 512, 35]),
        (WorkflowId::Gp, Objective::CompTime) => Config(vec![35, 35, 35, 35]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::Problem;

    #[test]
    fn expert_configs_valid_and_feasible() {
        for id in WorkflowId::ALL {
            for obj in Objective::ALL {
                let prob = Problem::new(id, obj);
                let cfg = expert_config(id, obj);
                assert!(
                    prob.sim.spec.validate(&cfg).is_ok(),
                    "{id}/{obj}: {cfg} invalid"
                );
                assert!(prob.sim.feasible(&cfg), "{id}/{obj}: {cfg} infeasible");
                let m = prob.sim.expected(&cfg);
                assert!(obj.value(&m) > 0.0);
            }
        }
    }
}
