//! Campaign runner: repeated tuning runs of one algorithm on one
//! (workflow, objective, budget) cell, with the paper's metrics
//! aggregated over repetitions (§7.3 runs each algorithm 100 times and
//! reports averages).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::WorkflowId;
use crate::metrics::{least_number_of_uses, mdape, mdape_top_fraction, recall_score};
use crate::sim::Objective;
use crate::surrogate::Scorer;
use crate::tuner::journal::JOURNAL_FILE;
use crate::tuner::{
    drive, drive_checkpointed, replay_into, ActiveLearning, Alph, Ceal, CealParams, Collector,
    DiagSink, FailurePolicy, FaultInjector, FaultSpec, Pool, Problem, RandomSampling,
    SessionJournal, TraceError, TraceHeader, Tuner, TunerOutput,
};
use crate::util::rng::Pcg32;
use crate::util::stats;

use super::expert::expert_config;
use super::history::{historical_samples, HIST_SAMPLES};

/// Algorithm selector (the paper's comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Rs,
    Al,
    Geist,
    Ceal,
    /// CEAL with free historical component measurements (§7.5).
    CealHist,
    Alph,
    /// ALpH with historical component measurements (§7.5.2).
    AlphHist,
}

impl Algo {
    /// Every registered algorithm, in roster order (`ceal info` and
    /// the `--algo` error message print this).
    pub const ALL: [Algo; 7] = [
        Algo::Rs,
        Algo::Al,
        Algo::Geist,
        Algo::Ceal,
        Algo::CealHist,
        Algo::Alph,
        Algo::AlphHist,
    ];

    /// Roster names, for CLI listings and error messages.
    pub fn names() -> Vec<&'static str> {
        Algo::ALL.iter().map(|a| a.name()).collect()
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Rs => "RS",
            Algo::Al => "AL",
            Algo::Geist => "GEIST",
            Algo::Ceal => "CEAL",
            Algo::CealHist => "CEAL+hist",
            Algo::Alph => "ALpH",
            Algo::AlphHist => "ALpH+hist",
        }
    }

    pub fn from_name(name: &str) -> Option<Algo> {
        match name.to_ascii_lowercase().as_str() {
            "rs" => Some(Algo::Rs),
            "al" => Some(Algo::Al),
            "geist" => Some(Algo::Geist),
            "ceal" => Some(Algo::Ceal),
            "ceal+hist" | "ceal_hist" => Some(Algo::CealHist),
            "alph" => Some(Algo::Alph),
            "alph+hist" | "alph_hist" => Some(Algo::AlphHist),
            _ => None,
        }
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which scoring backend campaign workers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScorerKind {
    Native,
    /// Load the AOT artifacts in each worker thread.
    Pjrt,
}

impl ScorerKind {
    pub fn build(&self) -> Scorer {
        match self {
            ScorerKind::Native => Scorer::Native,
            ScorerKind::Pjrt => Scorer::pjrt_or_native(),
        }
    }

    /// Stable name, round-tripped through `--scorer` and the session
    /// trace header (replay must score with the recorded backend).
    pub fn name(&self) -> &'static str {
        match self {
            ScorerKind::Native => "native",
            ScorerKind::Pjrt => "pjrt",
        }
    }

    pub fn from_name(name: &str) -> Option<ScorerKind> {
        match name.to_ascii_lowercase().as_str() {
            "native" => Some(ScorerKind::Native),
            "pjrt" => Some(ScorerKind::Pjrt),
            _ => None,
        }
    }
}

/// One campaign cell.
#[derive(Clone, Copy, Debug)]
pub struct Campaign {
    pub workflow: WorkflowId,
    pub objective: Objective,
    /// Training-sample budget m (workflow-run equivalents).
    pub m: usize,
    pub reps: usize,
    pub seed: u64,
    pub pool_size: usize,
    pub scorer: ScorerKind,
    /// Worker threads (1 = sequential).
    pub threads: usize,
    /// Override CEAL/ALpH hyper-parameters (Fig. 13 sweeps).
    pub ceal_params: Option<CealParams>,
    /// Inject deterministic measurement faults into every repetition
    /// (robustness studies).  Each rep gets its own schedule stream via
    /// [`FaultSpec::seed_for_rep`], so rep-level parallelism cannot
    /// reorder fault schedules, and sessions run with
    /// [`FailurePolicy::fault_tolerant`].
    pub faults: Option<FaultSpec>,
}

impl Campaign {
    pub fn new(workflow: WorkflowId, objective: Objective, m: usize) -> Campaign {
        Campaign {
            workflow,
            objective,
            m,
            reps: 40,
            seed: 0xCEA1,
            pool_size: crate::tuner::common::POOL_SIZE,
            scorer: ScorerKind::Native,
            threads: default_threads(),
            ceal_params: None,
            faults: None,
        }
    }

    pub fn with_reps(mut self, reps: usize) -> Campaign {
        self.reps = reps;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Campaign {
        self.seed = seed;
        self
    }

    pub fn with_pool_size(mut self, n: usize) -> Campaign {
        self.pool_size = n;
        self
    }

    pub fn with_scorer(mut self, s: ScorerKind) -> Campaign {
        self.scorer = s;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Campaign {
        self.threads = t.max(1);
        self
    }

    pub fn with_ceal_params(mut self, p: CealParams) -> Campaign {
        self.ceal_params = Some(p);
        self
    }

    pub fn with_faults(mut self, spec: FaultSpec) -> Campaign {
        self.faults = Some(spec);
        self
    }
}

/// Default campaign worker width: `CEAL_THREADS` when set, else the
/// hardware parallelism (see [`crate::util::parallel::default_threads`];
/// the CLI's `--threads` takes precedence over both).
pub fn default_threads() -> usize {
    crate::util::parallel::default_threads()
}

/// Per-repetition metrics.
#[derive(Clone, Debug)]
pub struct RepResult {
    /// Ground-truth objective value of the predicted-best config.
    pub best_value: f64,
    /// best_value normalized by the pool optimum (paper Figs. 5, 9, 10).
    pub norm_best: f64,
    /// Final-model recall at top-1..10 over the pool (Figs. 7, 11).
    pub recalls: Vec<f64>,
    /// Final-model MdAPE over all pool configs and the top 2% (Fig. 6).
    pub mdape_all: f64,
    pub mdape_top2: f64,
    /// Collection cost (Σ objective over training runs, §7.2.3),
    /// including retry/backoff charges for failed attempts.
    pub cost: f64,
    pub workflow_runs: usize,
    /// Measurement attempts that failed or timed out (0 without
    /// fault injection).
    pub failed_runs: usize,
}

/// Aggregated campaign outcome.
#[derive(Clone, Debug)]
pub struct Aggregate {
    pub algo: Algo,
    pub campaign_m: usize,
    pub workflow: WorkflowId,
    pub objective: Objective,
    pub reps: Vec<RepResult>,
    /// Pool (test-set) optimum the normalized plots divide by.
    pub pool_best: f64,
    /// Ground-truth objective of the expert configuration.
    pub expert_value: f64,
}

impl Aggregate {
    pub fn mean_best(&self) -> f64 {
        stats::mean(&self.reps.iter().map(|r| r.best_value).collect::<Vec<_>>())
    }

    pub fn mean_norm_best(&self) -> f64 {
        stats::mean(&self.reps.iter().map(|r| r.norm_best).collect::<Vec<_>>())
    }

    pub fn mean_recall(&self, n: usize) -> f64 {
        stats::mean(
            &self
                .reps
                .iter()
                .map(|r| r.recalls[n - 1])
                .collect::<Vec<_>>(),
        )
    }

    pub fn mean_mdape_all(&self) -> f64 {
        stats::mean(&self.reps.iter().map(|r| r.mdape_all).collect::<Vec<_>>())
    }

    pub fn mean_mdape_top2(&self) -> f64 {
        stats::mean(&self.reps.iter().map(|r| r.mdape_top2).collect::<Vec<_>>())
    }

    pub fn mean_cost(&self) -> f64 {
        stats::mean(&self.reps.iter().map(|r| r.cost).collect::<Vec<_>>())
    }

    /// Least number of uses (§7.2.3) from mean cost and mean tuned value.
    pub fn payoff_runs(&self) -> Option<f64> {
        least_number_of_uses(self.mean_cost(), self.expert_value, self.mean_best())
    }
}

/// Build the tuner for an algorithm (hist variants capture the shared
/// historical samples).  Public so the CLI's single-session
/// record/replay path constructs exactly the tuner a campaign cell
/// would.
pub fn tuner_for(
    algo: Algo,
    prob: &Problem,
    seed: u64,
    ceal_params: Option<CealParams>,
) -> Box<dyn Tuner> {
    match algo {
        Algo::Rs => Box::new(RandomSampling),
        Algo::Al => Box::new(ActiveLearning::default()),
        Algo::Geist => Box::new(crate::tuner::Geist::default()),
        Algo::Ceal => Box::new(Ceal::new(ceal_params.unwrap_or(CealParams::no_hist()))),
        Algo::CealHist => {
            let hist = Arc::new(historical_samples(prob, HIST_SAMPLES, seed ^ 0x415));
            Box::new(Ceal::with_historical(
                ceal_params.unwrap_or(CealParams::with_hist()),
                hist,
            ))
        }
        Algo::Alph => Box::new(Alph::new(ceal_params.unwrap_or(CealParams::no_hist()))),
        Algo::AlphHist => {
            let hist = Arc::new(historical_samples(prob, HIST_SAMPLES, seed ^ 0x415));
            Box::new(Alph::with_historical(
                ceal_params.unwrap_or(CealParams::with_hist()),
                hist,
            ))
        }
    }
}

/// The RNG stream of one repetition: (campaign seed, rep, algorithm)
/// fully determine it.  Public so the CLI's `--record`/`--replay`
/// single-session path (rep 0) reproduces campaign cells exactly.
pub fn session_rng(seed: u64, algo: Algo, rep: usize) -> Pcg32 {
    Pcg32::new(seed ^ 0xDEED, (rep as u64) << 8 | algo_stream(algo))
}

/// The checkpoint directory of one repetition under a campaign
/// checkpoint root: `<root>/<algo>-rep<NNN>` (with `+` mapped to `_`
/// so the name is shell-friendly).
pub fn rep_checkpoint_dir(root: &Path, algo: Algo, rep: usize) -> PathBuf {
    root.join(format!("{}-rep{rep:03}", algo.name().replace('+', "_")))
}

/// One uninterrupted repetition drive (the pre-checkpoint behaviour).
fn drive_rep_live(
    algo: Algo,
    tuner: &dyn Tuner,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    c: &Campaign,
    rep: usize,
) -> TunerOutput {
    let mut rng = session_rng(c.seed, algo, rep);
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut session = tuner.session(prob, pool, scorer, c.m, &mut rng);
    match &c.faults {
        Some(spec) if !spec.plan.is_none() => {
            session.set_failure_policy(FailurePolicy::fault_tolerant());
            let mut injector = FaultInjector::new(&mut col, spec.plan, spec.seed_for_rep(rep));
            drive(session, &mut injector)
        }
        _ => drive(session, &mut col),
    }
}

/// One crash-safe repetition drive: create the rep's journal in `dir`
/// (or resume it if a journal is already there) and run through
/// [`drive_checkpointed`].  The result is bit-identical to
/// [`drive_rep_live`] — the journal only adds durability.
fn drive_rep_journaled(
    algo: Algo,
    tuner: &dyn Tuner,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    c: &Campaign,
    rep: usize,
    dir: &Path,
) -> Result<TunerOutput, TraceError> {
    let (mut journal, loaded) = if dir.join(JOURNAL_FILE).exists() {
        let (journal, loaded) = SessionJournal::resume(dir)?;
        (journal, Some(loaded))
    } else {
        let header = TraceHeader {
            algo: algo.name().into(),
            workflow: c.workflow.name().into(),
            objective: c.objective.name().into(),
            m: c.m,
            pool_size: c.pool_size,
            seed: c.seed,
            scorer: c.scorer.name().into(),
            ceal_params: c.ceal_params,
            faults: c.faults,
        };
        (SessionJournal::create(dir, &header, rep)?, None)
    };
    let mut rng = session_rng(c.seed, algo, rep);
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut session = tuner.session(prob, pool, scorer, c.m, &mut rng);
    // journaled reps keep their retry/straggler warnings beside the
    // exchanges they explain, one diag.log per journal directory
    session.set_diag_sink(DiagSink::File(dir.join("diag.log")));
    let out = match &c.faults {
        Some(spec) if !spec.plan.is_none() => {
            session.set_failure_policy(FailurePolicy::fault_tolerant());
            let mut injector = FaultInjector::new(&mut col, spec.plan, spec.seed_for_rep(rep));
            if let Some(l) = &loaded {
                replay_into(session.as_mut(), &mut injector, l)?;
            }
            drive_checkpointed(session, &mut injector, &mut journal)
        }
        _ => {
            if let Some(l) = &loaded {
                replay_into(session.as_mut(), &mut col, l)?;
            }
            drive_checkpointed(session, &mut col, &mut journal)
        }
    };
    if let Some(e) = journal.error() {
        return Err(e.clone());
    }
    Ok(out)
}

/// One repetition: open an ask/tell session and drive it generically
/// against the simulator-backed collector — campaigns are just another
/// session driver now, same loop as any external embedder.  With a
/// checkpoint dir the rep journals through [`drive_rep_journaled`]; an
/// unusable checkpoint degrades to a live run with a warning, never a
/// changed result.
fn run_rep(
    algo: Algo,
    tuner: &dyn Tuner,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    c: &Campaign,
    rep: usize,
    checkpoint: Option<&Path>,
) -> RepResult {
    let out: TunerOutput = match checkpoint {
        Some(dir) => match drive_rep_journaled(algo, tuner, prob, pool, scorer, c, rep, dir) {
            Ok(out) => out,
            Err(e) => {
                eprintln!(
                    "warning: checkpoint {} unusable ({e}); rerunning rep {rep} live",
                    dir.display()
                );
                drive_rep_live(algo, tuner, prob, pool, scorer, c, rep)
            }
        },
        None => drive_rep_live(algo, tuner, prob, pool, scorer, c, rep),
    };
    // Exhaustive model-quality metrics (recalls, MdAPE, normalized
    // best) compare against the materialized test set, so they only
    // exist on eager pools; a lazy pool reports NaN for them rather
    // than forcing O(pool) simulator runs and an O(pool) prediction
    // vector.  The best-config value itself needs just one on-demand
    // truth cell either way.
    let (recalls, mdape_all, mdape_top2, norm_best) = match pool.truth_eager() {
        Some(truth) => {
            // models are log-space: exponentiate to real-scale times
            // (scored through the pool's resident code cache, so a
            // multi-rep campaign codes the pool once, not per rep)
            let preds: Vec<f64> = scorer
                .score_view(&out.model, pool.feats.workflow_view())
                .into_iter()
                .map(f64::exp)
                .collect();
            (
                (1..=10).map(|n| recall_score(n, &preds, truth)).collect(),
                mdape(truth, &preds),
                mdape_top_fraction(truth, &preds, 0.02),
                pool.truth_of(out.best_idx) / pool.best_value(),
            )
        }
        None => (vec![f64::NAN; 10], f64::NAN, f64::NAN, f64::NAN),
    };
    RepResult {
        best_value: pool.truth_of(out.best_idx),
        norm_best,
        recalls,
        mdape_all,
        mdape_top2,
        cost: out.collection_cost,
        workflow_runs: out.workflow_runs,
        failed_runs: out.failed_runs,
    }
}

fn algo_stream(algo: Algo) -> u64 {
    match algo {
        Algo::Rs => 1,
        Algo::Al => 2,
        Algo::Geist => 3,
        Algo::Ceal => 4,
        Algo::CealHist => 5,
        Algo::Alph => 6,
        Algo::AlphHist => 7,
    }
}

/// Run one algorithm's campaign cell. The pool (the paper's measured
/// test set) is deterministic in (workflow, objective, pool_size, seed)
/// and **shared by every algorithm at the same cell** through the
/// process-wide [`PoolCache`](super::PoolCache): the first algorithm to
/// reach a cell generates it (ground truth measured across this
/// campaign's worker threads), every later one reuses the same
/// `Arc<Pool>`.  Pools are immutable after generation — tuners receive
/// `&Pool` and must never mutate it; that contract is what makes the
/// sharing sound across the repetition worker threads of concurrent
/// campaigns.
pub fn run_campaign(algo: Algo, c: &Campaign) -> Aggregate {
    run_campaign_impl(algo, c, None)
}

/// [`run_campaign`] with per-repetition crash-safe journals under
/// `root` (one [`rep_checkpoint_dir`] each).  A rerun after a kill
/// resumes every finished or partial rep from its journal and produces
/// the same [`Aggregate`] bit-for-bit.
pub fn run_campaign_checkpointed(algo: Algo, c: &Campaign, root: &Path) -> Aggregate {
    run_campaign_impl(algo, c, Some(root))
}

fn run_campaign_impl(algo: Algo, c: &Campaign, ckpt: Option<&Path>) -> Aggregate {
    let prob = Problem::new(c.workflow, c.objective);
    let pool = super::poolcache::shared_pool(&prob, c.pool_size, c.seed, c.threads);
    let expert_value = c
        .objective
        .value(&prob.sim.expected(&expert_config(c.workflow, c.objective)));

    // one tuner per campaign: stateless across reps, and the hist
    // variants cache their deterministic component models internally
    let tuner = tuner_for(algo, &prob, c.seed, c.ceal_params);
    let reps: Vec<RepResult> = if c.threads <= 1 {
        let scorer = c.scorer.build();
        (0..c.reps)
            .map(|rep| {
                let dir = ckpt.map(|root| rep_checkpoint_dir(root, algo, rep));
                run_rep(algo, tuner.as_ref(), &prob, &pool, &scorer, c, rep, dir.as_deref())
            })
            .collect()
    } else {
        run_reps_parallel(algo, tuner.as_ref(), &prob, &pool, c, ckpt)
    };

    Aggregate {
        algo,
        campaign_m: c.m,
        workflow: c.workflow,
        objective: c.objective,
        // lazy pools have no exhaustive best: report NaN in the CSV
        pool_best: pool.truth_eager().map_or(f64::NAN, |_| pool.best_value()),
        expert_value,
        reps,
    }
}

std::thread_local! {
    /// Per-worker scorer cache for parallel repetitions: a PJRT client
    /// is thread-local and expensive to build, and pool workers are
    /// persistent, so each worker builds a scorer once per kind and
    /// reuses it across every repetition (and campaign) it executes.
    static REP_SCORER: std::cell::RefCell<Option<(ScorerKind, std::rc::Rc<Scorer>)>> =
        const { std::cell::RefCell::new(None) };
}

fn with_thread_scorer<R>(kind: ScorerKind, f: impl FnOnce(&Scorer) -> R) -> R {
    let scorer = REP_SCORER.with(|cache| {
        let mut cache = cache.borrow_mut();
        match &*cache {
            Some((k, s)) if *k == kind => std::rc::Rc::clone(s),
            _ => {
                let s = std::rc::Rc::new(kind.build());
                *cache = Some((kind, std::rc::Rc::clone(&s)));
                s
            }
        }
    });
    f(&scorer)
}

/// Repetitions fan out as one task each on the process-wide worker
/// pool ([`crate::util::parallel`]).  Nested use is the point: a rep's
/// own GBT training, pool scoring and batch measurements fork inner
/// jobs on the same pool, so campaigns with fewer reps than cores no
/// longer strand the remaining cores.  Each rep derives its RNG from
/// (campaign seed, rep, algo) exactly as the sequential path does, and
/// results land in per-rep slots — bit-identical for any worker count.
fn run_reps_parallel(
    algo: Algo,
    tuner: &dyn Tuner,
    prob: &Problem,
    pool: &Pool,
    c: &Campaign,
    ckpt: Option<&Path>,
) -> Vec<RepResult> {
    crate::util::parallel::map_indexed(c.threads, c.reps, |rep| {
        let dir = ckpt.map(|root| rep_checkpoint_dir(root, algo, rep));
        with_thread_scorer(c.scorer, |scorer| {
            run_rep(algo, tuner, prob, pool, scorer, c, rep, dir.as_deref())
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_campaign(algo: Algo) -> Aggregate {
        let c = Campaign::new(WorkflowId::LV, Objective::CompTime, 20)
            .with_reps(3)
            .with_pool_size(120)
            .with_threads(1);
        run_campaign(algo, &c)
    }

    #[test]
    fn campaign_produces_metrics() {
        let agg = tiny_campaign(Algo::Rs);
        assert_eq!(agg.reps.len(), 3);
        assert!(agg.mean_best() >= agg.pool_best);
        assert!(agg.mean_norm_best() >= 1.0);
        assert!(agg.mean_recall(1) >= 0.0 && agg.mean_recall(1) <= 1.0);
        assert!(agg.mean_mdape_all() >= 0.0);
        assert!(agg.expert_value > 0.0);
    }

    #[test]
    fn parallel_equals_sequential() {
        let base = Campaign::new(WorkflowId::HS, Objective::ExecTime, 15)
            .with_reps(4)
            .with_pool_size(100);
        let seq = run_campaign(Algo::Ceal, &base.with_threads(1));
        let par = run_campaign(Algo::Ceal, &base.with_threads(4));
        for (a, b) in seq.reps.iter().zip(&par.reps) {
            assert_eq!(a.best_value, b.best_value, "reps must be thread-count invariant");
            assert_eq!(a.workflow_runs, b.workflow_runs);
        }
    }

    /// Fault schedules are per-rep streams, so faulted campaigns stay
    /// bit-identical across worker counts — the thread-invariance
    /// guarantee survives fault injection.
    #[test]
    fn faulted_campaign_is_thread_invariant() {
        use crate::tuner::FaultPlan;
        let base = Campaign::new(WorkflowId::LV, Objective::CompTime, 15)
            .with_reps(4)
            .with_pool_size(100)
            .with_faults(FaultSpec {
                plan: FaultPlan::transient(0.2, 0.05),
                seed: 7,
            });
        let seq = run_campaign(Algo::Ceal, &base.with_threads(1));
        let par = run_campaign(Algo::Ceal, &base.with_threads(4));
        let mut any_failed = false;
        for (a, b) in seq.reps.iter().zip(&par.reps) {
            assert_eq!(a.best_value, b.best_value, "thread-count invariant");
            assert_eq!(a.cost, b.cost);
            assert_eq!(a.failed_runs, b.failed_runs);
            any_failed |= a.failed_runs > 0;
        }
        assert!(any_failed, "a 20% fault rate should hit at least one attempt");
    }

    #[test]
    fn all_algorithms_run() {
        for algo in [Algo::Al, Algo::Geist, Algo::Ceal, Algo::CealHist, Algo::Alph] {
            let agg = tiny_campaign(algo);
            assert_eq!(agg.reps.len(), 3, "{algo}");
            assert!(agg.mean_cost() > 0.0, "{algo}");
        }
    }

    /// Journaling a campaign changes durability, never results: the
    /// checkpointed run matches the live one bit-for-bit, and a rerun
    /// over the finished checkpoints resumes every rep from disk to
    /// the same aggregate.
    #[test]
    fn checkpointed_campaign_matches_live_and_resumes() {
        let root = std::env::temp_dir().join(format!(
            "ceal-campaign-ckpt-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let c = Campaign::new(WorkflowId::LV, Objective::CompTime, 12)
            .with_reps(2)
            .with_pool_size(80)
            .with_threads(1)
            .with_seed(0xCCC1);
        let live = run_campaign(Algo::Ceal, &c);
        let ckpt = run_campaign_checkpointed(Algo::Ceal, &c, &root);
        assert!(
            rep_checkpoint_dir(&root, Algo::Ceal, 0).join(JOURNAL_FILE).exists(),
            "each rep must leave its journal behind"
        );
        let resumed = run_campaign_checkpointed(Algo::Ceal, &c, &root);
        for ((a, b), r) in live.reps.iter().zip(&ckpt.reps).zip(&resumed.reps) {
            assert_eq!(a.best_value, b.best_value, "journaling must not change results");
            assert_eq!(a.cost, b.cost);
            assert_eq!(a.workflow_runs, b.workflow_runs);
            assert_eq!(b.best_value, r.best_value, "resume must reproduce the rep");
            assert_eq!(b.cost, r.cost);
            assert_eq!(b.workflow_runs, r.workflow_runs);
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn pool_built_once_across_algorithms() {
        use crate::coordinator::{PoolCache, PoolKey};
        use crate::tuner::Problem;
        // a seed no other test uses, so the global cache entry is ours
        let c = Campaign::new(WorkflowId::HS, Objective::CompTime, 10)
            .with_reps(2)
            .with_pool_size(60)
            .with_threads(1);
        let mut c = c;
        c.seed = 0xB111_7001;
        let key = PoolKey::for_problem(&Problem::new(c.workflow, c.objective), c.pool_size, c.seed);
        assert_eq!(PoolCache::global().hit_count(&key), None);
        run_campaign(Algo::Rs, &c);
        assert_eq!(
            PoolCache::global().hit_count(&key),
            Some(0),
            "first algorithm generates the cell"
        );
        run_campaign(Algo::Al, &c);
        run_campaign(Algo::Ceal, &c);
        assert_eq!(
            PoolCache::global().hit_count(&key),
            Some(2),
            "later algorithms at the same cell must reuse the cached pool"
        );
    }
}
