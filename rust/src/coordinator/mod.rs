//! Campaign coordination: run (algorithm × workflow × objective ×
//! budget) grids with repetitions, aggregate the paper's metrics,
//! share ground-truth pools across cells, and manage expert baselines
//! and historical component measurements.

pub mod campaign;
pub mod expert;
pub mod history;
pub mod poolcache;

pub use campaign::{
    rep_checkpoint_dir, run_campaign, run_campaign_checkpointed, session_rng, tuner_for,
    Aggregate, Algo, Campaign, RepResult, ScorerKind,
};
pub use expert::expert_config;
pub use history::historical_samples;
pub use poolcache::{shared_pool, PoolCache, PoolKey};
