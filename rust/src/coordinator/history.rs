//! Historical component measurements (paper §7.1): 500 random
//! configurations per configurable component, measured in isolation.
//! When an auto-tuner is given these, they are treated as free — the
//! paper's "component reuse across workflows" scenario (§7.5).

use crate::surrogate::lowfi::ComponentSamples;
use crate::tuner::Problem;
use crate::util::rng::Pcg32;

/// Paper's historical sample count per component.
pub const HIST_SAMPLES: usize = 500;

/// Generate `n` isolated measurements per configurable component,
/// deterministically in (problem, seed).
pub fn historical_samples(prob: &Problem, n: usize, seed: u64) -> Vec<ComponentSamples> {
    let spec = &prob.sim.spec;
    let mut out = Vec::new();
    for &comp in &spec.configurable() {
        let mut rng = Pcg32::new(seed, 0xA15C + comp as u64);
        let cs = &spec.components[comp];
        let mut samples = ComponentSamples::default();
        for _ in 0..n {
            // historical runs happened on the same <=32-node testbed
            let cfg = match prob.sim.sample_component_feasible(comp, &mut rng) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("warning: {e}; historical set truncated at {}", samples.len());
                    break;
                }
            };
            let m = prob.sim.run_component(comp, &cfg, &mut rng);
            samples.push(cs.encode(&cfg), prob.objective.value(&m));
        }
        out.push(samples);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn generates_per_component() {
        let prob = Problem::new(WorkflowId::GP, Objective::ExecTime);
        let h = historical_samples(&prob, 30, 1);
        assert_eq!(h.len(), 2); // GS + PDF configurable
        for s in &h {
            assert_eq!(s.len(), 30);
            assert!(s.y.iter().all(|&y| y > 0.0));
        }
    }

    #[test]
    fn deterministic() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let a = historical_samples(&prob, 10, 5);
        let b = historical_samples(&prob, 10, 5);
        assert_eq!(a[0].y, b[0].y);
        let c = historical_samples(&prob, 10, 6);
        assert_ne!(a[0].y, c[0].y);
    }
}
