//! Cross-campaign pool cache.
//!
//! Building a pool is the most expensive fixed cost of a campaign cell:
//! `pool_size` (paper: 2000) noise-free simulator runs just to establish
//! the ground-truth test set.  The pool is fully determined by
//! (workflow, objective, pool_size, seed) — so when an experiment suite
//! runs seven algorithms over the same cell (as every `exper/fig*.rs`
//! grid does), regenerating it per algorithm multiplies that cost by
//! seven for bit-identical results.
//!
//! [`PoolCache`] memoizes generated pools as `Arc<Pool>` keyed by
//! [`PoolKey`].  **Sharing contract:** pools are immutable after
//! generation — tuners receive `&Pool` and must never mutate it; the
//! lazily built per-`k` kNN graphs and the lazy-truth cache are the
//! only interior state (see [`Pool::knn_graph`]).  Generation routes
//! through [`Pool::try_generate_auto`]: cells at or above
//! [`crate::tuner::LAZY_POOL_MIN`] come back *lazy* (features only, no
//! up-front ground truth), smaller cells are built eagerly via the
//! parallel reference path and are thread-count invariant.
//!
//! **Memory cap:** the cache is bytes-accounted ([`Pool::approx_bytes`])
//! against a cap (default 2 GiB, `CEAL_POOL_CACHE_BYTES` env override,
//! [`PoolCache::set_cap_bytes`] for the CLI flag).  Inserting a pool
//! that pushes the total over the cap evicts least-recently-used cells
//! — never the one just requested — and counts each eviction; callers
//! holding an evicted `Arc<Pool>` keep it alive, the cache just drops
//! its reference.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::WorkflowId;
use crate::sim::Objective;
use crate::tuner::{Pool, Problem};

/// Default LRU cap: 2 GiB of pool bytes.
const DEFAULT_CAP_BYTES: usize = 2 * 1024 * 1024 * 1024;

/// Cache key for a pool cell, keyed by the workflow's *registry name*
/// (a [`WorkflowId`] is a thin alias over one) — any registered
/// workflow, built-in or user-added, caches the same way.  Valid only
/// for problems built by `Problem::new` on the default
/// [`Machine`](crate::sim::Machine):
/// pool ground truth also depends on the (publicly mutable) machine and
/// spec fields of `WorkflowSim`, which the key deliberately does not
/// capture — problems with a customized machine or spec must bypass the
/// cache via [`Pool::generate_par`] (enforced by a debug assertion in
/// [`PoolCache::get_or_generate`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PoolKey {
    pub workflow: WorkflowId,
    pub objective: Objective,
    pub pool_size: usize,
    pub seed: u64,
}

impl PoolKey {
    pub fn for_problem(prob: &Problem, pool_size: usize, seed: u64) -> PoolKey {
        PoolKey {
            workflow: prob.sim.id,
            objective: prob.objective,
            pool_size,
            seed,
        }
    }
}

/// One cell's slot: the pool is built through the `OnceLock` *outside*
/// the cache-wide map lock, so distinct cells generate concurrently, a
/// panicking generation poisons nothing (the slot just stays empty),
/// and a cell is still built at most once (`OnceLock::get_or_init`
/// blocks duplicate initializers).
#[derive(Default)]
struct Slot {
    pool: OnceLock<Arc<Pool>>,
    hits: AtomicUsize,
    /// Logical LRU timestamp (cache-wide tick at last request).
    last_used: AtomicU64,
}

/// Memoized pool store; see the module docs for the sharing contract
/// and the memory cap.
pub struct PoolCache {
    map: Mutex<HashMap<PoolKey, Arc<Slot>>>,
    /// Monotonic logical clock for LRU ordering.
    tick: AtomicU64,
    cap_bytes: AtomicUsize,
    evictions: AtomicUsize,
}

impl Default for PoolCache {
    fn default() -> PoolCache {
        PoolCache::new()
    }
}

impl PoolCache {
    pub fn new() -> PoolCache {
        let cap = std::env::var("CEAL_POOL_CACHE_BYTES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAP_BYTES);
        PoolCache {
            map: Mutex::new(HashMap::new()),
            tick: AtomicU64::new(0),
            cap_bytes: AtomicUsize::new(cap),
            evictions: AtomicUsize::new(0),
        }
    }

    /// The process-wide cache used by
    /// [`run_campaign`](crate::coordinator::run_campaign) and the
    /// experiment harness.
    pub fn global() -> &'static PoolCache {
        static GLOBAL: OnceLock<PoolCache> = OnceLock::new();
        GLOBAL.get_or_init(PoolCache::new)
    }

    /// Return the cached pool for the cell, generating (and storing) it
    /// on first request.  The map lock is only held to fetch the cell's
    /// slot; generation runs outside it.
    pub fn get_or_generate(
        &self,
        prob: &Problem,
        pool_size: usize,
        seed: u64,
        threads: usize,
    ) -> Arc<Pool> {
        debug_assert!(
            prob.sim.machine == crate::sim::Machine::default(),
            "PoolCache keys don't capture a customized Machine — use Pool::generate_par directly"
        );
        let key = PoolKey::for_problem(prob, pool_size, seed);
        let slot = self.slot(&key);
        let mut built = false;
        let pool = slot.pool.get_or_init(|| {
            built = true;
            let pool = Pool::try_generate_auto(prob, pool_size, seed, threads)
                .unwrap_or_else(|e| panic!("pool generation failed: {e}"));
            Arc::new(pool)
        });
        if !built {
            // served from cache — including racers that blocked on the
            // builder inside get_or_init
            slot.hits.fetch_add(1, Ordering::Relaxed);
        }
        let pool = Arc::clone(pool);
        self.touch(&slot);
        if built {
            self.enforce_cap(Some(&key));
        }
        pool
    }

    /// Fallible counterpart of [`get_or_generate`](Self::get_or_generate):
    /// a workflow whose space admits no feasible configuration surfaces
    /// as an `Err` instead of panicking inside the campaign (the CLI
    /// pre-flights pools through this before `run_campaign`).  On a
    /// lost publication race the duplicate build is dropped — the
    /// strict build-once guarantee stays with `get_or_generate`, whose
    /// `OnceLock` initializer blocks duplicates.
    pub fn try_get_or_generate(
        &self,
        prob: &Problem,
        pool_size: usize,
        seed: u64,
        threads: usize,
    ) -> Result<Arc<Pool>, crate::sim::InfeasibleSpace> {
        debug_assert!(
            prob.sim.machine == crate::sim::Machine::default(),
            "PoolCache keys don't capture a customized Machine — use Pool::generate_par directly"
        );
        let key = PoolKey::for_problem(prob, pool_size, seed);
        let slot = self.slot(&key);
        if let Some(pool) = slot.pool.get() {
            slot.hits.fetch_add(1, Ordering::Relaxed);
            let pool = Arc::clone(pool);
            self.touch(&slot);
            return Ok(pool);
        }
        let fresh = Arc::new(Pool::try_generate_auto(prob, pool_size, seed, threads)?);
        let pool = Arc::clone(slot.pool.get_or_init(|| fresh));
        self.touch(&slot);
        self.enforce_cap(Some(&key));
        Ok(pool)
    }

    /// How many times `key` was served from cache (None = never built).
    /// Test/diagnostic instrumentation for the "pool built exactly once
    /// per cell" invariant.
    pub fn hit_count(&self, key: &PoolKey) -> Option<usize> {
        let slot = self.map.lock().unwrap().get(key).map(Arc::clone)?;
        slot.pool.get()?;
        Some(slot.hits.load(Ordering::Relaxed))
    }

    /// Total cache hits summed over every resident cell (the CLI's
    /// end-of-run observability line; per-key counts via
    /// [`hit_count`](Self::hit_count)).
    pub fn total_hits(&self) -> usize {
        self.map
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.pool.get().is_some())
            .map(|s| s.hits.load(Ordering::Relaxed))
            .sum()
    }

    /// Number of distinct cells generated so far.
    pub fn len(&self) -> usize {
        self.map
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.pool.get().is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total approximate bytes of every resident pool.
    pub fn resident_bytes(&self) -> usize {
        self.map
            .lock()
            .unwrap()
            .values()
            .filter_map(|s| s.pool.get())
            .map(|p| p.approx_bytes())
            .sum()
    }

    /// LRU evictions performed so far (process lifetime of this cache).
    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes.load(Ordering::Relaxed)
    }

    /// Override the byte cap (CLI `--pool-cache-bytes`) and enforce it
    /// immediately.
    pub fn set_cap_bytes(&self, bytes: usize) {
        self.cap_bytes.store(bytes, Ordering::Relaxed);
        self.enforce_cap(None);
    }

    /// Drop every cached pool (memory reclamation between suites).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    fn slot(&self, key: &PoolKey) -> Arc<Slot> {
        let mut map = self.map.lock().unwrap();
        Arc::clone(map.entry(*key).or_default())
    }

    fn touch(&self, slot: &Slot) {
        let t = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        slot.last_used.store(t, Ordering::Relaxed);
    }

    /// Evict least-recently-used built cells until the resident total
    /// fits the cap.  `keep` (the cell just requested) is never
    /// evicted, so a single oversized pool stays usable — the cap
    /// bounds the *cache*, not one campaign's working set.
    fn enforce_cap(&self, keep: Option<&PoolKey>) {
        let cap = self.cap_bytes.load(Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        loop {
            let mut total = 0usize;
            let mut victim: Option<(PoolKey, u64)> = None;
            for (k, s) in map.iter() {
                if s.pool.get().is_none() {
                    continue;
                }
                total += s.pool.get().map_or(0, |p| p.approx_bytes());
                if Some(k) == keep {
                    continue;
                }
                let lu = s.last_used.load(Ordering::Relaxed);
                let older = match victim {
                    Some((_, v)) => lu < v,
                    None => true,
                };
                if older {
                    victim = Some((*k, lu));
                }
            }
            if total <= cap {
                return;
            }
            match victim.take() {
                Some((k, _)) => {
                    map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // only the protected cell remains — nothing to evict
                None => return,
            }
        }
    }
}

/// Convenience: fetch a shared pool from the process-wide cache.
pub fn shared_pool(prob: &Problem, pool_size: usize, seed: u64, threads: usize) -> Arc<Pool> {
    PoolCache::global().get_or_generate(prob, pool_size, seed, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prob() -> Problem {
        Problem::new(WorkflowId::LV, Objective::CompTime)
    }

    /// Cached pools must be indistinguishable from fresh generation —
    /// configs, ground truth (bitwise) and best index.
    #[test]
    fn pool_cache_returns_identical_pool() {
        let cache = PoolCache::new();
        let p = prob();
        let cached = cache.get_or_generate(&p, 50, 0xCAFE, 2);
        let fresh = Pool::generate(&p, 50, 0xCAFE);
        assert_eq!(cached.configs, fresh.configs);
        assert_eq!(cached.truth(), fresh.truth());
        assert_eq!(cached.best_idx(), fresh.best_idx());
    }

    #[test]
    fn second_lookup_hits_and_shares() {
        let cache = PoolCache::new();
        let p = prob();
        let key = PoolKey::for_problem(&p, 40, 7);
        assert_eq!(cache.hit_count(&key), None);
        let a = cache.get_or_generate(&p, 40, 7, 1);
        assert_eq!(cache.hit_count(&key), Some(0));
        let b = cache.get_or_generate(&p, 40, 7, 4);
        assert_eq!(cache.hit_count(&key), Some(1));
        assert!(Arc::ptr_eq(&a, &b), "hit must share the same pool");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_cells_do_not_collide() {
        let cache = PoolCache::new();
        let p = prob();
        let exec = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let a = cache.get_or_generate(&p, 30, 1, 1);
        let b = cache.get_or_generate(&exec, 30, 1, 1);
        let c = cache.get_or_generate(&p, 30, 2, 1);
        let d = cache.get_or_generate(&p, 31, 1, 1);
        assert_eq!(cache.len(), 4);
        // same configs for same (workflow, size, seed), different truth
        // per objective
        assert_eq!(a.configs, b.configs);
        assert_ne!(a.truth(), b.truth());
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(!Arc::ptr_eq(&a, &d));
        cache.clear();
        assert!(cache.is_empty());
    }

    /// LRU cap: inserting past the cap evicts the least-recently-used
    /// cell, never the one just built, and counts each eviction.
    /// Callers holding an evicted Arc keep their pool alive.
    #[test]
    fn lru_cap_evicts_oldest_cells() {
        let cache = PoolCache::new();
        let p = prob();
        let a = cache.get_or_generate(&p, 30, 1, 1);
        let one_pool = cache.resident_bytes();
        assert!(one_pool > 0);
        let _b = cache.get_or_generate(&p, 30, 2, 1);
        // cap to roughly one pool: enforcing evicts the LRU cell (seed 1)
        cache.set_cap_bytes(one_pool + one_pool / 2);
        assert_eq!(cache.evictions(), 1, "set_cap_bytes enforces immediately");
        // rebuilding seed 1 is itself protected, so seed 2 goes; then
        // inserting seed 3 (protected) evicts the rebuilt seed 1
        let a2 = cache.get_or_generate(&p, 30, 1, 1);
        assert!(Arc::ptr_eq(&a, &a2) || a.configs == a2.configs);
        let c = cache.get_or_generate(&p, 30, 3, 1);
        assert!(cache.evictions() >= 2);
        assert!(cache.resident_bytes() <= cache.cap_bytes() || cache.len() == 1);
        // the freshly built pool must still be resident
        let key3 = PoolKey::for_problem(&p, 30, 3);
        assert!(cache.hit_count(&key3).is_some());
        drop(c);
        // evicted pools stay usable through outstanding Arcs
        assert_eq!(a.len(), 30);
    }

    /// Large cells generate lazily through the cache: no materialized
    /// truth, memory bounded by the feature side.
    #[test]
    fn auto_lazy_above_threshold() {
        let cache = PoolCache::new();
        let p = prob();
        let small = cache.try_get_or_generate(&p, 50, 9, 1).unwrap();
        assert!(!small.is_lazy());
        let big = cache
            .try_get_or_generate(&p, crate::tuner::LAZY_POOL_MIN, 9, 1)
            .unwrap();
        assert!(big.is_lazy());
        assert!(big.truth_eager().is_none());
        assert_eq!(big.len(), crate::tuner::LAZY_POOL_MIN);
    }
}
