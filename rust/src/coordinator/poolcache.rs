//! Cross-campaign pool cache.
//!
//! Building a pool is the most expensive fixed cost of a campaign cell:
//! `pool_size` (paper: 2000) noise-free simulator runs just to establish
//! the ground-truth test set.  The pool is fully determined by
//! (workflow, objective, pool_size, seed) — so when an experiment suite
//! runs seven algorithms over the same cell (as every `exper/fig*.rs`
//! grid does), regenerating it per algorithm multiplies that cost by
//! seven for bit-identical results.
//!
//! [`PoolCache`] memoizes generated pools as `Arc<Pool>` keyed by
//! [`PoolKey`].  **Sharing contract:** pools are immutable after
//! generation — tuners receive `&Pool` and must never mutate it; the
//! lazily built per-`k` kNN graphs are the only interior state (see
//! [`Pool::knn_graph`]).  Ground-truth measurement inside a miss is
//! parallelized across the requesting campaign's worker threads via
//! [`Pool::generate_par`] and is thread-count invariant.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::WorkflowId;
use crate::sim::Objective;
use crate::tuner::{Pool, Problem};

/// Cache key for a pool cell, keyed by the workflow's *registry name*
/// (a [`WorkflowId`] is a thin alias over one) — any registered
/// workflow, built-in or user-added, caches the same way.  Valid only
/// for problems built by `Problem::new` on the default
/// [`Machine`](crate::sim::Machine):
/// pool ground truth also depends on the (publicly mutable) machine and
/// spec fields of `WorkflowSim`, which the key deliberately does not
/// capture — problems with a customized machine or spec must bypass the
/// cache via [`Pool::generate_par`] (enforced by a debug assertion in
/// [`PoolCache::get_or_generate`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PoolKey {
    pub workflow: WorkflowId,
    pub objective: Objective,
    pub pool_size: usize,
    pub seed: u64,
}

impl PoolKey {
    pub fn for_problem(prob: &Problem, pool_size: usize, seed: u64) -> PoolKey {
        PoolKey {
            workflow: prob.sim.id,
            objective: prob.objective,
            pool_size,
            seed,
        }
    }
}

/// One cell's slot: the pool is built through the `OnceLock` *outside*
/// the cache-wide map lock, so distinct cells generate concurrently, a
/// panicking generation poisons nothing (the slot just stays empty),
/// and a cell is still built at most once (`OnceLock::get_or_init`
/// blocks duplicate initializers).
#[derive(Default)]
struct Slot {
    pool: OnceLock<Arc<Pool>>,
    hits: AtomicUsize,
}

/// Memoized pool store; see the module docs for the sharing contract.
#[derive(Default)]
pub struct PoolCache {
    map: Mutex<HashMap<PoolKey, Arc<Slot>>>,
}

impl PoolCache {
    pub fn new() -> PoolCache {
        PoolCache::default()
    }

    /// The process-wide cache used by
    /// [`run_campaign`](crate::coordinator::run_campaign) and the
    /// experiment harness.
    pub fn global() -> &'static PoolCache {
        static GLOBAL: OnceLock<PoolCache> = OnceLock::new();
        GLOBAL.get_or_init(PoolCache::new)
    }

    /// Return the cached pool for the cell, generating (and storing) it
    /// on first request.  The map lock is only held to fetch the cell's
    /// slot; generation runs outside it.
    pub fn get_or_generate(
        &self,
        prob: &Problem,
        pool_size: usize,
        seed: u64,
        threads: usize,
    ) -> Arc<Pool> {
        debug_assert!(
            prob.sim.machine == crate::sim::Machine::default(),
            "PoolCache keys don't capture a customized Machine — use Pool::generate_par directly"
        );
        let key = PoolKey::for_problem(prob, pool_size, seed);
        let slot = {
            let mut map = self.map.lock().unwrap();
            Arc::clone(map.entry(key).or_default())
        };
        let mut built = false;
        let pool = slot.pool.get_or_init(|| {
            built = true;
            Arc::new(Pool::generate_par(prob, pool_size, seed, threads))
        });
        if !built {
            // served from cache — including racers that blocked on the
            // builder inside get_or_init
            slot.hits.fetch_add(1, Ordering::Relaxed);
        }
        Arc::clone(pool)
    }

    /// Fallible counterpart of [`get_or_generate`](Self::get_or_generate):
    /// a workflow whose space admits no feasible configuration surfaces
    /// as an `Err` instead of panicking inside the campaign (the CLI
    /// pre-flights pools through this before `run_campaign`).  On a
    /// lost publication race the duplicate build is dropped — the
    /// strict build-once guarantee stays with `get_or_generate`, whose
    /// `OnceLock` initializer blocks duplicates.
    pub fn try_get_or_generate(
        &self,
        prob: &Problem,
        pool_size: usize,
        seed: u64,
        threads: usize,
    ) -> Result<Arc<Pool>, crate::sim::InfeasibleSpace> {
        debug_assert!(
            prob.sim.machine == crate::sim::Machine::default(),
            "PoolCache keys don't capture a customized Machine — use Pool::generate_par directly"
        );
        let key = PoolKey::for_problem(prob, pool_size, seed);
        let slot = {
            let mut map = self.map.lock().unwrap();
            Arc::clone(map.entry(key).or_default())
        };
        if let Some(pool) = slot.pool.get() {
            slot.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(pool));
        }
        let fresh = Arc::new(Pool::try_generate_par(prob, pool_size, seed, threads)?);
        Ok(Arc::clone(slot.pool.get_or_init(|| fresh)))
    }

    /// How many times `key` was served from cache (None = never built).
    /// Test/diagnostic instrumentation for the "pool built exactly once
    /// per cell" invariant.
    pub fn hit_count(&self, key: &PoolKey) -> Option<usize> {
        let slot = self.map.lock().unwrap().get(key).map(Arc::clone)?;
        slot.pool.get()?;
        Some(slot.hits.load(Ordering::Relaxed))
    }

    /// Number of distinct cells generated so far.
    pub fn len(&self) -> usize {
        self.map
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.pool.get().is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached pool (memory reclamation between suites).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

/// Convenience: fetch a shared pool from the process-wide cache.
pub fn shared_pool(prob: &Problem, pool_size: usize, seed: u64, threads: usize) -> Arc<Pool> {
    PoolCache::global().get_or_generate(prob, pool_size, seed, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prob() -> Problem {
        Problem::new(WorkflowId::LV, Objective::CompTime)
    }

    /// Cached pools must be indistinguishable from fresh generation —
    /// configs, ground truth (bitwise) and best index.
    #[test]
    fn pool_cache_returns_identical_pool() {
        let cache = PoolCache::new();
        let p = prob();
        let cached = cache.get_or_generate(&p, 50, 0xCAFE, 2);
        let fresh = Pool::generate(&p, 50, 0xCAFE);
        assert_eq!(cached.configs, fresh.configs);
        assert_eq!(cached.truth, fresh.truth);
        assert_eq!(cached.best_idx, fresh.best_idx);
    }

    #[test]
    fn second_lookup_hits_and_shares() {
        let cache = PoolCache::new();
        let p = prob();
        let key = PoolKey::for_problem(&p, 40, 7);
        assert_eq!(cache.hit_count(&key), None);
        let a = cache.get_or_generate(&p, 40, 7, 1);
        assert_eq!(cache.hit_count(&key), Some(0));
        let b = cache.get_or_generate(&p, 40, 7, 4);
        assert_eq!(cache.hit_count(&key), Some(1));
        assert!(Arc::ptr_eq(&a, &b), "hit must share the same pool");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_cells_do_not_collide() {
        let cache = PoolCache::new();
        let p = prob();
        let exec = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let a = cache.get_or_generate(&p, 30, 1, 1);
        let b = cache.get_or_generate(&exec, 30, 1, 1);
        let c = cache.get_or_generate(&p, 30, 2, 1);
        let d = cache.get_or_generate(&p, 31, 1, 1);
        assert_eq!(cache.len(), 4);
        // same configs for same (workflow, size, seed), different truth
        // per objective
        assert_eq!(a.configs, b.configs);
        assert_ne!(a.truth, b.truth);
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(!Arc::ptr_eq(&a, &d));
        cache.clear();
        assert!(cache.is_empty());
    }
}
