//! The paper's three workflows (Table 1): LV, HS and GP parameter
//! spaces, exactly as published.
//!
//! These spaces are the Table-1 *data* only — **one registry instance
//! among several**.  Workflow identity, topology, profiles and
//! everything behavioural live in the declarative tables under
//! [`crate::sim::defs`], which zip these specs with profile/allocation
//! rules and register them in the process-wide
//! [`WorkflowRegistry`](crate::sim::WorkflowRegistry) next to the
//! synthetic scenario families (CH5, DM4).  [`WorkflowId`] is a thin
//! alias over a registered name; resolving one goes through the
//! registry, never through a hardcoded branch.
//!
//! | Wf | Component   | Parameters                                        |
//! |----|-------------|---------------------------------------------------|
//! | LV | LAMMPS      | procs 2..1085, ppn 1..35, tpp 1..4, io 50..400/50 |
//! |    | Voro++      | procs 2..1085, ppn 1..35, tpp 1..4                |
//! | HS | HeatTransfer| px 2..32, py 2..32, ppn 1..35, writes 4..32/4,    |
//! |    |             | buffer 1..40 MB                                   |
//! |    | StageWrite  | procs 2..1085, ppn 1..35                          |
//! | GP | GrayScott   | procs 2..1085, ppn 1..35                          |
//! |    | PDFcalc     | procs 1..512, ppn 1..35                           |
//! |    | G-Plot      | (fixed, 1 proc)                                   |
//! |    | P-Plot      | (fixed, 1 proc)                                   |

use super::param::ParamDef;
use super::space::{ComponentSpec, WorkflowSpec};

pub use crate::sim::registry::WorkflowId;

/// LV: LAMMPS molecular dynamics + Voro++ tesselation via staging.
pub fn lv_spec() -> WorkflowSpec {
    WorkflowSpec::new(
        "LV",
        vec![
            ComponentSpec::new(
                "LAMMPS",
                vec![
                    ParamDef::range("procs", 2, 1085),
                    ParamDef::range("ppn", 1, 35),
                    ParamDef::range("tpp", 1, 4),
                    ParamDef::range_step("io_steps", 50, 400, 50),
                ],
            ),
            ComponentSpec::new(
                "Voro++",
                vec![
                    ParamDef::range("procs", 2, 1085),
                    ParamDef::range("ppn", 1, 35),
                    ParamDef::range("tpp", 1, 4),
                ],
            ),
        ],
    )
}

/// HS: Heat Transfer mini-app + Stage Write I/O forwarder.
pub fn hs_spec() -> WorkflowSpec {
    WorkflowSpec::new(
        "HS",
        vec![
            ComponentSpec::new(
                "HeatTransfer",
                vec![
                    ParamDef::range("px", 2, 32),
                    ParamDef::range("py", 2, 32),
                    ParamDef::range("ppn", 1, 35),
                    ParamDef::range_step("io_writes", 4, 32, 4),
                    ParamDef::range("buffer_mb", 1, 40),
                ],
            ),
            ComponentSpec::new(
                "StageWrite",
                vec![ParamDef::range("procs", 2, 1085), ParamDef::range("ppn", 1, 35)],
            ),
        ],
    )
}

/// GP: Gray-Scott reaction-diffusion + PDF calculator + two fixed
/// single-process plotters.
pub fn gp_spec() -> WorkflowSpec {
    WorkflowSpec::new(
        "GP",
        vec![
            ComponentSpec::new(
                "GrayScott",
                vec![ParamDef::range("procs", 2, 1085), ParamDef::range("ppn", 1, 35)],
            ),
            ComponentSpec::new(
                "PDFcalc",
                vec![ParamDef::range("procs", 1, 512), ParamDef::range("ppn", 1, 35)],
            ),
            ComponentSpec::new("G-Plot", vec![]),
            ComponentSpec::new("P-Plot", vec![]),
        ],
    )
}

/// Look up any *registered* workflow's spec by name (LV / HS / GP /
/// CH5 / DM4 / anything registered later), via the registry.
pub fn spec_by_name(name: &str) -> Option<WorkflowSpec> {
    WorkflowId::from_name(name).map(|id| id.spec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lv_space_size_order_of_magnitude() {
        // Paper: 2.3e10 joint (LAMMPS 6.1e5, Voro 7.6e4). Our literal
        // Table 1 reading gives the same order of magnitude.
        let s = lv_spec();
        let lammps = s.components[0].space_size() as f64;
        let voro = s.components[1].space_size() as f64;
        assert!(lammps > 1e5 && lammps < 2e6, "LAMMPS {lammps}");
        assert!(voro > 5e4 && voro < 5e5, "Voro {voro}");
        let joint = s.space_size() as f64;
        assert!(joint > 1e10 && joint < 1e12, "joint {joint}");
    }

    #[test]
    fn hs_space_size() {
        let s = hs_spec();
        let heat = s.components[0].space_size() as f64;
        assert!(heat > 1e6 && heat < 2e7, "Heat {heat}"); // paper 5.4e6
        let stage = s.components[1].space_size() as f64;
        assert!(stage > 1e4 && stage < 1e5, "Stage {stage}"); // paper 1.9e4
    }

    #[test]
    fn gp_space_and_configurables() {
        let s = gp_spec();
        assert_eq!(s.configurable(), vec![0, 1]);
        let gs = s.components[0].space_size() as f64;
        let pdf = s.components[1].space_size() as f64;
        assert!(gs > 1e4 && gs < 1e5); // paper 1.9e4 (procs*ppn = 37940)
        assert!(pdf > 9e3 && pdf < 2e4); // paper 9.0e3
        assert_eq!(s.components[2].space_size(), 1);
        // joint ~ 8.5e7 in the paper (feasible counting); literal product:
        let joint = s.space_size() as f64;
        assert!(joint > 1e8 && joint < 1e10, "joint {joint}");
    }

    #[test]
    fn expert_configs_are_admissible() {
        // Table 2 expert rows must validate against our spaces.
        use crate::config::space::Config;
        let lv = lv_spec();
        assert!(lv
            .validate(&Config(vec![288, 18, 2, 400, 288, 18, 2]))
            .is_ok());
        let hs = hs_spec();
        assert!(hs.validate(&Config(vec![32, 17, 34, 4, 20, 560, 35])).is_ok());
        let gp = gp_spec();
        assert!(gp.validate(&Config(vec![35, 35, 35, 35])).is_ok());
    }

    #[test]
    fn names_resolve_through_the_registry() {
        for id in WorkflowId::ALL {
            assert_eq!(WorkflowId::from_name(id.name()), Some(id));
            // specs resolve through the registry, matching the Table 1
            // data above for the paper trio
            assert_eq!(spec_by_name(id.name()).unwrap().name, id.name());
        }
        assert_eq!(WorkflowId::from_name("lv"), Some(WorkflowId::LV));
        assert_eq!(WorkflowId::from_name("zz"), None);
        // registered synthetic scenarios resolve too
        assert!(spec_by_name("CH5").is_some());
        assert!(spec_by_name("dm4").is_some());
    }
}
