//! A single configuration parameter: a named, ordered, finite set of
//! integer values (all Table 1 parameters are integer-valued).

use crate::util::rng::Pcg32;

/// The value domain of a parameter.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValues {
    /// `lo, lo+step, ..., <= hi` (inclusive arithmetic progression).
    Range { lo: i64, hi: i64, step: i64 },
    /// Explicit value list (ordered).
    List(Vec<i64>),
}

/// A named parameter definition.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub values: ParamValues,
}

impl ParamDef {
    pub fn range(name: &str, lo: i64, hi: i64) -> Self {
        ParamDef::range_step(name, lo, hi, 1)
    }

    pub fn range_step(name: &str, lo: i64, hi: i64, step: i64) -> Self {
        assert!(step > 0 && hi >= lo, "bad range for {name}");
        ParamDef {
            name: name.to_string(),
            values: ParamValues::Range { lo, hi, step },
        }
    }

    pub fn list(name: &str, values: &[i64]) -> Self {
        assert!(!values.is_empty(), "empty list for {name}");
        ParamDef {
            name: name.to_string(),
            values: ParamValues::List(values.to_vec()),
        }
    }

    /// Number of admissible values.
    pub fn count(&self) -> u64 {
        match &self.values {
            ParamValues::Range { lo, hi, step } => ((hi - lo) / step + 1) as u64,
            ParamValues::List(v) => v.len() as u64,
        }
    }

    /// The `idx`-th value (0-based, ordered).
    pub fn value_at(&self, idx: u64) -> i64 {
        debug_assert!(idx < self.count(), "{}: index {idx} out of range", self.name);
        match &self.values {
            ParamValues::Range { lo, step, .. } => lo + step * idx as i64,
            ParamValues::List(v) => v[idx as usize],
        }
    }

    /// Index of `value`; None if not admissible.
    pub fn index_of(&self, value: i64) -> Option<u64> {
        match &self.values {
            ParamValues::Range { lo, hi, step } => {
                if value < *lo || value > *hi || (value - lo) % step != 0 {
                    None
                } else {
                    Some(((value - lo) / step) as u64)
                }
            }
            ParamValues::List(v) => v.iter().position(|&x| x == value).map(|i| i as u64),
        }
    }

    /// Lowest / highest admissible value.
    pub fn min(&self) -> i64 {
        self.value_at(0)
    }

    pub fn max(&self) -> i64 {
        self.value_at(self.count() - 1)
    }

    /// Uniform random admissible value.
    pub fn sample(&self, rng: &mut Pcg32) -> i64 {
        self.value_at(rng.gen_range(self.count()))
    }

    /// Normalize a value to [0, 1] by index position (robust to uneven
    /// spacing in `List` domains).
    pub fn normalize(&self, value: i64) -> f32 {
        let idx = self
            .index_of(value)
            .unwrap_or_else(|| panic!("{}: value {value} not admissible", self.name));
        let n = self.count();
        if n <= 1 {
            0.0
        } else {
            idx as f32 / (n - 1) as f32
        }
    }

    /// Admissible values adjacent to `value` (±1 index) — the edges of
    /// GEIST's parameter graph along this axis.
    pub fn neighbors(&self, value: i64) -> Vec<i64> {
        let idx = match self.index_of(value) {
            Some(i) => i,
            None => return vec![],
        };
        let mut out = Vec::with_capacity(2);
        if idx > 0 {
            out.push(self.value_at(idx - 1));
        }
        if idx + 1 < self.count() {
            out.push(self.value_at(idx + 1));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_counting() {
        let p = ParamDef::range("procs", 2, 1085);
        assert_eq!(p.count(), 1084);
        assert_eq!(p.value_at(0), 2);
        assert_eq!(p.value_at(1083), 1085);
        assert_eq!(p.index_of(2), Some(0));
        assert_eq!(p.index_of(1086), None);
    }

    #[test]
    fn stepped_range() {
        let p = ParamDef::range_step("io", 50, 400, 50);
        assert_eq!(p.count(), 8);
        assert_eq!(p.value_at(7), 400);
        assert_eq!(p.index_of(150), Some(2));
        assert_eq!(p.index_of(151), None);
    }

    #[test]
    fn list_domain() {
        let p = ParamDef::list("tpp", &[1, 2, 3, 4]);
        assert_eq!(p.count(), 4);
        assert_eq!(p.index_of(3), Some(2));
        assert_eq!(p.min(), 1);
        assert_eq!(p.max(), 4);
    }

    #[test]
    fn normalize_bounds() {
        let p = ParamDef::range("x", 10, 20);
        assert_eq!(p.normalize(10), 0.0);
        assert_eq!(p.normalize(20), 1.0);
        let single = ParamDef::list("one", &[7]);
        assert_eq!(single.normalize(7), 0.0);
    }

    #[test]
    fn neighbors_at_edges() {
        let p = ParamDef::range_step("io", 50, 400, 50);
        assert_eq!(p.neighbors(50), vec![100]);
        assert_eq!(p.neighbors(400), vec![350]);
        assert_eq!(p.neighbors(200), vec![150, 250]);
        assert!(p.neighbors(123).is_empty());
    }

    #[test]
    fn sampling_is_admissible() {
        let p = ParamDef::range_step("io", 50, 400, 50);
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..200 {
            let v = p.sample(&mut rng);
            assert!(p.index_of(v).is_some());
        }
    }
}
