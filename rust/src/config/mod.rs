//! Configuration-space machinery: typed parameter definitions,
//! per-component and joint workflow spaces (paper Table 1), feature
//! encoding for the surrogate models, feasibility filtering, and
//! neighbor enumeration (GEIST's parameter graph).

pub mod param;
pub mod space;
pub mod spaces;

pub use param::{ParamDef, ParamValues};
pub use space::{ComponentSpec, Config, InfeasibleSpace, WorkflowSpec, F_MAX};
pub use spaces::{gp_spec, hs_spec, lv_spec, spec_by_name, WorkflowId};
