//! Component and workflow configuration spaces, and the feature
//! encodings consumed by the surrogate models and the AOT artifacts.

use super::param::ParamDef;
use crate::util::rng::Pcg32;

/// Feature-vector width baked into the AOT artifacts
/// (`python/compile/kernels/gbt_predict.py::F_MAX`). Every Table 1 view
/// (whole workflow or single component) has <= 8 parameters.
pub const F_MAX: usize = 8;

/// A concrete joint configuration: one value per workflow parameter, in
/// spec order (all components concatenated).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Config(pub Vec<i64>);

impl Config {
    pub fn values(&self) -> &[i64] {
        &self.0
    }
}

impl std::fmt::Display for Config {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(")?;
        for (i, v) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, ")")
    }
}

/// A feasibility rejection-sampler exhausted its budget: the scope's
/// slice of the configuration space admits no runnable configuration
/// under the active filter (typically the machine's allocation cap).
/// Registered workflows can legitimately have tight feasibility, so
/// both the joint sampler ([`WorkflowSpec::try_sample_feasible`]) and
/// the per-component sampler
/// ([`WorkflowSim::sample_component_feasible`]) surface this one
/// matchable error instead of panicking deep inside a campaign.
///
/// [`WorkflowSim::sample_component_feasible`]: crate::sim::WorkflowSim::sample_component_feasible
#[derive(Clone, Debug)]
pub struct InfeasibleSpace {
    /// The workflow (space) being sampled.
    pub workflow: String,
    /// What was being sampled ("component 2 (Feature)" or "joint space").
    pub scope: String,
    pub tries: usize,
}

impl std::fmt::Display for InfeasibleSpace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: no feasible configuration for {} in {} draws",
            self.workflow, self.scope, self.tries
        )
    }
}

impl std::error::Error for InfeasibleSpace {}

/// One component application's configurable view.
#[derive(Clone, Debug)]
pub struct ComponentSpec {
    pub name: String,
    pub params: Vec<ParamDef>,
}

impl ComponentSpec {
    pub fn new(name: &str, params: Vec<ParamDef>) -> Self {
        assert!(
            params.len() <= F_MAX,
            "{name}: {} params exceed F_MAX={F_MAX}",
            params.len()
        );
        ComponentSpec {
            name: name.to_string(),
            params,
        }
    }

    /// Size of this component's own configuration space.
    pub fn space_size(&self) -> u64 {
        self.params.iter().map(|p| p.count()).product::<u64>().max(1)
    }

    /// Whether this component exposes tunable parameters at all
    /// (G-Plot / P-Plot in GP do not).
    pub fn is_configurable(&self) -> bool {
        !self.params.is_empty()
    }

    /// Sample a component-local configuration.
    pub fn sample(&self, rng: &mut Pcg32) -> Vec<i64> {
        self.params.iter().map(|p| p.sample(rng)).collect()
    }

    /// Normalize a component-local configuration into an F_MAX-wide
    /// padded feature vector.
    pub fn encode(&self, values: &[i64]) -> [f32; F_MAX] {
        assert_eq!(values.len(), self.params.len(), "{}: arity", self.name);
        let mut out = [0.0f32; F_MAX];
        for (i, (p, &v)) in self.params.iter().zip(values).enumerate() {
            out[i] = p.normalize(v);
        }
        out
    }
}

/// A workflow: ordered components whose parameter lists concatenate into
/// the joint configuration vector.
#[derive(Clone, Debug)]
pub struct WorkflowSpec {
    pub name: String,
    pub components: Vec<ComponentSpec>,
}

impl WorkflowSpec {
    pub fn new(name: &str, components: Vec<ComponentSpec>) -> Self {
        let total: usize = components.iter().map(|c| c.params.len()).sum();
        assert!(
            total <= F_MAX,
            "{name}: joint parameter count {total} exceeds F_MAX={F_MAX}"
        );
        WorkflowSpec {
            name: name.to_string(),
            components,
        }
    }

    /// All parameters, flattened in component order.
    pub fn params(&self) -> Vec<&ParamDef> {
        self.components.iter().flat_map(|c| &c.params).collect()
    }

    pub fn n_params(&self) -> usize {
        self.components.iter().map(|c| c.params.len()).sum()
    }

    /// Joint configuration-space size (Table 1 caption numbers).
    pub fn space_size(&self) -> u64 {
        self.components.iter().map(|c| c.space_size()).product()
    }

    /// Indices of configurable components.
    pub fn configurable(&self) -> Vec<usize> {
        self.components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_configurable())
            .map(|(i, _)| i)
            .collect()
    }

    /// Start offset of component `j`'s parameters in the joint vector.
    pub fn component_offset(&self, j: usize) -> usize {
        self.components[..j].iter().map(|c| c.params.len()).sum()
    }

    /// Component `j`'s slice of a joint configuration.
    pub fn component_slice<'a>(&self, cfg: &'a Config, j: usize) -> &'a [i64] {
        let off = self.component_offset(j);
        &cfg.0[off..off + self.components[j].params.len()]
    }

    /// Uniform random joint configuration (no feasibility filter).
    pub fn sample(&self, rng: &mut Pcg32) -> Config {
        Config(
            self.components
                .iter()
                .flat_map(|c| c.sample(rng))
                .collect(),
        )
    }

    /// Rejection-sample a configuration satisfying `feasible` (the
    /// paper's pools contain only runnable <= 32-node configs).
    /// Errors after `max_tries` rejections — a sign the filter is
    /// inconsistent with the space (registered workflows can have
    /// arbitrarily tight feasibility).
    pub fn try_sample_feasible(
        &self,
        rng: &mut Pcg32,
        feasible: &dyn Fn(&Config) -> bool,
        max_tries: usize,
    ) -> Result<Config, InfeasibleSpace> {
        for _ in 0..max_tries {
            let c = self.sample(rng);
            if feasible(&c) {
                return Ok(c);
            }
        }
        Err(InfeasibleSpace {
            workflow: self.name.clone(),
            scope: "joint space".to_string(),
            tries: max_tries,
        })
    }

    /// [`try_sample_feasible`](Self::try_sample_feasible), panicking on
    /// exhaustion (legacy convenience for callers with known-good
    /// spaces).
    pub fn sample_feasible(
        &self,
        rng: &mut Pcg32,
        feasible: &dyn Fn(&Config) -> bool,
        max_tries: usize,
    ) -> Config {
        self.try_sample_feasible(rng, feasible, max_tries)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Validate that every value in `cfg` is admissible.
    pub fn validate(&self, cfg: &Config) -> Result<(), String> {
        let params = self.params();
        if cfg.0.len() != params.len() {
            return Err(format!(
                "{}: config arity {} != {}",
                self.name,
                cfg.0.len(),
                params.len()
            ));
        }
        for (p, &v) in params.iter().zip(&cfg.0) {
            if p.index_of(v).is_none() {
                return Err(format!("{}: {}={} not admissible", self.name, p.name, v));
            }
        }
        Ok(())
    }

    /// Whole-workflow feature encoding: all params normalized, padded to
    /// F_MAX (the high-fidelity model's view).
    pub fn encode_workflow(&self, cfg: &Config) -> [f32; F_MAX] {
        let mut out = [0.0f32; F_MAX];
        for (i, (p, &v)) in self.params().iter().zip(&cfg.0).enumerate() {
            out[i] = p.normalize(v);
        }
        out
    }

    /// Component `j`'s feature encoding of a joint configuration.
    pub fn encode_component(&self, cfg: &Config, j: usize) -> [f32; F_MAX] {
        self.components[j].encode(self.component_slice(cfg, j))
    }

    /// All joint configurations that differ from `cfg` by one step of
    /// one parameter — GEIST's parameter-graph edges.
    pub fn neighbors(&self, cfg: &Config) -> Vec<Config> {
        let params = self.params();
        let mut out = Vec::new();
        for (i, p) in params.iter().enumerate() {
            for nv in p.neighbors(cfg.0[i]) {
                let mut c = cfg.0.clone();
                c[i] = nv;
                out.push(Config(c));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::param::ParamDef;

    fn toy_spec() -> WorkflowSpec {
        WorkflowSpec::new(
            "toy",
            vec![
                ComponentSpec::new(
                    "simu",
                    vec![ParamDef::range("p", 1, 4), ParamDef::list("t", &[1, 2, 4])],
                ),
                ComponentSpec::new("anal", vec![ParamDef::range("q", 1, 5)]),
                ComponentSpec::new("plot", vec![]),
            ],
        )
    }

    #[test]
    fn sizes_and_offsets() {
        let s = toy_spec();
        assert_eq!(s.space_size(), 4 * 3 * 5);
        assert_eq!(s.n_params(), 3);
        assert_eq!(s.component_offset(0), 0);
        assert_eq!(s.component_offset(1), 2);
        assert_eq!(s.component_offset(2), 3);
        assert_eq!(s.configurable(), vec![0, 1]);
    }

    #[test]
    fn slices_and_encoding() {
        let s = toy_spec();
        let c = Config(vec![2, 4, 3]);
        assert_eq!(s.component_slice(&c, 0), &[2, 4]);
        assert_eq!(s.component_slice(&c, 1), &[3]);
        let enc = s.encode_workflow(&c);
        assert!((enc[0] - 1.0 / 3.0).abs() < 1e-6);
        assert_eq!(enc[1], 1.0); // t=4 is index 2 of 3
        assert_eq!(enc[2], 0.5);
        assert_eq!(enc[3], 0.0); // padding
        let enc1 = s.encode_component(&c, 1);
        assert_eq!(enc1[0], 0.5);
        assert_eq!(enc1[1], 0.0);
    }

    #[test]
    fn validation() {
        let s = toy_spec();
        assert!(s.validate(&Config(vec![2, 4, 3])).is_ok());
        assert!(s.validate(&Config(vec![2, 3, 3])).is_err()); // t=3 not in list
        assert!(s.validate(&Config(vec![2, 4])).is_err()); // arity
    }

    #[test]
    fn sampling_feasible() {
        let s = toy_spec();
        let mut rng = Pcg32::new(2, 2);
        let c = s.sample_feasible(&mut rng, &|c: &Config| c.0[0] >= 3, 1000);
        assert!(c.0[0] >= 3);
        assert!(s.validate(&c).is_ok());
    }

    #[test]
    #[should_panic(expected = "no feasible configuration")]
    fn infeasible_filter_panics() {
        let s = toy_spec();
        let mut rng = Pcg32::new(2, 2);
        s.sample_feasible(&mut rng, &|_| false, 50);
    }

    #[test]
    fn neighbors_change_one_param() {
        let s = toy_spec();
        let c = Config(vec![2, 2, 1]);
        let ns = s.neighbors(&c);
        // p: 1,3; t: 1,4; q: 2 -> 5 neighbors
        assert_eq!(ns.len(), 5);
        for n in &ns {
            let diff = n.0.iter().zip(&c.0).filter(|(a, b)| a != b).count();
            assert_eq!(diff, 1);
            assert!(s.validate(n).is_ok());
        }
    }
}
