//! The xla-backed PJRT runtime (cargo feature `pjrt`).
//!
//! Compiles the three HLO-text artifacts once at load; scoring then
//! runs with no Python anywhere.  One `Runtime` per thread — the
//! underlying PJRT client is not shared across threads.

use std::path::Path;

use super::{artifacts_dir, Error, Meta, Result};
use crate::config::F_MAX;
use crate::gbt::{FlatEnsemble, DEPTH_MAX, LEAVES_MAX, TREES_MAX};

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(format!("xla: {e}"))
    }
}

/// A loaded, compiled PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    exec_pool: xla::PjRtLoadedExecutable,
    exec_small: xla::PjRtLoadedExecutable,
    exec_lowfi: xla::PjRtLoadedExecutable,
    pub meta: Meta,
}

impl Runtime {
    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&artifacts_dir())
    }

    /// Load and compile all artifacts under `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let meta_path = dir.join("meta.json");
        let meta_text = std::fs::read_to_string(&meta_path).map_err(|e| {
            Error::msg(format!(
                "reading {} (run `make artifacts`): {e}",
                meta_path.display()
            ))
        })?;
        let meta = Meta::parse(&meta_text)?;
        meta.validate()?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::msg(format!("creating PJRT CPU client: {e}")))?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::msg(format!("parsing {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| Error::msg(format!("compiling {}: {e}", path.display())))
        };
        Ok(Runtime {
            exec_pool: compile("ensemble_predict.hlo.txt")?,
            exec_small: compile("ensemble_predict_small.hlo.txt")?,
            exec_lowfi: compile("lowfi_score.hlo.txt")?,
            meta,
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Score `xs` with one flattened ensemble via the AOT kernel.
    /// Batches larger than the pool artifact are processed in slabs.
    pub fn score(&self, ens: &FlatEnsemble, xs: &[[f32; F_MAX]]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len());
        let mut off = 0;
        while off < xs.len() {
            let remaining = xs.len() - off;
            let (exe, cap) = if remaining <= self.meta.small_n {
                (&self.exec_small, self.meta.small_n)
            } else {
                (&self.exec_pool, self.meta.pool_n)
            };
            let take = remaining.min(cap);
            let scores = self.score_slab(exe, cap, ens, &xs[off..off + take])?;
            out.extend_from_slice(&scores[..take]);
            off += take;
        }
        Ok(out)
    }

    fn score_slab(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        cap: usize,
        ens: &FlatEnsemble,
        xs: &[[f32; F_MAX]],
    ) -> Result<Vec<f32>> {
        let x_lit = pack_features(xs, cap)?;
        let feat = xla::Literal::vec1(ens.feat.as_slice())
            .reshape(&[TREES_MAX as i64, DEPTH_MAX as i64])?;
        let thr = xla::Literal::vec1(ens.thr.as_slice())
            .reshape(&[TREES_MAX as i64, DEPTH_MAX as i64])?;
        let leaves = xla::Literal::vec1(ens.leaves.as_slice())
            .reshape(&[TREES_MAX as i64, LEAVES_MAX as i64])?;
        let result = exe.execute::<xla::Literal>(&[x_lit, feat, thr, leaves])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Low-fidelity combined score (Eqns 1-2) in one fused execution:
    /// per-component ensembles + borrowed per-component feature views +
    /// mode (1.0 = max / execution time, 0.0 = sum / computer time).
    pub fn lowfi_score(
        &self,
        comps: &[(FlatEnsemble, &[[f32; F_MAX]])],
        mode: f32,
    ) -> Result<Vec<f32>> {
        let j_max = self.meta.j_max;
        if comps.is_empty() || comps.len() > j_max {
            return Err(Error::msg(format!(
                "lowfi_score needs 1..={j_max} components, got {}",
                comps.len()
            )));
        }
        let n = comps[0].1.len();
        if comps.iter().any(|(_, xs)| xs.len() != n) {
            return Err(Error::msg(
                "lowfi_score: inconsistent pool sizes across components",
            ));
        }
        let cap = self.meta.pool_n;
        if n > cap {
            return Err(Error::msg(format!(
                "lowfi_score: pool of {n} exceeds artifact capacity {cap}"
            )));
        }
        // xs [J, N, F]; padding slots carry the neutral-component
        // ensemble (log-space NEG_PRED -> exp == 0)
        let neutral = FlatEnsemble::neutral_component();
        let mut xflat = vec![0f32; j_max * cap * F_MAX];
        let mut feat = vec![0i32; j_max * TREES_MAX * DEPTH_MAX];
        let mut thr = vec![f32::INFINITY; j_max * TREES_MAX * DEPTH_MAX];
        let mut leaves = vec![0f32; j_max * TREES_MAX * LEAVES_MAX];
        for j in comps.len()..j_max {
            let lb = j * TREES_MAX * LEAVES_MAX;
            leaves[lb..lb + TREES_MAX * LEAVES_MAX].copy_from_slice(&neutral.leaves);
        }
        for (j, (ens, xs)) in comps.iter().enumerate() {
            for (i, row) in xs.iter().enumerate() {
                let base = (j * cap + i) * F_MAX;
                xflat[base..base + F_MAX].copy_from_slice(row);
            }
            let fb = j * TREES_MAX * DEPTH_MAX;
            feat[fb..fb + TREES_MAX * DEPTH_MAX].copy_from_slice(&ens.feat);
            thr[fb..fb + TREES_MAX * DEPTH_MAX].copy_from_slice(&ens.thr);
            let lb = j * TREES_MAX * LEAVES_MAX;
            leaves[lb..lb + TREES_MAX * LEAVES_MAX].copy_from_slice(&ens.leaves);
        }
        let xs_lit = xla::Literal::vec1(xflat.as_slice()).reshape(&[
            j_max as i64,
            cap as i64,
            F_MAX as i64,
        ])?;
        let feat_lit = xla::Literal::vec1(feat.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            DEPTH_MAX as i64,
        ])?;
        let thr_lit = xla::Literal::vec1(thr.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            DEPTH_MAX as i64,
        ])?;
        let leaves_lit = xla::Literal::vec1(leaves.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            LEAVES_MAX as i64,
        ])?;
        let mode_lit = xla::Literal::scalar(mode);
        let result = self
            .exec_lowfi
            .execute::<xla::Literal>(&[xs_lit, feat_lit, thr_lit, leaves_lit, mode_lit])?[0][0]
            .to_literal_sync()?;
        let mut scores = result.to_tuple1()?.to_vec::<f32>()?;
        scores.truncate(n);
        Ok(scores)
    }
}

/// Pack feature rows into a zero-padded `[cap, F_MAX]` literal.
fn pack_features(xs: &[[f32; F_MAX]], cap: usize) -> Result<xla::Literal> {
    assert!(xs.len() <= cap);
    let mut flat = vec![0f32; cap * F_MAX];
    for (i, row) in xs.iter().enumerate() {
        flat[i * F_MAX..(i + 1) * F_MAX].copy_from_slice(row);
    }
    Ok(xla::Literal::vec1(flat.as_slice()).reshape(&[cap as i64, F_MAX as i64])?)
}
