//! Stub runtime compiled when the `pjrt` feature is off.
//!
//! Keeps the exact public surface of [`super::pjrt::Runtime`] so
//! callers type-check unchanged, but can never be constructed: both
//! loaders return an error naming the missing feature, which is what
//! routes `Scorer::pjrt_or_native` (and the benches / integration
//! tests, which skip on load failure) onto the native scorer.

use std::path::Path;

use super::{Error, Meta, Result};
use crate::config::F_MAX;
use crate::gbt::FlatEnsemble;

/// Uninhabited placeholder for the PJRT runtime (see module docs).
pub struct Runtime {
    pub meta: Meta,
    never: std::convert::Infallible,
}

impl Runtime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load_default() -> Result<Runtime> {
        Err(Error::msg(
            "crate built without the `pjrt` feature — enable it (and the \
             vendored `xla` dependency in Cargo.toml) to load AOT artifacts",
        ))
    }

    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Self::load_default().map_err(|e| e.context(format!("loading {}", dir.display())))
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Unreachable (no `Runtime` value can exist); signature mirror of
    /// the pjrt implementation.
    pub fn score(&self, _ens: &FlatEnsemble, _xs: &[[f32; F_MAX]]) -> Result<Vec<f32>> {
        match self.never {}
    }

    /// Unreachable; signature mirror of the pjrt implementation.
    pub fn lowfi_score(
        &self,
        _comps: &[(FlatEnsemble, &[[f32; F_MAX]])],
        _mode: f32,
    ) -> Result<Vec<f32>> {
        match self.never {}
    }
}
