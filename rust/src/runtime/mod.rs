//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`)
//! produced by `python/compile/aot.py` and executes them on the tuning
//! hot path.  Python never runs here — trained ensembles and pool
//! feature matrices are passed as runtime tensors.
//!
//! Interchange is HLO **text**: the bundled xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The xla-backed implementation lives in [`pjrt`] behind the `pjrt`
//! cargo feature (it needs the offline-vendored `xla` crate — see
//! Cargo.toml).  Without the feature a same-shaped stub [`Runtime`] is
//! compiled whose `load*` constructors return a descriptive error, so
//! every caller (`Scorer::pjrt_or_native`, benches, integration tests)
//! falls back to the exact native scorer instead of failing to build.

use std::fmt;
use std::path::PathBuf;

use crate::config::F_MAX;
use crate::gbt::{DEPTH_MAX, LEAVES_MAX, TREES_MAX};
use crate::util::json;

/// Runtime-layer error: a single pre-rendered context chain (printed
/// the same under `{e}` and anyhow-style `{e:#}` call sites).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }

    /// Prefix a context layer, `anyhow::Context`-style.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Artifact-shape manifest (artifacts/meta.json), asserted at load.
#[derive(Clone, Debug, PartialEq)]
pub struct Meta {
    pub pool_n: usize,
    pub small_n: usize,
    pub f_max: usize,
    pub trees: usize,
    pub depth: usize,
    pub leaves: usize,
    pub j_max: usize,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let v = json::parse(text).map_err(|e| Error::msg(format!("meta.json: {e}")))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| Error::msg(format!("meta.json missing '{k}'")))
        };
        Ok(Meta {
            pool_n: get("pool_n")?,
            small_n: get("small_n")?,
            f_max: get("f_max")?,
            trees: get("trees")?,
            depth: get("depth")?,
            leaves: get("leaves")?,
            j_max: get("j_max")?,
        })
    }

    /// Check against the crate's compiled-in constants.
    pub fn validate(&self) -> Result<()> {
        if self.f_max != F_MAX
            || self.trees != TREES_MAX
            || self.depth != DEPTH_MAX
            || self.leaves != LEAVES_MAX
        {
            return Err(Error::msg(format!(
                "artifact manifest {:?} does not match crate constants \
                 (F_MAX={F_MAX}, TREES_MAX={TREES_MAX}, DEPTH_MAX={DEPTH_MAX}, \
                 LEAVES_MAX={LEAVES_MAX}) — re-run `make artifacts`",
                self
            )));
        }
        Ok(())
    }
}

/// Locate the artifacts directory: `$CEAL_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the crate root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CEAL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_and_validate() {
        let text = r#"{"pool_n":2048,"small_n":256,"f_max":8,"trees":64,
                       "depth":6,"leaves":64,"j_max":4,"artifacts":[]}"#;
        let m = Meta::parse(text).unwrap();
        assert_eq!(m.pool_n, 2048);
        assert!(m.validate().is_ok());
    }

    #[test]
    fn meta_mismatch_rejected() {
        let text = r#"{"pool_n":2048,"small_n":256,"f_max":4,"trees":64,
                       "depth":6,"leaves":64,"j_max":4}"#;
        let m = Meta::parse(text).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(Meta::parse(r#"{"pool_n": 10}"#).is_err());
        assert!(Meta::parse("not json").is_err());
    }

    #[test]
    fn error_context_chains() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer: inner");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::load_default().unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
