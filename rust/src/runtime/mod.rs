//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`)
//! produced by `python/compile/aot.py` and executes them on the tuning
//! hot path.  Python never runs here — trained ensembles and pool
//! feature matrices are passed as runtime tensors.
//!
//! Interchange is HLO **text**: the bundled xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::F_MAX;
use crate::gbt::{FlatEnsemble, DEPTH_MAX, LEAVES_MAX, TREES_MAX};
use crate::util::json;

/// Artifact-shape manifest (artifacts/meta.json), asserted at load.
#[derive(Clone, Debug, PartialEq)]
pub struct Meta {
    pub pool_n: usize,
    pub small_n: usize,
    pub f_max: usize,
    pub trees: usize,
    pub depth: usize,
    pub leaves: usize,
    pub j_max: usize,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .with_context(|| format!("meta.json missing '{k}'"))
        };
        Ok(Meta {
            pool_n: get("pool_n")?,
            small_n: get("small_n")?,
            f_max: get("f_max")?,
            trees: get("trees")?,
            depth: get("depth")?,
            leaves: get("leaves")?,
            j_max: get("j_max")?,
        })
    }

    /// Check against the crate's compiled-in constants.
    pub fn validate(&self) -> Result<()> {
        if self.f_max != F_MAX
            || self.trees != TREES_MAX
            || self.depth != DEPTH_MAX
            || self.leaves != LEAVES_MAX
        {
            bail!(
                "artifact manifest {:?} does not match crate constants \
                 (F_MAX={F_MAX}, TREES_MAX={TREES_MAX}, DEPTH_MAX={DEPTH_MAX}, \
                 LEAVES_MAX={LEAVES_MAX}) — re-run `make artifacts`",
                self
            );
        }
        Ok(())
    }
}

/// Locate the artifacts directory: `$CEAL_ARTIFACTS`, else `artifacts/`
/// relative to the working directory, else relative to the crate root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CEAL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A loaded, compiled PJRT runtime. One per thread (the underlying
/// client is not shared across threads); construction compiles the
/// three artifacts once and scoring then runs with no Python anywhere.
pub struct Runtime {
    client: xla::PjRtClient,
    exec_pool: xla::PjRtLoadedExecutable,
    exec_small: xla::PjRtLoadedExecutable,
    exec_lowfi: xla::PjRtLoadedExecutable,
    pub meta: Meta,
}

impl Runtime {
    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&artifacts_dir())
    }

    /// Load and compile all artifacts under `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json (run `make artifacts`)", dir.display()))?;
        let meta = Meta::parse(&meta_text)?;
        meta.validate()?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        Ok(Runtime {
            exec_pool: compile("ensemble_predict.hlo.txt")?,
            exec_small: compile("ensemble_predict_small.hlo.txt")?,
            exec_lowfi: compile("lowfi_score.hlo.txt")?,
            meta,
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Score `xs` with one flattened ensemble via the AOT kernel.
    /// Batches larger than the pool artifact are processed in slabs.
    pub fn score(&self, ens: &FlatEnsemble, xs: &[[f32; F_MAX]]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len());
        let mut off = 0;
        while off < xs.len() {
            let remaining = xs.len() - off;
            let (exe, cap) = if remaining <= self.meta.small_n {
                (&self.exec_small, self.meta.small_n)
            } else {
                (&self.exec_pool, self.meta.pool_n)
            };
            let take = remaining.min(cap);
            let scores = self.score_slab(exe, cap, ens, &xs[off..off + take])?;
            out.extend_from_slice(&scores[..take]);
            off += take;
        }
        Ok(out)
    }

    fn score_slab(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        cap: usize,
        ens: &FlatEnsemble,
        xs: &[[f32; F_MAX]],
    ) -> Result<Vec<f32>> {
        let x_lit = pack_features(xs, cap)?;
        let feat = xla::Literal::vec1(ens.feat.as_slice())
            .reshape(&[TREES_MAX as i64, DEPTH_MAX as i64])?;
        let thr = xla::Literal::vec1(ens.thr.as_slice())
            .reshape(&[TREES_MAX as i64, DEPTH_MAX as i64])?;
        let leaves = xla::Literal::vec1(ens.leaves.as_slice())
            .reshape(&[TREES_MAX as i64, LEAVES_MAX as i64])?;
        let result = exe.execute::<xla::Literal>(&[x_lit, feat, thr, leaves])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Low-fidelity combined score (Eqns 1-2) in one fused execution:
    /// per-component ensembles + per-component feature views + mode
    /// (1.0 = max / execution time, 0.0 = sum / computer time).
    pub fn lowfi_score(
        &self,
        comps: &[(FlatEnsemble, Vec<[f32; F_MAX]>)],
        mode: f32,
    ) -> Result<Vec<f32>> {
        let j_max = self.meta.j_max;
        if comps.is_empty() || comps.len() > j_max {
            bail!("lowfi_score needs 1..={j_max} components, got {}", comps.len());
        }
        let n = comps[0].1.len();
        if comps.iter().any(|(_, xs)| xs.len() != n) {
            bail!("lowfi_score: inconsistent pool sizes across components");
        }
        let cap = self.meta.pool_n;
        if n > cap {
            bail!("lowfi_score: pool of {n} exceeds artifact capacity {cap}");
        }
        // xs [J, N, F]; padding slots carry the neutral-component
        // ensemble (log-space NEG_PRED -> exp == 0)
        let neutral = FlatEnsemble::neutral_component();
        let mut xflat = vec![0f32; j_max * cap * F_MAX];
        let mut feat = vec![0i32; j_max * TREES_MAX * DEPTH_MAX];
        let mut thr = vec![f32::INFINITY; j_max * TREES_MAX * DEPTH_MAX];
        let mut leaves = vec![0f32; j_max * TREES_MAX * LEAVES_MAX];
        for j in comps.len()..j_max {
            let lb = j * TREES_MAX * LEAVES_MAX;
            leaves[lb..lb + TREES_MAX * LEAVES_MAX].copy_from_slice(&neutral.leaves);
        }
        for (j, (ens, xs)) in comps.iter().enumerate() {
            for (i, row) in xs.iter().enumerate() {
                let base = (j * cap + i) * F_MAX;
                xflat[base..base + F_MAX].copy_from_slice(row);
            }
            let fb = j * TREES_MAX * DEPTH_MAX;
            feat[fb..fb + TREES_MAX * DEPTH_MAX].copy_from_slice(&ens.feat);
            thr[fb..fb + TREES_MAX * DEPTH_MAX].copy_from_slice(&ens.thr);
            let lb = j * TREES_MAX * LEAVES_MAX;
            leaves[lb..lb + TREES_MAX * LEAVES_MAX].copy_from_slice(&ens.leaves);
        }
        let xs_lit = xla::Literal::vec1(xflat.as_slice()).reshape(&[
            j_max as i64,
            cap as i64,
            F_MAX as i64,
        ])?;
        let feat_lit = xla::Literal::vec1(feat.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            DEPTH_MAX as i64,
        ])?;
        let thr_lit = xla::Literal::vec1(thr.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            DEPTH_MAX as i64,
        ])?;
        let leaves_lit = xla::Literal::vec1(leaves.as_slice()).reshape(&[
            j_max as i64,
            TREES_MAX as i64,
            LEAVES_MAX as i64,
        ])?;
        let mode_lit = xla::Literal::scalar(mode);
        let result = self
            .exec_lowfi
            .execute::<xla::Literal>(&[xs_lit, feat_lit, thr_lit, leaves_lit, mode_lit])?[0][0]
            .to_literal_sync()?;
        let mut scores = result.to_tuple1()?.to_vec::<f32>()?;
        scores.truncate(n);
        Ok(scores)
    }
}

/// Pack feature rows into a zero-padded `[cap, F_MAX]` literal.
fn pack_features(xs: &[[f32; F_MAX]], cap: usize) -> Result<xla::Literal> {
    assert!(xs.len() <= cap);
    let mut flat = vec![0f32; cap * F_MAX];
    for (i, row) in xs.iter().enumerate() {
        flat[i * F_MAX..(i + 1) * F_MAX].copy_from_slice(row);
    }
    Ok(xla::Literal::vec1(flat.as_slice()).reshape(&[cap as i64, F_MAX as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_and_validate() {
        let text = r#"{"pool_n":2048,"small_n":256,"f_max":8,"trees":64,
                       "depth":6,"leaves":64,"j_max":4,"artifacts":[]}"#;
        let m = Meta::parse(text).unwrap();
        assert_eq!(m.pool_n, 2048);
        assert!(m.validate().is_ok());
    }

    #[test]
    fn meta_mismatch_rejected() {
        let text = r#"{"pool_n":2048,"small_n":256,"f_max":4,"trees":64,
                       "depth":6,"leaves":64,"j_max":4}"#;
        let m = Meta::parse(text).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(Meta::parse(r#"{"pool_n": 10}"#).is_err());
        assert!(Meta::parse("not json").is_err());
    }
}
