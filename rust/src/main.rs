//! `ceal` — the leader binary: reproduce paper tables/figures, or run a
//! single tuning campaign.
//!
//! ```text
//! ceal table <1|2>          reproduce a paper table
//! ceal fig <4..13>          reproduce a paper figure
//! ceal all                  everything (the `make repro` target)
//! ceal tune                 one tuning campaign (see flags below)
//! ceal info                 runtime/artifact diagnostics
//!
//! common flags:
//!   --out DIR         output directory for CSVs        [results]
//!   --reps N          repetitions per campaign cell    [40]
//!   --pool N          pool / test-set size             [2000]
//!   --seed N          root seed                        [0xCEA1]
//!   --threads N       worker threads ($CEAL_THREADS)   [#cpus]
//!   --scorer S        native | pjrt                    [native]
//! tune flags:
//!   --workflow W      any registered workflow (see `ceal info`) [LV]
//!   --objective O     exec | comp                      [comp]
//!   --algo A          rs|al|geist|ceal|ceal+hist|alph|alph+hist [ceal]
//!   --m N             training-sample budget           [50]
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, Algo, ScorerKind};
use ceal::exper::{self, ExpCtx};
use ceal::sim::{Objective, WorkflowRegistry};
use ceal::util::cli::Args;
use ceal::util::table::fnum;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn parse_ctx(args: &Args) -> Result<ExpCtx, String> {
    let mut ctx = ExpCtx::default();
    ctx.out_dir = PathBuf::from(args.opt_or("out", "results"));
    ctx.reps = args.opt_usize("reps", ctx.reps)?;
    ctx.pool_size = args.opt_usize("pool", ctx.pool_size)?;
    ctx.seed = args.opt_u64("seed", ctx.seed)?;
    ctx.threads = args.opt_usize("threads", ctx.threads)?;
    // Precedence: --threads > CEAL_THREADS > available parallelism.
    // The default already folds the env var in, so installing the
    // resolved value makes every inner fork-join (GBT training, pool
    // scoring, batch measurement) agree with the campaign width.
    ceal::util::parallel::set_threads(ctx.threads);
    ctx.scorer = match args.opt_or("scorer", "native") {
        "native" => ScorerKind::Native,
        "pjrt" => ScorerKind::Pjrt,
        other => return Err(format!("unknown --scorer '{other}' (native|pjrt)")),
    };
    Ok(ctx)
}

fn run() -> Result<(), String> {
    let args = Args::parse_env()?;
    let ctx = parse_ctx(&args)?;
    match args.subcommand.as_deref() {
        Some("table") => {
            let n: usize = args
                .positionals
                .first()
                .ok_or("usage: ceal table <1|2>")?
                .parse()
                .map_err(|e| format!("bad table number: {e}"))?;
            if !exper::run_table(n, &ctx) {
                return Err(format!("no table {n} (have 1, 2)"));
            }
        }
        Some("fig") => {
            let n: usize = args
                .positionals
                .first()
                .ok_or("usage: ceal fig <4..13>")?
                .parse()
                .map_err(|e| format!("bad figure number: {e}"))?;
            if !exper::run_fig(n, &ctx) {
                return Err(format!("no figure {n} (have 4..13)"));
            }
        }
        Some("all") => exper::run_all(&ctx),
        Some("ablation") => exper::ablations::run(&ctx),
        Some("tune") => tune(&args, &ctx)?,
        Some("info") => info(),
        other => {
            eprintln!("{}", usage());
            if let Some(cmd) = other {
                return Err(format!("unknown subcommand '{cmd}'"));
            }
        }
    }
    Ok(())
}

fn tune(args: &Args, ctx: &ExpCtx) -> Result<(), String> {
    let wf_name = args.opt_or("workflow", "LV");
    let wf = WorkflowId::from_name(wf_name).ok_or_else(|| {
        format!(
            "unknown --workflow '{wf_name}' (registered: {})",
            WorkflowRegistry::global().names().join(" | ")
        )
    })?;
    let obj = Objective::from_name(args.opt_or("objective", "comp"))
        .ok_or("unknown --objective (exec|comp)")?;
    let algo =
        Algo::from_name(args.opt_or("algo", "ceal")).ok_or("unknown --algo")?;
    let m = args.opt_usize("m", 50)?;
    println!(
        "tuning {wf} for {obj} with {algo}, m={m}, pool={}, reps={}, scorer={:?}",
        ctx.pool_size, ctx.reps, ctx.scorer
    );
    // Pre-flight the cell's pool fallibly: a registered workflow whose
    // space admits no feasible configuration errors out here instead of
    // panicking inside the campaign (the cache hands the same pool to
    // run_campaign below).
    ceal::coordinator::PoolCache::global()
        .try_get_or_generate(
            &ceal::tuner::Problem::new(wf, obj),
            ctx.pool_size,
            ctx.seed,
            ctx.threads,
        )
        .map_err(|e| format!("cannot tune {wf}: {e}"))?;
    let mut campaign = ctx.campaign(wf, obj, m);
    // optional CEAL/ALpH hyper-parameter overrides (Fig. 13 territory)
    if args.opt("mr").is_some() || args.opt("m0").is_some() || args.opt("iters").is_some() {
        let base = match algo {
            Algo::CealHist | Algo::AlphHist => ceal::tuner::CealParams::with_hist(),
            _ => ceal::tuner::CealParams::no_hist(),
        };
        campaign = campaign.with_ceal_params(ceal::tuner::CealParams {
            iterations: args.opt_usize("iters", base.iterations)?,
            m0_frac: args.opt_f64("m0", base.m0_frac)?,
            mr_frac: args.opt_f64("mr", base.mr_frac)?,
        });
    }
    let agg = run_campaign(algo, &campaign);
    println!(
        "pool best     : {} {}",
        fnum(agg.pool_best, 4),
        obj.unit()
    );
    println!(
        "expert config : {} {}",
        fnum(agg.expert_value, 4),
        obj.unit()
    );
    println!(
        "tuned (mean)  : {} {}  (normalized {:.3})",
        fnum(agg.mean_best(), 4),
        obj.unit(),
        agg.mean_norm_best()
    );
    println!(
        "top-1 recall  : {:.0}%   collection cost {} {}",
        agg.mean_recall(1) * 100.0,
        fnum(agg.mean_cost(), 3),
        obj.unit()
    );
    match agg.payoff_runs() {
        Some(p) => println!("pays off after {} workflow runs", fnum(p, 0)),
        None => println!("does not beat the expert configuration"),
    }
    Ok(())
}

fn info() {
    println!("ceal {} — CEAL in-situ workflow auto-tuning reproduction", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", ceal::runtime::artifacts_dir().display());
    match ceal::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT runtime : OK (platform {})", rt.platform());
            println!("artifact meta: {:?}", rt.meta);
        }
        Err(e) => println!("PJRT runtime : unavailable — {e:#}"),
    }
    let reg = WorkflowRegistry::global();
    println!("workflow registry ({} registered):", reg.len());
    for def in reg.defs() {
        let spec = def.spec();
        let comps: Vec<&str> = def.components.iter().map(|c| c.stage_name).collect();
        let edges: Vec<String> = def
            .edges
            .iter()
            .map(|e| {
                format!(
                    "{}->{}",
                    def.components[e.from].stage_name, def.components[e.to].stage_name
                )
            })
            .collect();
        println!(
            "  {:<4} {} params, space {:.1e}",
            def.name,
            spec.n_params(),
            spec.space_size() as f64
        );
        println!("       components: {}", comps.join(", "));
        println!("       edges     : {}", edges.join(", "));
    }
}

fn usage() -> &'static str {
    "usage: ceal <table N | fig N | all | tune | info> [flags]\n(see `ceal` source header or README for flags)"
}
