//! `ceal` — the leader binary: reproduce paper tables/figures, or run a
//! single tuning campaign.
//!
//! ```text
//! ceal table <1|2>          reproduce a paper table
//! ceal fig <4..13>          reproduce a paper figure
//! ceal all                  everything (the `make repro` target)
//! ceal tune                 one tuning campaign (see flags below)
//! ceal serve                multi-tenant ask/tell tuning daemon
//! ceal client               one-shot client driving a served session
//! ceal info                 runtime/artifact diagnostics
//!
//! common flags:
//!   --out DIR         output directory for CSVs        [results]
//!   --reps N          repetitions per campaign cell    [40]
//!   --pool N          pool / test-set size             [2000]
//!   --seed N          root seed                        [0xCEA1]
//!   --threads N       worker threads ($CEAL_THREADS)   [#cpus]
//!   --scorer S        native | pjrt                    [native]
//!   --pool-cache-bytes N
//!                     pool-cache memory cap in bytes
//!                     ($CEAL_POOL_CACHE_BYTES)         [2 GiB]
//! tune flags:
//!   --workflow W      any registered workflow (see `ceal info`) [LV]
//!   --objective O     exec | comp                      [comp]
//!   --algo A          any registered algorithm (see `ceal info`) [ceal]
//!   --m N             training-sample budget           [50]
//!   --record PATH     run ONE session (campaign rep 0) and record its
//!                     measurement stream to a versioned JSONL trace
//!   --replay PATH     re-run a recorded session from its trace alone
//!                     (no simulator measurements; settings come from
//!                     the trace header)
//!   --faults F,T,S    inject deterministic measurement faults:
//!                     failure probability F, timeout probability T,
//!                     schedule seed S (see README "Failure semantics")
//!   --checkpoint-dir DIR
//!                     run ONE session crash-safely: every ask/tell is
//!                     journaled to DIR before it happens (see README
//!                     "Crash recovery")
//!   --resume DIR      resume a killed --checkpoint-dir session from
//!                     its journal; the finished run is bit-identical
//!                     to the uninterrupted one
//!   --measure-deadline SECS
//!                     watchdog for --checkpoint-dir/--resume: a batch
//!                     older than SECS is journaled as timed out and
//!                     flows through the session's retry handling
//! serve flags (see README "Serving"):
//!   --addr A          listen address                   [127.0.0.1:7433]
//!   --serve-root DIR  one journal dir per session token [serve]
//!   --session-ttl SECS
//!                     evict idle sessions to disk after SECS [900]
//!   --no-session-ttl  keep every session resident forever
//! client flags:
//!   --addr A          daemon address                   [127.0.0.1:7433]
//!   --token T         resume an existing session by token
//!   --token-file PATH write the session token to PATH on open
//!   --throttle-ms N   sleep N ms between exchanges (CI kill windows)
//!   (fresh opens also take --workflow/--objective/--algo/--m and the
//!    common --pool/--seed/--scorer; resume pins them from the token)
//! ```
//!
//! `ceal robustness` runs the quality-vs-failure-rate degradation
//! sweep (all algorithms under increasing fault rates).
//!
//! Exit codes: `0` success; `1` usage or runtime error; `2` corrupted,
//! truncated or incompatible trace/journal/checkpoint; `3` the
//! requested configuration space admits no feasible configuration.

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::Duration;

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, session_rng, tuner_for, Algo, PoolCache, ScorerKind};
use ceal::exper::{self, ExpCtx};
use ceal::serve::{OpenSpec, ServeClient, ServeConfig, ServeError, TcpTransport};
use ceal::sim::{Objective, WorkflowRegistry};
use ceal::tuner::{
    drive, drive_checkpointed, replay_into, Collector, DeadlineEvaluator, DiagSink, Evaluator,
    FailurePolicy, FaultInjector, FaultPlan, FaultSpec, LoadedCheckpoint, Pool, Problem,
    SessionJournal, TraceError, TraceHeader, TraceRecorder, TraceReplayer, TunerOutput,
    TunerSession,
};
use ceal::util::cli::Args;
use ceal::util::csv::CsvWriter;
use ceal::util::json::Json;
use ceal::util::table::fnum;

/// Corrupted/truncated/incompatible trace, journal or checkpoint.
const EXIT_TRACE: u8 = 2;
/// The requested space admits no feasible configuration.
const EXIT_INFEASIBLE: u8 = 3;

/// A CLI failure with its process exit code (documented in the module
/// header): generic errors exit 1, trace/journal errors 2, infeasible
/// spaces 3 — so scripts and the CI cells can tell them apart.
struct CliError {
    code: u8,
    msg: String,
}

impl CliError {
    fn trace(e: TraceError) -> CliError {
        CliError {
            code: EXIT_TRACE,
            msg: e.to_string(),
        }
    }

    fn infeasible(msg: String) -> CliError {
        CliError {
            code: EXIT_INFEASIBLE,
            msg,
        }
    }
}

impl From<String> for CliError {
    fn from(msg: String) -> CliError {
        CliError { code: 1, msg }
    }
}

impl From<&str> for CliError {
    fn from(msg: &str) -> CliError {
        CliError {
            code: 1,
            msg: msg.to_string(),
        }
    }
}

impl From<ServeError> for CliError {
    /// Serve failures carry the CLI's own exit-code taxonomy (and a
    /// remote error preserves the server's code verbatim), so `ceal
    /// client` exits exactly as the equivalent `ceal tune` would.
    fn from(e: ServeError) -> CliError {
        CliError {
            code: e.code(),
            msg: e.to_string(),
        }
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {}", e.msg);
            ExitCode::from(e.code)
        }
    }
}

fn parse_ctx(args: &Args) -> Result<ExpCtx, String> {
    let mut ctx = ExpCtx::default();
    ctx.out_dir = PathBuf::from(args.opt_or("out", "results"));
    ctx.reps = args.opt_usize("reps", ctx.reps)?;
    ctx.pool_size = args.opt_usize("pool", ctx.pool_size)?;
    ctx.seed = args.opt_u64("seed", ctx.seed)?;
    ctx.threads = args.opt_usize("threads", ctx.threads)?;
    // Precedence: --threads > CEAL_THREADS > available parallelism.
    // The default already folds the env var in, so installing the
    // resolved value makes every inner fork-join (GBT training, pool
    // scoring, batch measurement) agree with the campaign width.
    ceal::util::parallel::set_threads(ctx.threads);
    let scorer_name = args.opt_or("scorer", "native");
    ctx.scorer = ScorerKind::from_name(scorer_name)
        .ok_or_else(|| format!("unknown --scorer '{scorer_name}' (native|pjrt)"))?;
    // Precedence mirrors --threads: --pool-cache-bytes > env > default
    // (the cache already folded $CEAL_POOL_CACHE_BYTES in at startup).
    if args.opt("pool-cache-bytes").is_some() {
        let bytes = args.opt_usize("pool-cache-bytes", 0)?;
        PoolCache::global().set_cap_bytes(bytes);
    }
    Ok(ctx)
}

fn run() -> Result<(), CliError> {
    let args = Args::parse_env()?;
    let ctx = parse_ctx(&args)?;
    match args.subcommand.as_deref() {
        Some("table") => {
            let n: usize = args
                .positionals
                .first()
                .ok_or("usage: ceal table <1|2>")?
                .parse()
                .map_err(|e| format!("bad table number: {e}"))?;
            if !exper::run_table(n, &ctx) {
                return Err(format!("no table {n} (have 1, 2)").into());
            }
        }
        Some("fig") => {
            let n: usize = args
                .positionals
                .first()
                .ok_or("usage: ceal fig <4..13>")?
                .parse()
                .map_err(|e| format!("bad figure number: {e}"))?;
            if !exper::run_fig(n, &ctx) {
                return Err(format!("no figure {n} (have 4..13)").into());
            }
        }
        Some("all") => exper::run_all(&ctx),
        Some("ablation") => exper::ablations::run(&ctx),
        Some("robustness") => exper::robustness::run(&ctx),
        Some("tune") => tune(&args, &ctx)?,
        Some("serve") => serve_cmd(&args, &ctx)?,
        Some("client") => client_cmd(&args, &ctx)?,
        Some("info") => info(),
        other => {
            eprintln!("{}", usage());
            if let Some(cmd) = other {
                return Err(format!("unknown subcommand '{cmd}'").into());
            }
        }
    }
    Ok(())
}

/// Optional CEAL/ALpH hyper-parameter overrides (Fig. 13 territory).
fn ceal_overrides(args: &Args, algo: Algo) -> Result<Option<ceal::tuner::CealParams>, String> {
    if args.opt("mr").is_none() && args.opt("m0").is_none() && args.opt("iters").is_none() {
        return Ok(None);
    }
    let base = match algo {
        Algo::CealHist | Algo::AlphHist => ceal::tuner::CealParams::with_hist(),
        _ => ceal::tuner::CealParams::no_hist(),
    };
    Ok(Some(ceal::tuner::CealParams {
        iterations: args.opt_usize("iters", base.iterations)?,
        m0_frac: args.opt_f64("m0", base.m0_frac)?,
        mr_frac: args.opt_f64("mr", base.mr_frac)?,
    }))
}

/// `--faults p_fail,p_timeout,seed`: the CLI's transient fault plan
/// (crashes/transport losses at `p_fail`, timeouts at `p_timeout`,
/// plus the plan's light straggler/corruption tail), scheduled by a
/// dedicated seed so fault schedules and session RNG never alias.
fn parse_faults(args: &Args) -> Result<Option<FaultSpec>, String> {
    let Some(spec) = args.opt("faults") else {
        return Ok(None);
    };
    let parts: Vec<&str> = spec.split(',').collect();
    if parts.len() != 3 {
        return Err(format!(
            "--faults wants p_fail,p_timeout,seed (got '{spec}')"
        ));
    }
    let p_fail: f64 = parts[0]
        .parse()
        .map_err(|e| format!("bad --faults p_fail '{}': {e}", parts[0]))?;
    let p_timeout: f64 = parts[1]
        .parse()
        .map_err(|e| format!("bad --faults p_timeout '{}': {e}", parts[1]))?;
    let seed: u64 = parts[2]
        .parse()
        .map_err(|e| format!("bad --faults seed '{}': {e}", parts[2]))?;
    if !(0.0..=1.0).contains(&p_fail) || !(0.0..=1.0).contains(&p_timeout) {
        return Err("--faults probabilities must be within [0,1]".into());
    }
    Ok(Some(FaultSpec {
        plan: FaultPlan::transient(p_fail, p_timeout),
        seed,
    }))
}

/// `--measure-deadline SECS`: the wall-clock watchdog for journaled
/// sessions.
fn parse_deadline(args: &Args) -> Result<Option<Duration>, String> {
    args.opt_secs("measure-deadline")
}

fn tune(args: &Args, ctx: &ExpCtx) -> Result<(), CliError> {
    let deadline = parse_deadline(args)?;
    if let Some(dir) = args.opt_path("resume") {
        return resume_session(args, ctx, &dir, deadline);
    }
    if let Some(path) = args.opt_path("replay") {
        return replay_session(args, ctx, &path);
    }
    let wf_name = args.opt_or("workflow", "LV");
    let wf = WorkflowId::from_name(wf_name).ok_or_else(|| {
        format!(
            "unknown --workflow '{wf_name}' (registered: {})",
            WorkflowRegistry::global().names().join(" | ")
        )
    })?;
    let obj = Objective::from_name(args.opt_or("objective", "comp"))
        .ok_or("unknown --objective (exec|comp)")?;
    let algo_name = args.opt_or("algo", "ceal");
    let algo = Algo::from_name(algo_name).ok_or_else(|| {
        format!(
            "unknown --algo '{algo_name}' (registered: {})",
            Algo::names().join(" | ")
        )
    })?;
    let m = args.opt_usize("m", 50)?;
    let overrides = ceal_overrides(args, algo)?;
    let faults = parse_faults(args)?;
    let header = TraceHeader {
        algo: algo.name().into(),
        workflow: wf.name().into(),
        objective: obj.name().into(),
        m,
        pool_size: ctx.pool_size,
        seed: ctx.seed,
        scorer: ctx.scorer.name().into(),
        ceal_params: overrides,
        faults: faults.clone(),
    };

    if let Some(dir) = args.opt_path("checkpoint-dir") {
        if args.opt("record").is_some() {
            return Err(
                "--record conflicts with --checkpoint-dir (the journal already records the \
                 measurement stream)"
                    .into(),
            );
        }
        return checkpointed_session(ctx, &dir, Some(&header), deadline);
    }
    if deadline.is_some() {
        return Err(
            "--measure-deadline requires a journaled session (--checkpoint-dir or --resume)"
                .into(),
        );
    }
    if let Some(path) = args.opt_path("record") {
        return run_single_session(ctx, &header, Some(path.as_path()), None);
    }

    println!(
        "tuning {wf} for {obj} with {algo}, m={m}, pool={}, reps={}, scorer={:?}",
        ctx.pool_size, ctx.reps, ctx.scorer
    );
    if let Some(spec) = &faults {
        println!(
            "fault injection: p_fail={} p_timeout={} schedule seed {}",
            spec.plan.p_fail, spec.plan.p_timeout, spec.seed
        );
    }
    // Pre-flight the cell's pool fallibly: a registered workflow whose
    // space admits no feasible configuration errors out here instead of
    // panicking inside the campaign (the cache hands the same pool to
    // run_campaign below).
    PoolCache::global()
        .try_get_or_generate(
            &Problem::new(wf, obj),
            ctx.pool_size,
            ctx.seed,
            ctx.threads,
        )
        .map_err(|e| CliError::infeasible(format!("cannot tune {wf}: {e}")))?;
    let mut campaign = ctx.campaign(wf, obj, m);
    if let Some(p) = overrides {
        campaign = campaign.with_ceal_params(p);
    }
    if let Some(spec) = faults {
        campaign = campaign.with_faults(spec);
    }
    let agg = run_campaign(algo, &campaign);
    println!(
        "pool best     : {} {}",
        fnum(agg.pool_best, 4),
        obj.unit()
    );
    println!(
        "expert config : {} {}",
        fnum(agg.expert_value, 4),
        obj.unit()
    );
    println!(
        "tuned (mean)  : {} {}  (normalized {:.3})",
        fnum(agg.mean_best(), 4),
        obj.unit(),
        agg.mean_norm_best()
    );
    println!(
        "top-1 recall  : {:.0}%   collection cost {} {}",
        agg.mean_recall(1) * 100.0,
        fnum(agg.mean_cost(), 3),
        obj.unit()
    );
    match agg.payoff_runs() {
        Some(p) => println!("pays off after {} workflow runs", fnum(p, 0)),
        None => println!("does not beat the expert configuration"),
    }
    let failed: usize = agg.reps.iter().map(|r| r.failed_runs).sum();
    if failed > 0 {
        println!("failed attempts: {failed} across {} reps", agg.reps.len());
    }
    print_cache_stats();
    // Per-rep CSV with shortest-round-trip floats: two identical
    // invocations yield byte-identical files, which is what the CI
    // fault-determinism cell compares.
    let mut w = CsvWriter::new(&[
        "rep",
        "best_value",
        "norm_best",
        "cost",
        "workflow_runs",
        "failed_runs",
    ]);
    for (rep, r) in agg.reps.iter().enumerate() {
        w.row(&[
            rep.to_string(),
            r.best_value.to_string(),
            r.norm_best.to_string(),
            r.cost.to_string(),
            r.workflow_runs.to_string(),
            r.failed_runs.to_string(),
        ]);
    }
    let path = ctx.out_dir.join("tune_reps.csv");
    w.save(&path)
        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    println!("per-rep CSV -> {}", path.display());
    Ok(())
}

/// `ceal tune --replay`: every session setting comes from the trace
/// header, so flags that would contradict it are rejected rather than
/// silently ignored.
fn replay_session(args: &Args, ctx: &ExpCtx, path: &Path) -> Result<(), CliError> {
    let pinned = [
        "workflow", "objective", "algo", "m", "seed", "pool", "scorer", "mr", "m0", "iters",
        "record", "faults", "checkpoint-dir", "measure-deadline",
    ];
    for flag in pinned {
        if args.opt(flag).is_some() {
            return Err(format!(
                "--{flag} conflicts with --replay: the trace header pins the session settings"
            )
            .into());
        }
    }
    // TraceError carries the structured load failure (bad version,
    // malformed line, not a trace); its Display is the user message
    let replayer = TraceReplayer::load(path).map_err(CliError::trace)?;
    let header = replayer.header.clone();
    run_single_session(ctx, &header, None, Some(replayer))
}

/// `ceal tune --resume DIR`: every session setting comes from the
/// checkpoint's journal header, so flags that would contradict it are
/// rejected rather than silently ignored.
fn resume_session(
    args: &Args,
    ctx: &ExpCtx,
    dir: &Path,
    deadline: Option<Duration>,
) -> Result<(), CliError> {
    let pinned = [
        "workflow", "objective", "algo", "m", "seed", "pool", "scorer", "mr", "m0", "iters",
        "record", "replay", "faults", "checkpoint-dir",
    ];
    for flag in pinned {
        if args.opt(flag).is_some() {
            return Err(format!(
                "--{flag} conflicts with --resume: the checkpoint pins the session settings"
            )
            .into());
        }
    }
    checkpointed_session(ctx, dir, None, deadline)
}

/// Resolve a trace/journal header's cell names against the registries.
fn resolve_header(header: &TraceHeader) -> Result<(WorkflowId, Objective, Algo), String> {
    let wf = WorkflowId::from_name(&header.workflow).ok_or_else(|| {
        format!(
            "workflow '{}' is not registered (registered: {})",
            header.workflow,
            WorkflowRegistry::global().names().join(" | ")
        )
    })?;
    let obj = Objective::from_name(&header.objective)
        .ok_or_else(|| format!("objective '{}' unknown", header.objective))?;
    let algo = Algo::from_name(&header.algo).ok_or_else(|| {
        format!(
            "algorithm '{}' is not registered (registered: {})",
            header.algo,
            Algo::names().join(" | ")
        )
    })?;
    Ok((wf, obj, algo))
}

/// Run one crash-safe session: fresh (`header` given, journal created
/// in `dir`) or resumed (`header` absent — everything reloads from
/// `dir`, the journaled exchanges replay into a rebuilt session, and
/// tuning continues from exactly where the crash hit).
fn checkpointed_session(
    ctx: &ExpCtx,
    dir: &Path,
    fresh: Option<&TraceHeader>,
    deadline: Option<Duration>,
) -> Result<(), CliError> {
    let (mut journal, loaded) = match fresh {
        Some(header) => (
            SessionJournal::create(dir, header, 0).map_err(CliError::trace)?,
            None,
        ),
        None => {
            let (journal, loaded) = SessionJournal::resume(dir).map_err(CliError::trace)?;
            for note in &loaded.recovered {
                eprintln!("warning: {note}");
            }
            (journal, Some(loaded))
        }
    };
    let header = journal.header().clone();
    let rep = journal.rep();
    let (wf, obj, algo) = resolve_header(&header)?;
    let prob = Problem::new(wf, obj);
    let pool = PoolCache::global()
        .try_get_or_generate(&prob, header.pool_size, header.seed, ctx.threads)
        .map_err(|e| CliError::infeasible(format!("cannot build pool for {wf}: {e}")))?;
    let scorer = ScorerKind::from_name(&header.scorer)
        .ok_or_else(|| format!("scorer '{}' unknown (native|pjrt)", header.scorer))?
        .build();
    let tuner = tuner_for(algo, &prob, header.seed, header.ceal_params);
    let mut rng = session_rng(header.seed, algo, rep);
    let mut col = Collector::new(&prob, rng.derive_str("collector"));
    let mut session = tuner.session(&prob, &pool, &scorer, header.m, &mut rng);
    if header.faults.is_some() {
        session.set_failure_policy(FailurePolicy::fault_tolerant());
    }
    // diagnostics (retry/straggler/infeasible-space warnings) belong
    // to the session, so they land in the journal directory next to
    // the exchanges they explain instead of an ephemeral stderr
    session.set_diag_sink(DiagSink::File(dir.join("diag.log")));

    // The evaluator stack mirrors the campaign composition (injector
    // innermost, so the journal records the post-fault stream); the
    // deadline watchdog wraps the whole stack.
    let out = match (&header.faults, deadline) {
        (Some(spec), Some(d)) => {
            let mut injector = FaultInjector::new(&mut col, spec.plan, spec.seed_for_rep(rep));
            let mut watchdog = DeadlineEvaluator::new(&mut injector, d);
            run_journaled(session, &mut watchdog, &mut journal, loaded.as_ref())?
        }
        (Some(spec), None) => {
            let mut injector = FaultInjector::new(&mut col, spec.plan, spec.seed_for_rep(rep));
            run_journaled(session, &mut injector, &mut journal, loaded.as_ref())?
        }
        (None, Some(d)) => {
            let mut watchdog = DeadlineEvaluator::new(&mut col, d);
            run_journaled(session, &mut watchdog, &mut journal, loaded.as_ref())?
        }
        (None, None) => run_journaled(session, &mut col, &mut journal, loaded.as_ref())?,
    };
    let provenance = match &loaded {
        Some(l) => format!(
            "resumed from {} ({} journaled exchanges replayed)",
            dir.display(),
            l.exchanges.len()
        ),
        None => format!("checkpointing to {}", dir.display()),
    };
    report_session(ctx, &header, obj, &pool, &out, &provenance)
}

/// Replay the checkpointed exchanges (if resuming) and drive the rest
/// of the session through the journal; journaling errors latched
/// during the run surface here with the trace exit code.
fn run_journaled(
    mut session: Box<dyn TunerSession + '_>,
    evaluator: &mut dyn Evaluator,
    journal: &mut SessionJournal,
    loaded: Option<&LoadedCheckpoint>,
) -> Result<TunerOutput, CliError> {
    if let Some(l) = loaded {
        replay_into(session.as_mut(), evaluator, l).map_err(CliError::trace)?;
    }
    let out = drive_checkpointed(session, evaluator, journal);
    if let Some(e) = journal.error() {
        return Err(CliError::trace(e.clone()));
    }
    Ok(out)
}

/// Run exactly one tuning session (campaign rep 0 of the header's
/// cell), either live against the simulator collector (optionally
/// recording the measurement stream) or replayed from a trace.
fn run_single_session(
    ctx: &ExpCtx,
    header: &TraceHeader,
    record_to: Option<&Path>,
    replay_from: Option<TraceReplayer>,
) -> Result<(), CliError> {
    let (wf, obj, algo) = resolve_header(header)?;
    let prob = Problem::new(wf, obj);
    // The pool regenerates deterministically from the header — replay
    // needs it for selection/feature state, not for measurements.
    let pool = PoolCache::global()
        .try_get_or_generate(&prob, header.pool_size, header.seed, ctx.threads)
        .map_err(|e| CliError::infeasible(format!("cannot build pool for {wf}: {e}")))?;
    // the header pins the scoring backend: replay must score with the
    // backend the session was recorded under
    let scorer = ScorerKind::from_name(&header.scorer)
        .ok_or_else(|| format!("trace scorer '{}' unknown (native|pjrt)", header.scorer))?
        .build();
    let tuner = tuner_for(algo, &prob, header.seed, header.ceal_params);
    let mut rng = session_rng(header.seed, algo, 0);
    let mut col = Collector::new(&prob, rng.derive_str("collector"));
    let mut session = tuner.session(&prob, &pool, &scorer, header.m, &mut rng);
    if header.faults.is_some() {
        // the measurement stream carries failures (live injection or a
        // recorded faulted trace): arm the failure-handling policy
        session.set_failure_policy(FailurePolicy::fault_tolerant());
    }

    let (out, provenance) = match replay_from {
        Some(mut replayer) => {
            let out = drive(session, &mut replayer);
            if let Some(e) = replayer.error() {
                return Err(CliError::trace(e.clone()));
            }
            if replayer.remaining() > 0 {
                return Err(CliError {
                    code: EXIT_TRACE,
                    msg: format!(
                        "replay left {} unconsumed batches — the trace does not match this build",
                        replayer.remaining()
                    ),
                });
            }
            let n = replayer.batches().len();
            (out, format!("replayed {n} batches from trace"))
        }
        None => {
            let path = record_to.expect("live sessions are recorded");
            // composition order matters: the recorder wraps the
            // injector, so the trace captures the *post-fault* stream
            // and replays reproduce the faulted run bit-exactly.  This
            // session is campaign rep 0, so the schedule seed matches
            // the campaign's rep-0 fault stream.
            let (out, n) = match &header.faults {
                Some(spec) => {
                    let mut injector =
                        FaultInjector::new(&mut col, spec.plan, spec.seed_for_rep(0));
                    record_run(&mut injector, session, path, header)?
                }
                None => record_run(&mut col, session, path, header)?,
            };
            (out, format!("recorded {n} batches to {}", path.display()))
        }
    };
    report_session(ctx, header, obj, &pool, &out, &provenance)
}

/// Drive one live session through a [`TraceRecorder`] wrapping `live`,
/// returning the output and the number of batches written.  The trace
/// accumulates in memory and lands via one atomic rename, so a crash
/// mid-session never leaves a torn trace file behind.
fn record_run(
    live: &mut dyn ceal::tuner::Evaluator,
    session: Box<dyn ceal::tuner::TunerSession + '_>,
    path: &Path,
    header: &TraceHeader,
) -> Result<(TunerOutput, u64), String> {
    let mut recorder = TraceRecorder::new(live, Vec::new(), header)
        .map_err(|e| format!("cannot write trace header: {e}"))?;
    let out = drive(session, &mut recorder);
    let n = recorder.batches_written();
    let buf = recorder
        .finish()
        .map_err(|e| format!("trace write failed: {e}"))?;
    ceal::util::fsio::atomic_write(path, &buf)
        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    Ok((out, n))
}

/// Print the single-session outcome and write `session_best.csv` —
/// the file the CI record→replay round-trip compares byte-for-byte.
fn report_session(
    ctx: &ExpCtx,
    header: &TraceHeader,
    obj: Objective,
    pool: &Pool,
    out: &TunerOutput,
    provenance: &str,
) -> Result<(), CliError> {
    let best_cfg = &pool.configs[out.best_idx];
    let best_truth = pool.truth_of(out.best_idx);
    println!(
        "session: {} on {} ({}), m={}, pool={}, seed={}",
        header.algo, header.workflow, header.objective, header.m, header.pool_size, header.seed
    );
    println!("{provenance}");
    println!(
        "best idx {}  config {}  truth {} {}",
        out.best_idx,
        best_cfg,
        fnum(best_truth, 4),
        obj.unit()
    );
    println!(
        "measured {} workflow runs, collection cost {} {}",
        out.workflow_runs,
        fnum(out.collection_cost, 3),
        obj.unit()
    );
    print_cache_stats();
    let mut w = CsvWriter::new(&[
        "algo",
        "workflow",
        "objective",
        "m",
        "pool",
        "seed",
        "best_idx",
        "best_config",
        "best_truth",
        "collection_cost",
        "workflow_runs",
        "failed_runs",
        "measured",
    ]);
    // float cells use shortest-round-trip formatting, so a bitwise
    // identical session yields a byte-identical CSV
    w.row(&[
        header.algo.clone(),
        header.workflow.clone(),
        header.objective.clone(),
        header.m.to_string(),
        header.pool_size.to_string(),
        header.seed.to_string(),
        out.best_idx.to_string(),
        best_cfg.to_string(),
        best_truth.to_string(),
        out.collection_cost.to_string(),
        out.workflow_runs.to_string(),
        out.failed_runs.to_string(),
        out.measured.len().to_string(),
    ]);
    let path = ctx.out_dir.join("session_best.csv");
    w.save(&path)
        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    println!("best CSV -> {}", path.display());
    Ok(())
}

/// `ceal serve`: run the multi-tenant ask/tell daemon until killed.
fn serve_cmd(args: &Args, ctx: &ExpCtx) -> Result<(), CliError> {
    let ttl = match args.opt_secs("session-ttl")? {
        Some(d) => Some(d),
        None if args.flag("no-session-ttl") => None,
        None => Some(ceal::serve::DEFAULT_SESSION_TTL),
    };
    let cfg = ServeConfig {
        addr: args.opt_or("addr", "127.0.0.1:7433").to_string(),
        root: args
            .opt_path("serve-root")
            .unwrap_or_else(|| PathBuf::from("serve")),
        ttl,
        threads: ctx.threads,
    };
    ceal::serve::serve(cfg).map_err(CliError::from)
}

/// A non-finite float crosses the wire as a string; both forms parse
/// back to the exact f64 the server measured.
fn wire_float(v: &Json, key: &str) -> Result<f64, CliError> {
    match v.get(key) {
        Some(Json::Num(x)) => Ok(*x),
        Some(Json::Str(s)) => s
            .parse()
            .map_err(|e| CliError::from(format!("bad '{key}' in finish payload: {e}"))),
        _ => Err(format!("finish payload missing '{key}'").into()),
    }
}

fn wire_usize(v: &Json, key: &str) -> Result<usize, CliError> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("finish payload missing integer '{key}'").into())
}

/// `ceal client`: open (or resume by token) one served session, drive
/// it to completion measuring locally, and write the same
/// `session_best.csv` an equivalent `ceal tune --checkpoint-dir` run
/// would — byte for byte (the CI kill-resume cell `cmp`s the two).
fn client_cmd(args: &Args, ctx: &ExpCtx) -> Result<(), CliError> {
    let addr = args.opt_or("addr", "127.0.0.1:7433");
    let throttle_ms = args.opt_f64("throttle-ms", 0.0)?;
    let throttle = (throttle_ms > 0.0).then(|| Duration::from_secs_f64(throttle_ms / 1000.0));
    let mut client = ServeClient::new(TcpTransport::connect(addr)?);
    let info = match args.opt("token") {
        Some(token) => {
            for flag in ["workflow", "objective", "algo", "m", "pool", "seed", "scorer"] {
                if args.opt(flag).is_some() {
                    return Err(format!(
                        "--{flag} conflicts with --token: the session's journal header pins \
                         the cell settings"
                    )
                    .into());
                }
            }
            client.reopen(token)?
        }
        None => client.open(&OpenSpec {
            workflow: args.opt_or("workflow", "LV").into(),
            objective: args.opt_or("objective", "comp").into(),
            algo: args.opt_or("algo", "ceal").into(),
            m: args.opt_usize("m", 50)?,
            pool_size: ctx.pool_size,
            seed: ctx.seed,
            scorer: ctx.scorer.name().into(),
        })?,
    };
    println!(
        "session {}: {} on {} ({}), m={}, pool={}, seed={}{}",
        info.token,
        info.header.algo,
        info.header.workflow,
        info.header.objective,
        info.header.m,
        info.header.pool_size,
        info.header.seed,
        if info.resumed {
            format!(" — resumed at {} exchanges", info.exchanges)
        } else {
            String::new()
        }
    );
    if let Some(path) = args.opt_path("token-file") {
        std::fs::write(&path, &info.token)
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    }
    // The client-side evaluator is constructed exactly as `ceal tune`
    // rep 0 constructs its collector (same seed, same RNG derivation),
    // then fast-forwarded to the journaled noise position on resume —
    // so the served run is bit-identical to the uninterrupted local
    // one no matter how many times either side restarted.
    let (wf, obj, algo) = resolve_header(&info.header)?;
    let prob = Problem::new(wf, obj);
    let mut rng = session_rng(info.header.seed, algo, 0);
    let mut col = Collector::new(&prob, rng.derive_str("collector"));
    if let Some(eval) = &info.eval {
        col.restore_state(eval);
    }
    let payload = client.drive(&mut col, throttle)?;
    let best_idx = wire_usize(&payload, "best_idx")?;
    let best_config = payload
        .get("best_config")
        .and_then(Json::as_str)
        .ok_or("finish payload missing 'best_config'")?
        .to_string();
    let best_truth = wire_float(&payload, "best_truth")?;
    let collection_cost = wire_float(&payload, "collection_cost")?;
    println!(
        "best idx {best_idx}  config {best_config}  truth {} {}",
        fnum(best_truth, 4),
        obj.unit()
    );
    println!(
        "measured {} workflow runs, collection cost {} {}",
        wire_usize(&payload, "workflow_runs")?,
        fnum(collection_cost, 3),
        obj.unit()
    );
    let mut w = CsvWriter::new(&[
        "algo",
        "workflow",
        "objective",
        "m",
        "pool",
        "seed",
        "best_idx",
        "best_config",
        "best_truth",
        "collection_cost",
        "workflow_runs",
        "failed_runs",
        "measured",
    ]);
    w.row(&[
        info.header.algo.clone(),
        info.header.workflow.clone(),
        info.header.objective.clone(),
        info.header.m.to_string(),
        info.header.pool_size.to_string(),
        info.header.seed.to_string(),
        best_idx.to_string(),
        best_config,
        best_truth.to_string(),
        collection_cost.to_string(),
        wire_usize(&payload, "workflow_runs")?.to_string(),
        wire_usize(&payload, "failed_runs")?.to_string(),
        wire_usize(&payload, "measured")?.to_string(),
    ]);
    let path = ctx.out_dir.join("session_best.csv");
    w.save(&path)
        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    println!("best CSV -> {}", path.display());
    Ok(())
}

/// Pool-cache and refit-amortization counters, printed (never written
/// to a CSV — output files must stay byte-identical run to run) so the
/// once-per-pool invariants are observable without a profiler.  The CI
/// amortization cell greps these lines.
fn print_cache_stats() {
    let cache = PoolCache::global();
    println!(
        "pool cache    : {} pools resident ({} bytes, cap {}), {} hits, {} evictions",
        cache.len(),
        cache.resident_bytes(),
        cache.cap_bytes(),
        cache.total_hits(),
        cache.evictions()
    );
    let c = ceal::gbt::amortization_counters();
    println!(
        "amortization  : pool code builds {}, quantized re-ranks {}, full quantized builds {}, refit skips {}",
        c.pool_code_builds, c.quant_reranks, c.quant_full_builds, c.refit_skips
    );
}

fn info() {
    println!("ceal {} — CEAL in-situ workflow auto-tuning reproduction", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", ceal::runtime::artifacts_dir().display());
    match ceal::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT runtime : OK (platform {})", rt.platform());
            println!("artifact meta: {:?}", rt.meta);
        }
        Err(e) => println!("PJRT runtime : unavailable — {e:#}"),
    }
    let reg = WorkflowRegistry::global();
    println!("workflow registry ({} registered):", reg.len());
    for def in reg.defs() {
        let spec = def.spec();
        let comps: Vec<&str> = def.components.iter().map(|c| c.stage_name).collect();
        let edges: Vec<String> = def
            .edges
            .iter()
            .map(|e| {
                format!(
                    "{}->{}",
                    def.components[e.from].stage_name, def.components[e.to].stage_name
                )
            })
            .collect();
        println!(
            "  {:<4} {} params, space {:.1e}",
            def.name,
            spec.n_params(),
            spec.space_size() as f64
        );
        println!("       components: {}", comps.join(", "));
        println!("       edges     : {}", edges.join(", "));
    }
    println!("algorithm roster ({} registered):", Algo::ALL.len());
    println!("  {}", Algo::names().join(" | "));
    println!("  (+ budgeted CEAL via the library API: BudgetedCeal::run_with_cost_budget)");
    print_cache_stats();
}

fn usage() -> &'static str {
    "usage: ceal <table N | fig N | all | robustness | tune | serve | client | info> [flags]\n(see `ceal` source header or README for flags)"
}
