//! Fig. 12: practicality with historical measurements — least number
//! of uses for ALpH vs CEAL on LV and HS (paper: CEAL recoups its cost
//! after only 219 runs for LV exec m=50 / 269 for LV comp m=25).

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 12 — least number of uses with historical measurements",
        "paper Fig. 12 / §7.5.4",
    );
    let mut t = Table::new(&[
        "workflow", "objective", "m", "algo", "cost", "tuned", "expert", "payoff runs",
    ])
    .align_left(&[0, 1, 3]);
    let mut csv = CsvWriter::new(&[
        "workflow", "objective", "m", "algo", "cost", "tuned", "expert", "payoff_runs",
    ]);
    let cells = [
        (WorkflowId::LV, Objective::ExecTime, 50),
        (WorkflowId::LV, Objective::CompTime, 25),
        (WorkflowId::HS, Objective::ExecTime, 50),
        (WorkflowId::HS, Objective::CompTime, 25),
    ];
    for (wf, obj, m) in cells {
        for algo in [Algo::AlphHist, Algo::CealHist] {
            let agg = ctx.run_cell(algo, wf, obj, m);
            let payoff = agg.payoff_runs();
            t.row(&[
                wf.name().into(),
                obj.name().into(),
                m.to_string(),
                algo.name().into(),
                fnum(agg.mean_cost(), 2),
                fnum(agg.mean_best(), 3),
                fnum(agg.expert_value, 3),
                payoff.map(|p| fnum(p, 0)).unwrap_or("never".into()),
            ]);
            csv.row(&[
                wf.name().into(),
                obj.name().into(),
                m.to_string(),
                algo.name().into(),
                format!("{}", agg.mean_cost()),
                format!("{}", agg.mean_best()),
                format!("{}", agg.expert_value),
                payoff.map(|p| p.to_string()).unwrap_or_default(),
            ]);
        }
    }
    print!("{}", t.render());
    ctx.save_csv("fig12.csv", &csv);
}
