//! Fig. 6: prediction accuracy (MdAPE) of the final surrogate models of
//! RS / AL / CEAL over all pool configurations and over the top 2% —
//! the mechanism behind CEAL's wins (§7.4.2): comparable error overall,
//! much lower error on the top configurations.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub const ALGOS: [Algo; 3] = [Algo::Rs, Algo::Al, Algo::Ceal];

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 6 — model MdAPE: all configs vs top 2%",
        "paper Fig. 6 / §7.4.2: CEAL much more accurate on the top 2%",
    );
    let mut csv = CsvWriter::new(&[
        "workflow",
        "objective",
        "m",
        "algo",
        "mdape_all",
        "mdape_top2",
    ]);
    for obj in Objective::ALL {
        let m = ctx.budgets(obj)[1]; // the largest budget plotted
        let mut t = Table::new(&[
            "workflow", "RS all", "RS top2%", "AL all", "AL top2%", "CEAL all", "CEAL top2%",
        ])
        .align_left(&[0]);
        println!("-- objective={} m={m} (MdAPE, lower is better)", obj.name());
        for wf in WorkflowId::ALL {
            let mut cells = vec![wf.name().to_string()];
            for algo in ALGOS {
                let agg = ctx.run_cell(algo, wf, obj, m);
                cells.push(fnum(agg.mean_mdape_all() * 100.0, 1) + "%");
                cells.push(fnum(agg.mean_mdape_top2() * 100.0, 1) + "%");
                csv.row(&[
                    wf.name().into(),
                    obj.name().into(),
                    m.to_string(),
                    algo.name().into(),
                    format!("{}", agg.mean_mdape_all()),
                    format!("{}", agg.mean_mdape_top2()),
                ]);
            }
            t.row(&cells);
        }
        print!("{}", t.render());
    }
    ctx.save_csv("fig06.csv", &csv);
}
