//! Fig. 11: robustness with historical measurements — recall at
//! top-1..10, ALpH vs CEAL.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 11 — recall with historical measurements (ALpH vs CEAL)",
        "paper Fig. 11: CEAL always more robust; best-1/2 recalls ≥ 99%",
    );
    let mut csv = CsvWriter::new(&["workflow", "objective", "m", "algo", "n", "recall"]);
    for obj in Objective::ALL {
        let m = ctx.budgets(obj)[1];
        for wf in WorkflowId::ALL {
            let mut t = Table::new(&[
                "algo", "top1", "top2", "top3", "top4", "top5", "top6", "top7", "top8", "top9",
                "top10",
            ])
            .align_left(&[0]);
            println!("-- workflow={} objective={} m={m}", wf.name(), obj.name());
            for algo in [Algo::AlphHist, Algo::CealHist] {
                let agg = ctx.run_cell(algo, wf, obj, m);
                let mut cells = vec![algo.name().to_string()];
                for n in 1..=10usize {
                    let r = agg.mean_recall(n);
                    cells.push(fnum(r * 100.0, 0) + "%");
                    csv.row(&[
                        wf.name().into(),
                        obj.name().into(),
                        m.to_string(),
                        algo.name().into(),
                        n.to_string(),
                        format!("{r}"),
                    ]);
                }
                t.row(&cells);
            }
            print!("{}", t.render());
        }
    }
    ctx.save_csv("fig11.csv", &csv);
}
