//! Fig. 10: ALpH vs CEAL (both with historical component measurements)
//! — function-based component combination vs learned combination.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 10 — ALpH vs CEAL (with historical measurements)",
        "paper Fig. 10: CEAL wins every cell (e.g. LV comp -15.1% at m=25)",
    );
    let mut csv = CsvWriter::new(&[
        "workflow",
        "objective",
        "m",
        "algo",
        "norm_best_mean",
        "best_value_mean",
    ]);
    for obj in Objective::ALL {
        for m in ctx.budgets(obj) {
            let mut t = Table::new(&["workflow", "ALpH", "CEAL", "CEAL vs ALpH"]).align_left(&[0]);
            println!("-- objective={} m={m} (normalized best)", obj.name());
            for wf in WorkflowId::ALL {
                let alph = ctx.run_cell(Algo::AlphHist, wf, obj, m);
                let ceal = ctx.run_cell(Algo::CealHist, wf, obj, m);
                let imp = 1.0 - ceal.mean_best() / alph.mean_best();
                t.row(&[
                    wf.name().into(),
                    fnum(alph.mean_norm_best(), 3),
                    fnum(ceal.mean_norm_best(), 3),
                    fnum(imp * 100.0, 1) + "%",
                ]);
                for agg in [&alph, &ceal] {
                    csv.row(&[
                        wf.name().into(),
                        obj.name().into(),
                        m.to_string(),
                        agg.algo.name().into(),
                        format!("{}", agg.mean_norm_best()),
                        format!("{}", agg.mean_best()),
                    ]);
                }
            }
            print!("{}", t.render());
        }
    }
    ctx.save_csv("fig10.csv", &csv);
}
