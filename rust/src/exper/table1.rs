//! Table 1: parameter spaces of the three target workflows.

use crate::config::{ParamValues, WorkflowId};
use crate::util::table::Table;

use super::common::{banner, ExpCtx};
use crate::util::csv::CsvWriter;

fn options_string(values: &ParamValues) -> String {
    match values {
        ParamValues::Range { lo, hi, step } if *step == 1 => format!("{lo}, {}, ..., {hi}", lo + 1),
        ParamValues::Range { lo, hi, step } => format!("{lo}, {}, ..., {hi}", lo + step),
        ParamValues::List(v) => v
            .iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join(", "),
    }
}

pub fn run(ctx: &ExpCtx) {
    banner("Table 1 — parameter spaces", "paper Tbl. 1 and §7.1 space sizes");
    let mut t = Table::new(&["Workflow", "Application", "Parameter", "Options", "Count"])
        .align_left(&[0, 1, 2, 3]);
    let mut csv = CsvWriter::new(&["workflow", "application", "parameter", "options", "count"]);
    for id in WorkflowId::ALL {
        let spec = id.spec();
        for comp in &spec.components {
            if comp.params.is_empty() {
                t.row(&[
                    id.name().into(),
                    comp.name.clone(),
                    "# processes".into(),
                    "1".into(),
                    "1".into(),
                ]);
                csv.row(&[
                    id.name().into(),
                    comp.name.clone(),
                    "# processes".into(),
                    "1".into(),
                    "1".into(),
                ]);
                continue;
            }
            for p in &comp.params {
                t.row(&[
                    id.name().into(),
                    comp.name.clone(),
                    p.name.clone(),
                    options_string(&p.values),
                    p.count().to_string(),
                ]);
                csv.row(&[
                    id.name().into(),
                    comp.name.clone(),
                    p.name.clone(),
                    options_string(&p.values),
                    p.count().to_string(),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!("Joint configuration-space sizes (paper: LV 2.3e10, HS 5.1e10, GP 8.5e7):");
    for id in WorkflowId::ALL {
        let spec = id.spec();
        let comps: Vec<String> = spec
            .components
            .iter()
            .filter(|c| c.is_configurable())
            .map(|c| format!("{}: {:.1e}", c.name, c.space_size() as f64))
            .collect();
        println!(
            "  {:<3} joint {:.1e}   ({})",
            id.name(),
            spec.space_size() as f64,
            comps.join(", ")
        );
    }
    ctx.save_csv("table1.csv", &csv);
}
