//! Ablation studies for the design choices DESIGN.md calls out — not
//! in the paper, but they quantify why the implementation is built the
//! way it is:
//!
//!  * **switch policy** — CEAL's dynamic model switch (Alg. 1 lines
//!    16-21) vs never switching (always low-fidelity selection) vs
//!    switching immediately (always high-fidelity = AL with a lowfi
//!    first batch);
//!  * **cost-budget mode** — run-count CEAL vs the §6 resource-budgeted
//!    variant given the same expected spend;
//!  * **combination function** — the objective-matched function
//!    (max for exec, sum for comp) vs the mismatched one, validating
//!    the paper's §4 function-selection rule.

use crate::config::WorkflowId;
use crate::coordinator::historical_samples;
use crate::metrics::recall_score;
use crate::sim::Objective;
use crate::surrogate::lowfi::LowFiModel;
use crate::surrogate::Scorer;
use crate::tuner::ceal::gbt_params_for;
use crate::tuner::{BudgetedCeal, BudgetedCealParams, Ceal, CealParams, Problem, Tuner};
use crate::util::csv::CsvWriter;
use crate::util::rng::Pcg32;
use crate::util::stats;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Ablations — switch policy, budget mode, combination function",
        "DESIGN.md design-choice studies (extensions beyond the paper)",
    );
    let mut csv = CsvWriter::new(&["study", "variant", "workflow", "objective", "value"]);
    switch_policy(ctx, &mut csv);
    budget_mode(ctx, &mut csv);
    combination_function(ctx, &mut csv);
    ctx.save_csv("ablations.csv", &csv);
}

/// Run CEAL with a fixed switch policy by overriding iterations: we
/// emulate "never switch" with iterations=1 variants handled inline.
fn switch_policy(ctx: &ExpCtx, csv: &mut CsvWriter) {
    println!("-- switch policy (LV comp, m=50, normalized best)");
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = ctx.shared_pool(&prob, ctx.pool_size, ctx.seed);
    let scorer = ctx.scorer.build();
    let mut t = Table::new(&["variant", "normalized best"]).align_left(&[0]);
    for (name, params) in [
        ("dynamic switch (CEAL)", CealParams::no_hist()),
        // one iteration: every guided batch is chosen by the lowfi model
        // and the hifi model only does the final search ("never switch")
        (
            "never switch (I=1)",
            CealParams {
                iterations: 1,
                ..CealParams::no_hist()
            },
        ),
        // no component budget: hifi from the start ("switch immediately")
        (
            "immediate hifi (m_R=0)",
            CealParams {
                mr_frac: 0.0,
                m0_frac: 0.25,
                ..CealParams::no_hist()
            },
        ),
    ] {
        let vals: Vec<f64> = (0..ctx.reps)
            .map(|rep| {
                let mut rng = Pcg32::new(ctx.seed ^ 0xAB1, rep as u64);
                let out = Ceal::new(params).run(&prob, &pool, &scorer, 50, &mut rng);
                pool.truth_of(out.best_idx) / pool.best_value()
            })
            .collect();
        let mean = stats::mean(&vals);
        t.row(&[name.into(), fnum(mean, 3)]);
        csv.row(&[
            "switch_policy".into(),
            name.into(),
            "LV".into(),
            "comp_time".into(),
            format!("{mean}"),
        ]);
    }
    print!("{}", t.render());
}

fn budget_mode(ctx: &ExpCtx, csv: &mut CsvWriter) {
    println!("-- budget mode (LV comp): run-count m=50 vs equal cost budget");
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = ctx.shared_pool(&prob, ctx.pool_size, ctx.seed);
    let scorer = ctx.scorer.build();
    // measure run-count CEAL's average spend, then grant the budgeted
    // variant the same amount
    let mut spend = Vec::new();
    let mut count_vals = Vec::new();
    for rep in 0..ctx.reps {
        let mut rng = Pcg32::new(ctx.seed ^ 0xAB2, rep as u64);
        let out = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &scorer, 50, &mut rng);
        spend.push(out.collection_cost);
        count_vals.push(pool.truth_of(out.best_idx) / pool.best_value());
    }
    let budget = stats::mean(&spend);
    let budgeted_vals: Vec<f64> = (0..ctx.reps)
        .map(|rep| {
            let mut rng = Pcg32::new(ctx.seed ^ 0xAB3, rep as u64);
            let out = BudgetedCeal::new(BudgetedCealParams::default()).run_with_cost_budget(
                &prob, &pool, &scorer, budget, &mut rng,
            );
            pool.truth_of(out.best_idx) / pool.best_value()
        })
        .collect();
    let mut t = Table::new(&["variant", "normalized best", "budget (core-h)"]).align_left(&[0]);
    t.row(&[
        "run-count CEAL (m=50)".into(),
        fnum(stats::mean(&count_vals), 3),
        fnum(budget, 1),
    ]);
    t.row(&[
        "cost-budgeted CEAL (§6)".into(),
        fnum(stats::mean(&budgeted_vals), 3),
        fnum(budget, 1),
    ]);
    print!("{}", t.render());
    csv.row(&[
        "budget_mode".into(),
        "run_count".into(),
        "LV".into(),
        "comp_time".into(),
        format!("{}", stats::mean(&count_vals)),
    ]);
    csv.row(&[
        "budget_mode".into(),
        "cost_budgeted".into(),
        "LV".into(),
        "comp_time".into(),
        format!("{}", stats::mean(&budgeted_vals)),
    ]);
}

/// §4's function-selection rule: using the mismatched combination
/// function should hurt the low-fidelity model's recall.
fn combination_function(ctx: &ExpCtx, csv: &mut CsvWriter) {
    println!("-- combination function (low-fi recall@10 on 500-config pools)");
    let mut t = Table::new(&["workflow", "objective", "matched fn", "mismatched fn"])
        .align_left(&[0, 1]);
    let scorer = ctx.scorer.build();
    for wf in WorkflowId::ALL {
        for obj in Objective::ALL {
            let prob = Problem::new(wf, obj);
            let pool = ctx.shared_pool(&prob, 500, ctx.seed ^ 0xAB4);
            let hist = historical_samples(&prob, 500, ctx.seed ^ 0x415);
            let nf = prob.n_component_features();
            let lf = LowFiModel::fit(&hist, &nf, obj, &gbt_params_for(500));
            let matched = recall_score(10, &lf.score(&pool.feats, &scorer), pool.truth());
            // mismatched: swap the combination function
            let other = match obj {
                Objective::ExecTime => Objective::CompTime,
                Objective::CompTime => Objective::ExecTime,
            };
            let swapped = LowFiModel {
                comps: lf.comps.clone(),
                objective: other,
            };
            let mismatched = recall_score(10, &swapped.score(&pool.feats, &scorer), pool.truth());
            t.row(&[
                wf.name().into(),
                obj.name().into(),
                fnum(matched * 100.0, 0) + "%",
                fnum(mismatched * 100.0, 0) + "%",
            ]);
            for (variant, v) in [("matched", matched), ("mismatched", mismatched)] {
                csv.row(&[
                    "combination_fn".into(),
                    variant.into(),
                    wf.name().into(),
                    obj.name().into(),
                    format!("{v}"),
                ]);
            }
        }
    }
    print!("{}", t.render());
}
