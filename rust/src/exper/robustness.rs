//! Robustness sweep: tuned-quality degradation under measurement
//! faults.  Every registered algorithm runs the LV/comp-time cell
//! under increasing failure rates (crash/transport + timeout + the
//! plan's light straggler/corruption tail — see
//! [`FaultPlan::transient`]), with the fault-tolerant failure policy
//! armed.  The headline artifact `robustness_degradation.csv` plots
//! normalized tuned quality and collection cost (including retry
//! charges) against the fault rate.
//!
//! Not a paper figure: the paper assumes reliable measurements; this
//! sweep characterizes how gracefully each algorithm degrades when
//! that assumption breaks.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::tuner::{FaultPlan, FaultSpec};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

/// Failure probabilities swept (timeouts ride along at a quarter of
/// each rate, matching the CLI's transient plan shape).
pub const FAIL_RATES: [f64; 5] = [0.0, 0.05, 0.1, 0.2, 0.3];

pub fn run(ctx: &ExpCtx) {
    banner(
        "Robustness — tuned quality vs measurement-failure rate",
        "fault-tolerance study (no paper counterpart)",
    );
    let (wf, obj, m) = (WorkflowId::LV, Objective::CompTime, 25);
    let mut t = Table::new(&[
        "algo", "p_fail", "norm best", "cost", "failed/rep", "recall@1",
    ])
    .align_left(&[0]);
    let mut csv = CsvWriter::new(&[
        "workflow",
        "objective",
        "m",
        "algo",
        "p_fail",
        "p_timeout",
        "norm_best",
        "cost",
        "failed_runs_mean",
        "recall1",
        "mdape_all",
    ]);
    for algo in Algo::ALL {
        for rate in FAIL_RATES {
            let p_timeout = rate / 4.0;
            let mut campaign = ctx.campaign(wf, obj, m);
            if rate > 0.0 {
                campaign = campaign.with_faults(FaultSpec {
                    plan: FaultPlan::transient(rate, p_timeout),
                    // decouple the fault schedule from every other
                    // seed consumer at this cell
                    seed: ctx.seed ^ 0xFA17,
                });
            }
            let agg = crate::coordinator::run_campaign(algo, &campaign);
            let failed_mean = stats::mean(
                &agg.reps
                    .iter()
                    .map(|r| r.failed_runs as f64)
                    .collect::<Vec<_>>(),
            );
            t.row(&[
                algo.name().into(),
                fnum(rate, 2),
                fnum(agg.mean_norm_best(), 3),
                fnum(agg.mean_cost(), 2),
                fnum(failed_mean, 1),
                fnum(agg.mean_recall(1), 2),
            ]);
            csv.row(&[
                wf.name().into(),
                obj.name().into(),
                m.to_string(),
                algo.name().into(),
                rate.to_string(),
                p_timeout.to_string(),
                format!("{}", agg.mean_norm_best()),
                format!("{}", agg.mean_cost()),
                format!("{failed_mean}"),
                format!("{}", agg.mean_recall(1)),
                format!("{}", agg.mean_mdape_all()),
            ]);
        }
    }
    print!("{}", t.render());
    ctx.save_csv("robustness_degradation.csv", &csv);
}
