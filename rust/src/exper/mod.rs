//! Experiment harness: one module per paper table/figure (§7).  Each
//! prints the paper-style rows/series to stdout and writes a CSV under
//! the output directory; EXPERIMENTS.md records paper-vs-measured.

pub mod ablations;
pub mod common;
pub mod fig04;
pub mod fig05;
pub mod fig06;
pub mod fig07;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod robustness;
pub mod table1;
pub mod table2;

pub use common::ExpCtx;

/// Run every table and figure (the `ceal all` / `make repro` target).
pub fn run_all(ctx: &ExpCtx) {
    table1::run(ctx);
    table2::run(ctx);
    fig04::run(ctx);
    fig05::run(ctx);
    fig06::run(ctx);
    fig07::run(ctx);
    fig08::run(ctx);
    fig09::run(ctx);
    fig10::run(ctx);
    fig11::run(ctx);
    fig12::run(ctx);
    fig13::run(ctx);
    ablations::run(ctx);
    robustness::run(ctx);
}

/// Dispatch a single figure by number.
pub fn run_fig(n: usize, ctx: &ExpCtx) -> bool {
    match n {
        4 => fig04::run(ctx),
        5 => fig05::run(ctx),
        6 => fig06::run(ctx),
        7 => fig07::run(ctx),
        8 => fig08::run(ctx),
        9 => fig09::run(ctx),
        10 => fig10::run(ctx),
        11 => fig11::run(ctx),
        12 => fig12::run(ctx),
        13 => fig13::run(ctx),
        _ => return false,
    }
    true
}

/// Dispatch a single table by number.
pub fn run_table(n: usize, ctx: &ExpCtx) -> bool {
    match n {
        1 => table1::run(ctx),
        2 => table2::run(ctx),
        _ => return false,
    }
    true
}
