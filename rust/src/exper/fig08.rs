//! Fig. 8: practicality without historical measurements — the least
//! number of workflow runs needed to pay off the auto-tuning cost
//! (§7.2.3), AL vs CEAL, computer time, m = 50, LV and HS.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 8 — least number of uses (AL vs CEAL, comp time, m=50)",
        "paper Fig. 8: CEAL pays off ~40% sooner (864 vs 1444 on LV)",
    );
    let m = 50;
    let mut t = Table::new(&["workflow", "algo", "cost (core-h)", "tuned", "expert", "payoff runs"])
        .align_left(&[0, 1]);
    let mut csv = CsvWriter::new(&["workflow", "algo", "cost", "tuned", "expert", "payoff_runs"]);
    for wf in [WorkflowId::LV, WorkflowId::HS] {
        for algo in [Algo::Al, Algo::Ceal] {
            let agg = ctx.run_cell(algo, wf, Objective::CompTime, m);
            let payoff = agg.payoff_runs();
            let payoff_str = payoff.map(|p| fnum(p, 0)).unwrap_or("never".into());
            t.row(&[
                wf.name().into(),
                algo.name().into(),
                fnum(agg.mean_cost(), 2),
                fnum(agg.mean_best(), 3),
                fnum(agg.expert_value, 3),
                payoff_str.clone(),
            ]);
            csv.row(&[
                wf.name().into(),
                algo.name().into(),
                format!("{}", agg.mean_cost()),
                format!("{}", agg.mean_best()),
                format!("{}", agg.expert_value),
                payoff.map(|p| p.to_string()).unwrap_or_default(),
            ]);
        }
    }
    print!("{}", t.render());
    ctx.save_csv("fig08.csv", &csv);
}
