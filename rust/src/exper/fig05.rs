//! Fig. 5: actual performance of the best configuration predicted by
//! RS / GEIST / AL / CEAL without historical measurements, normalized
//! by the test-set optimum, for each workflow × objective × budget.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub const ALGOS: [Algo; 4] = [Algo::Rs, Algo::Geist, Algo::Al, Algo::Ceal];

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 5 — tuned performance w/o historical measurements",
        "paper Fig. 5: CEAL beats RS/GEIST/AL at every cell",
    );
    let mut csv = CsvWriter::new(&[
        "workflow",
        "objective",
        "m",
        "algo",
        "norm_best_mean",
        "best_value_mean",
        "pool_best",
    ]);
    for obj in Objective::ALL {
        for m in ctx.budgets(obj) {
            let mut t = Table::new(&["workflow", "RS", "GEIST", "AL", "CEAL"]).align_left(&[0]);
            println!("-- objective={} m={m} (normalized best; 1.0 = pool optimum)", obj.name());
            for wf in WorkflowId::ALL {
                let mut cells = vec![wf.name().to_string()];
                for algo in ALGOS {
                    let agg = ctx.run_cell(algo, wf, obj, m);
                    cells.push(fnum(agg.mean_norm_best(), 3));
                    csv.row(&[
                        wf.name().into(),
                        obj.name().into(),
                        m.to_string(),
                        algo.name().into(),
                        format!("{}", agg.mean_norm_best()),
                        format!("{}", agg.mean_best()),
                        format!("{}", agg.pool_best),
                    ]);
                }
                t.row(&cells);
            }
            print!("{}", t.render());
        }
    }
    ctx.save_csv("fig05.csv", &csv);
}
