//! Fig. 4: recall of the *low-fidelity* models (Eqns 1-2) when scoring
//! 500 random LV configurations, vs random selection.

use crate::config::WorkflowId;
use crate::coordinator::historical_samples;
use crate::metrics::recall_score;
use crate::sim::Objective;
use crate::surrogate::LowFiModel;
use crate::tuner::ceal::gbt_params_for;
use crate::tuner::Problem;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

/// Test-set size used by the paper's Fig. 4.
pub const FIG4_POOL: usize = 500;
pub const TOP_NS: [usize; 5] = [5, 10, 15, 20, 25];

pub struct Fig4Row {
    pub objective: Objective,
    pub n: usize,
    pub lowfi_recall: f64,
    pub random_recall: f64,
}

pub fn compute(ctx: &ExpCtx) -> Vec<Fig4Row> {
    let scorer = ctx.scorer.build();
    let mut out = Vec::new();
    for obj in Objective::ALL {
        let prob = Problem::new(WorkflowId::LV, obj);
        let pool = ctx.shared_pool(&prob, FIG4_POOL, ctx.seed ^ 0xF14);
        let hist = historical_samples(&prob, 500, ctx.seed ^ 0x415);
        let n_feats = prob.n_component_features();
        let lf = LowFiModel::fit(&hist, &n_feats, obj, &gbt_params_for(500));
        let scores = lf.score(&pool.feats, &scorer);
        for n in TOP_NS {
            out.push(Fig4Row {
                objective: obj,
                n,
                lowfi_recall: recall_score(n, &scores, pool.truth()),
                // expected recall of uniformly random ranking
                random_recall: n as f64 / pool.len() as f64,
            });
        }
    }
    out
}

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 4 — low-fidelity model recall on LV",
        "paper Fig. 4: recall > 30% for top 5..25, far above random",
    );
    let rows = compute(ctx);
    let mut t = Table::new(&["objective", "top-n", "low-fi recall", "random recall"])
        .align_left(&[0]);
    let mut csv = CsvWriter::new(&["objective", "n", "lowfi_recall", "random_recall"]);
    for r in &rows {
        t.row(&[
            r.objective.name().into(),
            r.n.to_string(),
            fnum(r.lowfi_recall * 100.0, 1) + "%",
            fnum(r.random_recall * 100.0, 1) + "%",
        ]);
        csv.row(&[
            r.objective.name().into(),
            r.n.to_string(),
            format!("{}", r.lowfi_recall),
            format!("{}", r.random_recall),
        ]);
    }
    print!("{}", t.render());
    ctx.save_csv("fig04.csv", &csv);
}
