//! Fig. 9: effect of historical component measurements on CEAL — with
//! history the m_R charge disappears, freeing budget for workflow runs.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 9 — CEAL with vs without historical measurements",
        "paper Fig. 9: history improves every cell (e.g. LV comp -10% at m=25)",
    );
    let mut csv = CsvWriter::new(&[
        "workflow",
        "objective",
        "m",
        "variant",
        "norm_best_mean",
        "best_value_mean",
    ]);
    for obj in Objective::ALL {
        for m in ctx.budgets(obj) {
            let mut t =
                Table::new(&["workflow", "CEAL w/o hist", "CEAL w/ hist", "improvement"])
                    .align_left(&[0]);
            println!("-- objective={} m={m} (normalized best)", obj.name());
            for wf in WorkflowId::ALL {
                let without = ctx.run_cell(Algo::Ceal, wf, obj, m);
                let with = ctx.run_cell(Algo::CealHist, wf, obj, m);
                let imp = 1.0 - with.mean_best() / without.mean_best();
                t.row(&[
                    wf.name().into(),
                    fnum(without.mean_norm_best(), 3),
                    fnum(with.mean_norm_best(), 3),
                    fnum(imp * 100.0, 1) + "%",
                ]);
                for (variant, agg) in [("no_hist", &without), ("hist", &with)] {
                    csv.row(&[
                        wf.name().into(),
                        obj.name().into(),
                        m.to_string(),
                        variant.into(),
                        format!("{}", agg.mean_norm_best()),
                        format!("{}", agg.mean_best()),
                    ]);
                }
            }
            print!("{}", t.render());
        }
    }
    ctx.save_csv("fig09.csv", &csv);
}
