//! Table 2: best (pool) vs expert-recommended configurations and their
//! achieved performance, per workflow and objective.

use crate::config::WorkflowId;
use crate::coordinator::expert_config;
use crate::sim::Objective;
use crate::tuner::Problem;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

pub fn run(ctx: &ExpCtx) {
    banner(
        "Table 2 — best vs expert configurations",
        "paper Tbl. 2 (magnitudes from our simulator substitute)",
    );
    let mut t = Table::new(&["Wf", "Objective", "Option", "Performance", "Configuration"])
        .align_left(&[0, 1, 2, 4]);
    let mut csv = CsvWriter::new(&["workflow", "objective", "option", "value", "unit", "config"]);
    for id in WorkflowId::ALL {
        for obj in Objective::ALL {
            let prob = Problem::new(id, obj);
            // same cell key as every campaign at this (wf, obj, seed):
            // the cache makes this table free after any figure ran
            let pool = ctx.shared_pool(&prob, ctx.pool_size, ctx.seed);
            let best_cfg = &pool.configs[pool.best_idx()];
            let best_val = pool.best_value();
            let exp_cfg = expert_config(id, obj);
            let exp_val = obj.value(&prob.sim.expected(&exp_cfg));
            for (option, val, cfg) in [
                ("Best", best_val, best_cfg.to_string()),
                ("Expert", exp_val, exp_cfg.to_string()),
            ] {
                t.row(&[
                    id.name().into(),
                    obj.name().into(),
                    option.into(),
                    format!("{} {}", fnum(val, 3), obj.unit()),
                    cfg.clone(),
                ]);
                csv.row(&[
                    id.name().into(),
                    obj.name().into(),
                    option.into(),
                    format!("{val}"),
                    obj.unit().into(),
                    cfg,
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "Paper reference rows: LV exec 27.2/36.8 s, LV comp 3.36/4.15 core-h, \
         HS exec 6.02/28.0 s, HS comp 0.517/0.894 core-h, GP exec 98.7/102 s, \
         GP comp 6.95/5.85 core-h (expert better for GP comp)."
    );
    ctx.save_csv("table2.csv", &csv);
}
