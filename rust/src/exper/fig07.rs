//! Fig. 7: robustness without historical measurements — recall of the
//! final models at top-1..10 for RS / GEIST / AL / CEAL.

use crate::config::WorkflowId;

use crate::sim::Objective;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};
use super::fig05::ALGOS;

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 7 — recall at top-1..10 w/o historical measurements",
        "paper Fig. 7: CEAL's top-1 recall dominates (e.g. 76-79% on LV)",
    );
    let mut csv = CsvWriter::new(&["workflow", "objective", "m", "algo", "n", "recall"]);
    for obj in Objective::ALL {
        let m = ctx.budgets(obj)[1];
        for wf in WorkflowId::ALL {
            let mut t = Table::new(&[
                "algo", "top1", "top2", "top3", "top4", "top5", "top6", "top7", "top8", "top9",
                "top10",
            ])
            .align_left(&[0]);
            println!("-- workflow={} objective={} m={m}", wf.name(), obj.name());
            for algo in ALGOS {
                let agg = ctx.run_cell(algo, wf, obj, m);
                let mut cells = vec![algo.name().to_string()];
                for n in 1..=10usize {
                    let r = agg.mean_recall(n);
                    cells.push(fnum(r * 100.0, 0) + "%");
                    csv.row(&[
                        wf.name().into(),
                        obj.name().into(),
                        m.to_string(),
                        algo.name().into(),
                        n.to_string(),
                        format!("{r}"),
                    ]);
                }
                t.row(&cells);
            }
            print!("{}", t.render());
        }
    }
    ctx.save_csv("fig07.csv", &csv);
}
