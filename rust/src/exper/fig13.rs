//! Fig. 13: hyper-parameter sensitivity on LV computer time with
//! m = 50 — (a) iterations I, (b) component budget m_R/m, (c) random
//! bootstrap m_0/m; with and without historical measurements.

use crate::config::WorkflowId;
use crate::coordinator::Algo;
use crate::sim::Objective;
use crate::tuner::CealParams;
use crate::util::csv::CsvWriter;
use crate::util::table::{fnum, Table};

use super::common::{banner, ExpCtx};

const WF: WorkflowId = WorkflowId::LV;
const OBJ: Objective = Objective::CompTime;
const M: usize = 50;

pub fn run(ctx: &ExpCtx) {
    banner(
        "Figure 13 — CEAL hyper-parameter sensitivity (LV comp, m=50)",
        "paper Fig. 13: converges after ~3 iterations; flat over wide m_R, m_0 ranges",
    );
    let mut csv = CsvWriter::new(&["panel", "variant", "param", "value", "comp_time_core_h"]);

    // (a) iterations I, both variants (paper: w/o hist m_R=0.5m; w/ hist m_R=0)
    {
        let mut t = Table::new(&["I", "CEAL w/o hist", "CEAL w/ hist"]);
        for i in 1..=10usize {
            let no = ctx.run_cell_params(
                Algo::Ceal,
                WF,
                OBJ,
                M,
                CealParams {
                    iterations: i,
                    m0_frac: 0.15,
                    mr_frac: 0.5,
                },
            );
            let with = ctx.run_cell_params(
                Algo::CealHist,
                WF,
                OBJ,
                M,
                CealParams {
                    iterations: i,
                    m0_frac: 0.3,
                    mr_frac: 0.0,
                },
            );
            t.row(&[
                i.to_string(),
                fnum(no.mean_best(), 3),
                fnum(with.mean_best(), 3),
            ]);
            csv.row(&["a".into(), "no_hist".into(), "I".into(), i.to_string(),
                format!("{}", no.mean_best())]);
            csv.row(&["a".into(), "hist".into(), "I".into(), i.to_string(),
                format!("{}", with.mean_best())]);
        }
        println!("-- (a) iterations I");
        print!("{}", t.render());
    }

    // (b) m_R / m sweep (only meaningful without history), m0 = 5% m
    {
        let mut t = Table::new(&["m_R/m", "CEAL w/o hist"]);
        let mut frac = 0.05;
        while frac <= 0.90 + 1e-9 {
            let agg = ctx.run_cell_params(
                Algo::Ceal,
                WF,
                OBJ,
                M,
                CealParams {
                    iterations: 6,
                    m0_frac: 0.05,
                    mr_frac: frac,
                },
            );
            t.row(&[fnum(frac * 100.0, 0) + "%", fnum(agg.mean_best(), 3)]);
            csv.row(&["b".into(), "no_hist".into(), "mr_frac".into(),
                format!("{frac:.2}"), format!("{}", agg.mean_best())]);
            frac += 0.10;
        }
        println!("-- (b) m_R / m (I=6, m_0=5% m)");
        print!("{}", t.render());
    }

    // (c) m_0 / m sweep, both variants (I=9, m_R=0 paper caption for hist)
    {
        let mut t = Table::new(&["m_0/m", "CEAL w/o hist", "CEAL w/ hist"]);
        let mut frac = 0.05;
        while frac <= 0.75 + 1e-9 {
            let no = ctx.run_cell_params(
                Algo::Ceal,
                WF,
                OBJ,
                M,
                CealParams {
                    iterations: 6,
                    m0_frac: frac,
                    mr_frac: (1.0 - frac - 0.1).max(0.0).min(0.35),
                },
            );
            let with = ctx.run_cell_params(
                Algo::CealHist,
                WF,
                OBJ,
                M,
                CealParams {
                    iterations: 9,
                    m0_frac: frac,
                    mr_frac: 0.0,
                },
            );
            t.row(&[
                fnum(frac * 100.0, 0) + "%",
                fnum(no.mean_best(), 3),
                fnum(with.mean_best(), 3),
            ]);
            csv.row(&["c".into(), "no_hist".into(), "m0_frac".into(),
                format!("{frac:.2}"), format!("{}", no.mean_best())]);
            csv.row(&["c".into(), "hist".into(), "m0_frac".into(),
                format!("{frac:.2}"), format!("{}", with.mean_best())]);
            frac += 0.10;
        }
        println!("-- (c) m_0 / m");
        print!("{}", t.render());
    }

    ctx.save_csv("fig13.csv", &csv);
}
