//! Shared experiment-harness context and helpers.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::WorkflowId;
use crate::coordinator::{run_campaign, shared_pool, Aggregate, Algo, Campaign, ScorerKind};
use crate::sim::Objective;
use crate::tuner::{CealParams, Pool, Problem};
use crate::util::csv::CsvWriter;

/// Experiment configuration (CLI-controlled).
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub out_dir: PathBuf,
    /// Repetitions per campaign cell (paper: 100).
    pub reps: usize,
    /// Pool size (paper: 2000).
    pub pool_size: usize,
    pub seed: u64,
    pub threads: usize,
    pub scorer: ScorerKind,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            out_dir: PathBuf::from("results"),
            reps: 40,
            pool_size: crate::tuner::common::POOL_SIZE,
            seed: 0xCEA1,
            threads: crate::coordinator::campaign::default_threads(),
            scorer: ScorerKind::Native,
        }
    }
}

impl ExpCtx {
    /// Budgets plotted per objective (paper Fig. 5: m doubled from 25;
    /// the two largest shown are 50/100 for exec and 25/50 for comp).
    pub fn budgets(&self, objective: Objective) -> [usize; 2] {
        match objective {
            Objective::ExecTime => [50, 100],
            Objective::CompTime => [25, 50],
        }
    }

    /// Build a campaign for a cell.  Carries this context's seed so
    /// `--seed` reaches campaign cells and their pool-cache key matches
    /// the non-campaign consumers of the same cell (table2, fig04,
    /// ablations).
    pub fn campaign(&self, wf: WorkflowId, obj: Objective, m: usize) -> Campaign {
        Campaign::new(wf, obj, m)
            .with_seed(self.seed)
            .with_reps(self.reps)
            .with_pool_size(self.pool_size)
            .with_scorer(self.scorer)
            .with_threads(self.threads)
    }

    /// Run one (algo, workflow, objective, m) cell.
    pub fn run_cell(&self, algo: Algo, wf: WorkflowId, obj: Objective, m: usize) -> Aggregate {
        run_campaign(algo, &self.campaign(wf, obj, m))
    }

    /// Fetch a ground-truth pool from the process-wide cache (built on
    /// first use with this context's worker threads, then shared with
    /// every campaign/figure at the same cell).  Pools are immutable —
    /// see the sharing contract on [`crate::coordinator::PoolCache`].
    pub fn shared_pool(&self, prob: &Problem, size: usize, seed: u64) -> Arc<Pool> {
        shared_pool(prob, size, seed, self.threads)
    }

    /// Run a cell with overridden CEAL hyper-parameters (Fig. 13).
    pub fn run_cell_params(
        &self,
        algo: Algo,
        wf: WorkflowId,
        obj: Objective,
        m: usize,
        params: CealParams,
    ) -> Aggregate {
        run_campaign(algo, &self.campaign(wf, obj, m).with_ceal_params(params))
    }

    /// Write a CSV into the output directory.
    pub fn save_csv(&self, name: &str, csv: &CsvWriter) {
        let path: &Path = &self.out_dir.join(name);
        csv.save(path)
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        println!("  -> wrote {}", path.display());
    }
}

/// Header banner for an experiment.
pub fn banner(title: &str, paper_ref: &str) {
    println!();
    println!("==== {title} ====");
    println!("     (reproduces {paper_ref})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_per_objective() {
        let ctx = ExpCtx::default();
        assert_eq!(ctx.budgets(Objective::ExecTime), [50, 100]);
        assert_eq!(ctx.budgets(Objective::CompTime), [25, 50]);
    }

    #[test]
    fn campaign_carries_ctx() {
        let mut ctx = ExpCtx::default();
        ctx.reps = 3;
        ctx.pool_size = 99;
        let c = ctx.campaign(WorkflowId::LV, Objective::ExecTime, 25);
        assert_eq!(c.reps, 3);
        assert_eq!(c.pool_size, 99);
        assert_eq!(c.m, 25);
    }
}
