//! Built-in workflow definition tables.
//!
//! The paper trio (LV / HS / GP) wires the analytic component models
//! under [`apps`](super::apps) onto the Table 1 parameter spaces from
//! [`config::spaces`](crate::config::spaces); the synthetic scenario
//! families (CH5 / DM4) are self-contained — spec, profiles, and
//! topology all declared here.  Each definition is one table entry;
//! nothing else in the codebase names these workflows.
//!
//! Adding a workflow = writing one more `WorkflowDef` (see the
//! repository README, "Adding a workflow") and registering it.

use super::apps::{grayscott, heat, lammps, pdfcalc, plots, stagewrite, voro};
use super::apps::{ConsumerProfile, SourceProfile};
use super::machine::Machine;
use super::registry::{
    BufferRule, ComponentDef, EdgeDef, IsoRun, StageProfile, Upstream, WorkflowDef,
};
use crate::config::{gp_spec, hs_spec, lv_spec, ComponentSpec, ParamDef};

/// Canonical chunk counts for isolated consumer runs (the producer's
/// cadence is not part of a consumer's own configuration — this is
/// precisely the approximation that keeps component models low-fidelity).
pub const ISO_CHUNKS_VORO: usize = 8;
pub const ISO_CHUNKS_STAGEWRITE: usize = 8;
pub const ISO_CHUNKS_PDF: usize = 10;
pub const ISO_CHUNKS_CH5: usize = 8;
pub const ISO_CHUNKS_DM4: usize = 8;

/// Every definition the global registry pre-registers.
pub(crate) fn builtin_defs() -> Vec<WorkflowDef> {
    vec![lv_def(), hs_def(), gp_def(), ch5_def(), dm4_def()]
}

fn source(p: SourceProfile) -> StageProfile {
    StageProfile {
        t_chunk_s: p.t_chunk_s,
        n_chunks: p.n_chunks,
        bytes_out: p.bytes_per_chunk,
        nodes: p.nodes,
    }
}

fn consumer(p: ConsumerProfile) -> StageProfile {
    StageProfile {
        t_chunk_s: p.t_chunk_s,
        n_chunks: 0,
        bytes_out: p.bytes_per_chunk_out,
        nodes: p.nodes,
    }
}

/// Allocation rule for the common `[procs, ppn, ...]` parameter prefix.
fn nodes_procs_ppn(cfg: &[i64], m: &Machine) -> u64 {
    m.nodes_for(cfg[0], cfg[1])
}

/// Allocation rule for HS's 2-D grid prefix `[px, py, ppn, ...]`.
fn nodes_grid_ppn(cfg: &[i64], m: &Machine) -> u64 {
    m.nodes_for(cfg[0] * cfg[1], cfg[2])
}

/// Fixed components that colocate with another allocation.
fn nodes_colocated(_cfg: &[i64], _m: &Machine) -> u64 {
    0
}

// ---------------------------------------------------------------- LV --

fn lammps_profile(cfg: &[i64], _up: Upstream, m: &Machine) -> StageProfile {
    source(lammps::profile(cfg, m))
}

fn voro_profile(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    consumer(voro::profile(cfg, up.bytes, m))
}

/// LV: LAMMPS molecular dynamics streaming frames to Voro++.
pub fn lv_def() -> WorkflowDef {
    let mut specs = lv_spec().components.into_iter();
    WorkflowDef {
        name: "LV",
        components: vec![
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "LAMMPS",
                profile: lammps_profile,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Source,
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Voro++",
                profile: voro_profile,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: lammps::N_ATOMS * lammps::BYTES_PER_ATOM,
                    chunks: ISO_CHUNKS_VORO,
                },
            },
        ],
        edges: vec![EdgeDef::staged(0, 1)],
        expert_exec: vec![288, 18, 2, 400, 288, 18, 2],
        expert_comp: vec![18, 18, 2, 400, 18, 18, 2],
    }
}

// ---------------------------------------------------------------- HS --

fn heat_profile(cfg: &[i64], _up: Upstream, m: &Machine) -> StageProfile {
    source(heat::profile(cfg, m))
}

fn stagewrite_profile(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    consumer(stagewrite::profile(cfg, up.bytes, m))
}

/// HS's staging channel: depth and efficiency follow the Heat Transfer
/// `buffer_mb` parameter (index 4 of the producer's slice).
fn hs_buffer_rule(h: &[i64]) -> BufferRule {
    BufferRule {
        xfer_divisor: heat::buffer_efficiency(h[4]),
        capacity: heat::buffer_slots(h[4]),
    }
}

/// HS: Heat Transfer snapshots forwarded to Stage Write.
pub fn hs_def() -> WorkflowDef {
    let mut specs = hs_spec().components.into_iter();
    WorkflowDef {
        name: "HS",
        components: vec![
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "HeatTransfer",
                profile: heat_profile,
                nodes: nodes_grid_ppn,
                iso: IsoRun::Source,
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "StageWrite",
                profile: stagewrite_profile,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: heat::snapshot_bytes(),
                    chunks: ISO_CHUNKS_STAGEWRITE,
                },
            },
        ],
        edges: vec![EdgeDef {
            from: 0,
            to: 1,
            buffer: hs_buffer_rule,
        }],
        expert_exec: vec![32, 17, 34, 4, 20, 560, 35],
        expert_comp: vec![8, 4, 32, 4, 20, 35, 35],
    }
}

// ---------------------------------------------------------------- GP --

fn grayscott_profile(cfg: &[i64], _up: Upstream, m: &Machine) -> StageProfile {
    source(grayscott::profile(cfg, m))
}

fn pdfcalc_profile(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    consumer(pdfcalc::profile(cfg, up.bytes, m))
}

fn gplot_profile(_cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    consumer(plots::gplot_profile(up.n_chunks, m))
}

fn pplot_profile(_cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    consumer(plots::pplot_profile(up.n_chunks, m))
}

/// GP: Gray-Scott fanning out to the PDF calculator and G-Plot (shared
/// producer NIC), with P-Plot rendering the PDF output.
pub fn gp_def() -> WorkflowDef {
    let mut specs = gp_spec().components.into_iter();
    WorkflowDef {
        name: "GP",
        components: vec![
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "GrayScott",
                profile: grayscott_profile,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Source,
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "PDFcalc",
                profile: pdfcalc_profile,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: grayscott::dump_bytes(),
                    chunks: ISO_CHUNKS_PDF,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "G-Plot",
                profile: gplot_profile,
                nodes: nodes_colocated,
                iso: IsoRun::Consumer { bytes: 0.0, chunks: 1 },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "P-Plot",
                profile: pplot_profile,
                nodes: nodes_colocated,
                iso: IsoRun::Consumer { bytes: 0.0, chunks: 1 },
            },
        ],
        edges: vec![
            EdgeDef::staged(0, 1),
            EdgeDef::staged(0, 2),
            EdgeDef::staged(1, 3),
        ],
        // Table 2 lists PDF procs = 525, but Table 1 bounds the PDF
        // calculator at 512 processes — clamped to the space.
        expert_exec: vec![525, 35, 512, 35],
        expert_comp: vec![35, 35, 35, 35],
    }
}

// --------------------------------------------------------------- CH5 --
//
// Synthetic 5-stage deep analysis chain:
//
//   ChainSim -> Filter -> Feature -> Reduce -> Archive
//
// ChainSim dumps frames on a tunable cadence; Filter thins them to 25%;
// Feature — the interesting mid-stage — pays a redistribution cost
// linear in its process count, so its optimum sits at a moderate
// allocation; Reduce collapses features with a log-cost reduction; the
// fixed Archive writer colocates and adds a small throughput floor.

pub const CH5_STEPS: f64 = 800.0;
/// Bytes per ChainSim frame (~240 MB).
pub const CH5_BYTES: f64 = 2.4e8;
const CH5_FILTER_KEEP: f64 = 0.25;
const CH5_FEATURE_KEEP: f64 = 0.5;
/// Archive's total fixed write time across a run, seconds.
pub const CH5_ARCHIVE_TOTAL_S: f64 = 6.0;
const CH5_REDUCE_PPN: i64 = 18;

fn ch5_spec_components() -> Vec<ComponentSpec> {
    vec![
        ComponentSpec::new(
            "ChainSim",
            vec![
                ParamDef::range("procs", 2, 512),
                ParamDef::range("ppn", 1, 35),
                ParamDef::range_step("io_steps", 20, 200, 20),
            ],
        ),
        ComponentSpec::new(
            "Filter",
            vec![ParamDef::range("procs", 1, 256), ParamDef::range("ppn", 1, 35)],
        ),
        ComponentSpec::new(
            "Feature",
            vec![ParamDef::range("procs", 1, 512), ParamDef::range("ppn", 1, 35)],
        ),
        ComponentSpec::new("Reduce", vec![ParamDef::range("procs", 1, 128)]),
        ComponentSpec::new("Archive", vec![]),
    ]
}

/// cfg = [procs, ppn, io_steps]
fn ch5_source(cfg: &[i64], _up: Upstream, m: &Machine) -> StageProfile {
    let (p, ppn, io) = (cfg[0], cfg[1], cfg[2]);
    let pf = p as f64;
    let mem = 1.0 / m.mem_factor(ppn, 1, 4.0);
    let oversub = m.oversub_factor(ppn, 1);
    let t_step = 0.09 * mem * oversub / pf + 2.4e-4 * pf.log2() + 1.2e-3;
    let nodes = m.nodes_for(p, ppn);
    let t_dump = CH5_BYTES / (1.5e9 * nodes as f64);
    StageProfile {
        t_chunk_s: io as f64 * t_step + t_dump,
        n_chunks: (CH5_STEPS / io as f64).ceil() as usize,
        bytes_out: CH5_BYTES,
        nodes,
    }
}

/// cfg = [procs, ppn]
fn ch5_filter(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let (q, ppn) = (cfg[0], cfg[1]);
    let nodes = m.nodes_for(q, ppn);
    let mem = 1.0 / m.mem_factor(ppn, 1, 2.0);
    let t_ingest = up.bytes / (2.0e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.05 + 7.0 / q as f64 * mem * m.oversub_factor(ppn, 1) + t_ingest,
        n_chunks: 0,
        bytes_out: up.bytes * CH5_FILTER_KEEP,
        nodes,
    }
}

/// cfg = [procs, ppn] — U-shaped in procs: the all-to-all feature
/// redistribution makes large allocations counterproductive.
fn ch5_feature(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let (r, ppn) = (cfg[0], cfg[1]);
    let rf = r as f64;
    let nodes = m.nodes_for(r, ppn);
    let mem = 1.0 / m.mem_factor(ppn, 1, 2.5);
    let t_ingest = up.bytes / (2.0e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.12
            + 16.0 / rf * mem * m.oversub_factor(ppn, 1)
            + 0.0045 * rf
            + t_ingest,
        n_chunks: 0,
        bytes_out: up.bytes * CH5_FEATURE_KEEP,
        nodes,
    }
}

/// cfg = [procs] (fixed ppn — Reduce is launched dense).
fn ch5_reduce(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let s = cfg[0];
    let sf = s as f64;
    let nodes = m.nodes_for(s, CH5_REDUCE_PPN);
    let t_ingest = up.bytes / (2.0e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.04 + 5.0 / sf + 0.012 * (sf + 1.0).log2() + t_ingest,
        n_chunks: 0,
        bytes_out: 2.0e6,
        nodes,
    }
}

fn ch5_reduce_nodes(cfg: &[i64], m: &Machine) -> u64 {
    m.nodes_for(cfg[0], CH5_REDUCE_PPN)
}

/// Fixed single-process writer: total time is constant per run.
fn ch5_archive(_cfg: &[i64], up: Upstream, _m: &Machine) -> StageProfile {
    StageProfile {
        t_chunk_s: CH5_ARCHIVE_TOTAL_S / up.n_chunks as f64,
        n_chunks: 0,
        bytes_out: 0.0,
        nodes: 0,
    }
}

/// CH5: the synthetic deep analysis chain, declared in pure data.
pub fn ch5_def() -> WorkflowDef {
    let mut specs = ch5_spec_components().into_iter();
    WorkflowDef {
        name: "CH5",
        components: vec![
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "ChainSim",
                profile: ch5_source,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Source,
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Filter",
                profile: ch5_filter,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: CH5_BYTES,
                    chunks: ISO_CHUNKS_CH5,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Feature",
                profile: ch5_feature,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: CH5_BYTES * CH5_FILTER_KEEP,
                    chunks: ISO_CHUNKS_CH5,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Reduce",
                profile: ch5_reduce,
                nodes: ch5_reduce_nodes,
                iso: IsoRun::Consumer {
                    bytes: CH5_BYTES * CH5_FILTER_KEEP * CH5_FEATURE_KEEP,
                    chunks: ISO_CHUNKS_CH5,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Archive",
                profile: ch5_archive,
                nodes: nodes_colocated,
                iso: IsoRun::Consumer { bytes: 0.0, chunks: 1 },
            },
        ],
        edges: vec![
            EdgeDef::staged(0, 1),
            EdgeDef::staged(1, 2),
            EdgeDef::staged(2, 3),
            EdgeDef::staged(3, 4),
        ],
        expert_exec: vec![256, 18, 60, 64, 18, 64, 18, 32],
        expert_comp: vec![32, 32, 200, 8, 32, 32, 32, 8],
    }
}

// --------------------------------------------------------------- DM4 --
//
// Synthetic diamond with a shared-NIC producer:
//
//   DiamondSim -> StatA ---\
//        \------> RenderB --> Merge
//
// The source fans out to both analyses (its NIC bandwidth is split —
// the generic out-degree rule), and Merge fans in, starting a chunk
// only once both branches have delivered it.

pub const DM4_STEPS: f64 = 600.0;
/// Bytes per DiamondSim frame (~320 MB).
pub const DM4_BYTES: f64 = 3.2e8;
const DM4_STAT_OUT: f64 = 4.0e6;
const DM4_RENDER_OUT: f64 = 8.0e6;
const DM4_MERGE_PPN: i64 = 18;

fn dm4_spec_components() -> Vec<ComponentSpec> {
    vec![
        ComponentSpec::new(
            "DiamondSim",
            vec![
                ParamDef::range("procs", 2, 512),
                ParamDef::range("ppn", 1, 35),
                ParamDef::range_step("io_steps", 10, 100, 10),
            ],
        ),
        ComponentSpec::new(
            "StatA",
            vec![ParamDef::range("procs", 1, 256), ParamDef::range("ppn", 1, 35)],
        ),
        ComponentSpec::new(
            "RenderB",
            vec![ParamDef::range("procs", 1, 256), ParamDef::range("ppn", 1, 35)],
        ),
        ComponentSpec::new("Merge", vec![ParamDef::range("procs", 1, 64)]),
    ]
}

/// cfg = [procs, ppn, io_steps]
fn dm4_source(cfg: &[i64], _up: Upstream, m: &Machine) -> StageProfile {
    let (p, ppn, io) = (cfg[0], cfg[1], cfg[2]);
    let pf = p as f64;
    let mem = 1.0 / m.mem_factor(ppn, 1, 4.5);
    let t_step = 0.075 * mem * m.oversub_factor(ppn, 1) / pf + 3.0e-4 * pf.log2() + 1.0e-3;
    let nodes = m.nodes_for(p, ppn);
    let t_dump = DM4_BYTES / (1.2e9 * nodes as f64);
    StageProfile {
        t_chunk_s: io as f64 * t_step + t_dump,
        n_chunks: (DM4_STEPS / io as f64).ceil() as usize,
        bytes_out: DM4_BYTES,
        nodes,
    }
}

/// cfg = [procs, ppn] — U-shaped statistics pass.
fn dm4_stat(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let (q, ppn) = (cfg[0], cfg[1]);
    let qf = q as f64;
    let nodes = m.nodes_for(q, ppn);
    let mem = 1.0 / m.mem_factor(ppn, 1, 2.0);
    let t_ingest = up.bytes / (2.0e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.06 + 6.0 / qf * mem * m.oversub_factor(ppn, 1) + 0.003 * qf + t_ingest,
        n_chunks: 0,
        bytes_out: DM4_STAT_OUT,
        nodes,
    }
}

/// cfg = [procs, ppn] — rendering scales sublinearly (serial compositing).
fn dm4_render(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let (q, ppn) = (cfg[0], cfg[1]);
    let qf = q as f64;
    let nodes = m.nodes_for(q, ppn);
    let mem = 1.0 / m.mem_factor(ppn, 1, 1.5);
    let t_ingest = up.bytes / (1.5e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.3 + 9.0 / qf.powf(0.62) * mem * m.oversub_factor(ppn, 1) + t_ingest,
        n_chunks: 0,
        bytes_out: DM4_RENDER_OUT,
        nodes,
    }
}

/// cfg = [procs] (fixed ppn) — fan-in join of both branches.
fn dm4_merge(cfg: &[i64], up: Upstream, m: &Machine) -> StageProfile {
    let s = cfg[0];
    let sf = s as f64;
    let nodes = m.nodes_for(s, DM4_MERGE_PPN);
    let t_ingest = up.bytes / (2.0e9 * nodes as f64);
    StageProfile {
        t_chunk_s: 0.05 + 1.5 / sf + 0.01 * (sf + 1.0).log2() + t_ingest,
        n_chunks: 0,
        bytes_out: 0.0,
        nodes,
    }
}

fn dm4_merge_nodes(cfg: &[i64], m: &Machine) -> u64 {
    m.nodes_for(cfg[0], DM4_MERGE_PPN)
}

/// DM4: the synthetic diamond, declared in pure data.
pub fn dm4_def() -> WorkflowDef {
    let mut specs = dm4_spec_components().into_iter();
    WorkflowDef {
        name: "DM4",
        components: vec![
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "DiamondSim",
                profile: dm4_source,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Source,
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "StatA",
                profile: dm4_stat,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: DM4_BYTES,
                    chunks: ISO_CHUNKS_DM4,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "RenderB",
                profile: dm4_render,
                nodes: nodes_procs_ppn,
                iso: IsoRun::Consumer {
                    bytes: DM4_BYTES,
                    chunks: ISO_CHUNKS_DM4,
                },
            },
            ComponentDef {
                spec: specs.next().unwrap(),
                stage_name: "Merge",
                profile: dm4_merge,
                nodes: dm4_merge_nodes,
                iso: IsoRun::Consumer {
                    bytes: DM4_STAT_OUT + DM4_RENDER_OUT,
                    chunks: ISO_CHUNKS_DM4,
                },
            },
        ],
        edges: vec![
            EdgeDef::staged(0, 1),
            EdgeDef::staged(0, 2),
            EdgeDef::staged(1, 3),
            EdgeDef::staged(2, 3),
        ],
        expert_exec: vec![128, 16, 50, 64, 16, 32, 16, 16],
        expert_comp: vec![16, 32, 100, 8, 32, 8, 32, 4],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, WorkflowId};
    use crate::sim::WorkflowSim;

    #[test]
    fn ch5_is_a_five_stage_chain() {
        let def = ch5_def();
        assert_eq!(def.components.len(), 5);
        assert_eq!(def.edges.len(), 4);
        assert!(def.validate().is_ok(), "{:?}", def.validate());
        assert_eq!(def.n_params(), 8);
        let sim = WorkflowSim::new(WorkflowId::CH5).with_noise(0.0);
        // a mid-range configuration completes in sane time
        let m = sim.expected(&Config(def.expert_exec.clone()));
        assert!(
            m.exec_time_s > CH5_ARCHIVE_TOTAL_S && m.exec_time_s < 300.0,
            "exec {}",
            m.exec_time_s
        );
        // starving the Filter of processes must slow the whole chain
        let starved = sim.expected(&Config(vec![256, 18, 60, 1, 18, 64, 18, 32]));
        assert!(
            starved.exec_time_s > 2.0 * m.exec_time_s,
            "starved {} vs {}",
            starved.exec_time_s,
            m.exec_time_s
        );
        // the mid-stage is U-shaped: a huge Feature allocation is worse
        // than a moderate one
        let moderate = sim.expected(&Config(vec![256, 32, 60, 64, 32, 64, 32, 32]));
        let huge = sim.expected(&Config(vec![256, 32, 60, 64, 32, 512, 32, 32]));
        assert!(
            huge.exec_time_s > moderate.exec_time_s,
            "feature redistribution: {} vs {}",
            moderate.exec_time_s,
            huge.exec_time_s
        );
    }

    #[test]
    fn dm4_diamond_fans_out_and_in() {
        let def = dm4_def();
        assert_eq!(def.components.len(), 4);
        assert_eq!(def.edges.len(), 4);
        assert!(def.validate().is_ok(), "{:?}", def.validate());
        assert_eq!(def.n_params(), 8);
        let sim = WorkflowSim::new(WorkflowId::DM4).with_noise(0.0);
        let base = sim.expected(&Config(def.expert_exec.clone()));
        assert!(base.exec_time_s > 1.0 && base.exec_time_s < 400.0, "{}", base.exec_time_s);
        // Merge waits on the slower branch: crippling RenderB must
        // dominate the makespan even with a fast StatA
        let slow_render = sim.expected(&Config(vec![128, 16, 50, 64, 16, 1, 16, 16]));
        assert!(
            slow_render.exec_time_s > 1.5 * base.exec_time_s,
            "fan-in join: {} vs {}",
            slow_render.exec_time_s,
            base.exec_time_s
        );
    }

    #[test]
    fn builtin_tables_match_table1_specs() {
        // the trio's defs derive their spaces from config::spaces —
        // Table 1 stays the single source of truth
        assert_eq!(lv_def().spec().space_size(), lv_spec().space_size());
        assert_eq!(hs_def().spec().space_size(), hs_spec().space_size());
        assert_eq!(gp_def().spec().space_size(), gp_spec().space_size());
    }
}
