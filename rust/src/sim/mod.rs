//! The in-situ workflow simulator substrate — the testbed substitute
//! (see DESIGN.md §2 for the substitution rationale).
//!
//! * [`machine`] — cluster model (nodes, cores, memory/NIC/FS bandwidth)
//! * [`pipeline`] — streaming DES with staging buffers and backpressure,
//!   split into an immutable [`PipelineStructure`] and a reusable
//!   [`SimWorkspace`] so the measurement hot path is allocation-free
//! * [`apps`] — analytic per-component performance models
//! * [`registry`] — declarative workflow tables ([`WorkflowDef`]) and
//!   the process-wide string-keyed [`WorkflowRegistry`]
//! * [`defs`] — the built-in tables: the paper trio (LV / HS / GP) and
//!   the synthetic scenario families (CH5 / DM4)
//! * [`workflows`] — generic table-driven simulation + isolated
//!   component runs
//! * [`measurement`] — measurements and optimization objectives

pub mod apps;
pub mod defs;
pub mod machine;
pub mod measurement;
pub mod pipeline;
pub mod registry;
pub mod workflows;

pub use machine::Machine;
pub use measurement::{FailureKind, Measurement, MeasurementOutcome, Objective};
pub use pipeline::{Edge, Pipeline, PipelineResult, PipelineStructure, SimWorkspace, Stage};
pub use registry::{
    BufferRule, ComponentDef, EdgeDef, IsoRun, StageProfile, Upstream, WorkflowDef, WorkflowId,
    WorkflowRegistry,
};
pub use workflows::{InfeasibleSpace, WorkflowSim};
