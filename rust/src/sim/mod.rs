//! The in-situ workflow simulator substrate — the testbed substitute
//! (see DESIGN.md §2 for the substitution rationale).
//!
//! * [`machine`] — cluster model (nodes, cores, memory/NIC/FS bandwidth)
//! * [`pipeline`] — streaming DES with staging buffers and backpressure,
//!   split into an immutable [`PipelineStructure`] and a reusable
//!   [`SimWorkspace`] so the measurement hot path is allocation-free
//! * [`apps`] — analytic per-component performance models
//! * [`workflows`] — LV / HS / GP assembly + isolated component runs
//! * [`measurement`] — measurements and optimization objectives

pub mod apps;
pub mod machine;
pub mod measurement;
pub mod pipeline;
pub mod workflows;

pub use machine::Machine;
pub use measurement::{Measurement, Objective};
pub use pipeline::{Edge, Pipeline, PipelineResult, PipelineStructure, SimWorkspace, Stage};
pub use workflows::WorkflowSim;
