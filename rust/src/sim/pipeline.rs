//! Streaming-pipeline discrete-event model: the in-situ coupling
//! substrate (ADIOS-style staging) the paper's workflows run on.
//!
//! A workflow is a DAG of *stages* (component applications) connected by
//! *edges* (staging channels with a finite buffer and a per-chunk
//! transfer time).  `K` data chunks flow from the source stage through
//! every downstream stage in order.  The model captures the coupling
//! effects that make in-situ tuning hard (§2.2):
//!
//! * **backpressure** — a producer blocks when a channel's buffer is
//!   full (its next production cannot start until the consumer has
//!   started draining the chunk `capacity` positions back);
//! * **starvation** — a consumer idles until a chunk has been produced
//!   and transferred;
//! * **rate matching** — steady-state throughput is set by the slowest
//!   stage, so per-component optima do not compose into a workflow
//!   optimum.
//!
//! Chunks move strictly in order, which lets the schedule be computed by
//! exact recurrences chunk-by-chunk in topological order — equivalent to
//! an event-queue simulation of this network but cache-friendly and
//! allocation-light (this sits on the auto-tuner's data-collection hot
//! path: every training sample is one simulated run).

/// One component application in the pipeline.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    /// Processing time per chunk (already includes any per-chunk noise).
    pub t_chunk_s: Vec<f64>,
    /// Nodes this stage occupies (bookkeeping for computer time).
    pub nodes: u64,
}

/// A staging channel between two stages.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    /// Per-chunk transfer time (bytes / effective bandwidth + latency).
    pub t_transfer_s: f64,
    /// Buffer capacity in chunks (>= 1). The producer of chunk `k` may
    /// not start until the consumer has started chunk `k - capacity`.
    pub capacity: usize,
}

/// A fully-assembled pipeline ready to simulate.
#[derive(Clone, Debug)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
    pub edges: Vec<Edge>,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Wall-clock finish time of each stage's last chunk.
    pub finish_s: Vec<f64>,
    /// Total time each stage spent blocked on backpressure.
    pub blocked_s: Vec<f64>,
    /// Total time each stage spent starved waiting for input.
    pub starved_s: Vec<f64>,
}

impl PipelineResult {
    /// Workflow makespan (longest component wall-clock).
    pub fn makespan_s(&self) -> f64 {
        self.finish_s.iter().cloned().fold(0.0, f64::max)
    }
}

impl Pipeline {
    /// Number of chunks (identical across stages; asserted).
    pub fn n_chunks(&self) -> usize {
        let k = self.stages[0].t_chunk_s.len();
        debug_assert!(
            self.stages.iter().all(|s| s.t_chunk_s.len() == k),
            "all stages must process the same chunk count"
        );
        k
    }

    /// Topological order of stage indices; panics on cycles (workflow
    /// DAGs are acyclic by construction).
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.stages.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            assert!(e.from < n && e.to < n && e.from != e.to, "bad edge");
            indeg[e.to] += 1;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for e in self.edges.iter().filter(|e| e.from == u) {
                indeg[e.to] -= 1;
                if indeg[e.to] == 0 {
                    queue.push(e.to);
                }
            }
        }
        assert_eq!(order.len(), n, "pipeline graph has a cycle");
        order
    }

    /// Run the in-order streaming schedule.
    pub fn simulate(&self) -> PipelineResult {
        let n = self.stages.len();
        let k_chunks = self.n_chunks();
        let order = self.topo_order();
        // in/out edge index lists per stage
        let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, e) in self.edges.iter().enumerate() {
            assert!(e.capacity >= 1, "edge capacity must be >= 1");
            in_edges[e.to].push(i);
            out_edges[e.from].push(i);
        }

        // start[u][k]: when stage u begins processing chunk k
        let mut start = vec![vec![0.0f64; k_chunks]; n];
        let mut finish = vec![vec![0.0f64; k_chunks]; n];
        let mut blocked = vec![0.0f64; n];
        let mut starved = vec![0.0f64; n];

        for k in 0..k_chunks {
            for &u in &order {
                let prev_done = if k == 0 { 0.0 } else { finish[u][k - 1] };
                // Input availability: all in-edges must have delivered
                // chunk k (producer finish + transfer).
                let mut ready = prev_done;
                let mut input_at: f64 = 0.0;
                for &ei in &in_edges[u] {
                    let e = &self.edges[ei];
                    input_at = input_at.max(finish[e.from][k] + e.t_transfer_s);
                }
                if !in_edges[u].is_empty() {
                    starved[u] += (input_at - prev_done).max(0.0);
                    ready = ready.max(input_at);
                }
                // Backpressure: every out-edge needs a free buffer slot.
                let mut slot_free: f64 = 0.0;
                for &ei in &out_edges[u] {
                    let e = &self.edges[ei];
                    if k >= e.capacity {
                        slot_free = slot_free.max(start[e.to][k - e.capacity]);
                    }
                }
                blocked[u] += (slot_free - ready).max(0.0);
                let s = ready.max(slot_free);
                start[u][k] = s;
                finish[u][k] = s + self.stages[u].t_chunk_s[k];
            }
        }

        PipelineResult {
            finish_s: (0..n).map(|u| finish[u][k_chunks - 1]).collect(),
            blocked_s: blocked,
            starved_s: starved,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(t0: f64, t1: f64, k: usize, cap: usize, xfer: f64) -> Pipeline {
        Pipeline {
            stages: vec![
                Stage {
                    name: "prod".into(),
                    t_chunk_s: vec![t0; k],
                    nodes: 1,
                },
                Stage {
                    name: "cons".into(),
                    t_chunk_s: vec![t1; k],
                    nodes: 1,
                },
            ],
            edges: vec![Edge {
                from: 0,
                to: 1,
                t_transfer_s: xfer,
                capacity: cap,
            }],
        }
    }

    #[test]
    fn consumer_bound_throughput() {
        // Slow consumer: steady-state rate = consumer rate; producer
        // blocks on the buffer.
        let k = 100;
        let p = chain(1.0, 3.0, k, 2, 0.0);
        let r = p.simulate();
        // consumer starts first chunk at t=1, then runs back-to-back
        let expect = 1.0 + 3.0 * k as f64;
        assert!((r.makespan_s() - expect).abs() < 1e-9, "{}", r.makespan_s());
        assert!(r.blocked_s[0] > 0.0, "producer should be backpressured");
        assert!(r.starved_s[1] <= 1.0 + 1e-9);
    }

    #[test]
    fn producer_bound_throughput() {
        let k = 50;
        let p = chain(2.0, 0.5, k, 4, 0.1);
        let r = p.simulate();
        // producer finishes at 2k; last chunk transfers + processes after
        let expect = 2.0 * k as f64 + 0.1 + 0.5;
        assert!((r.makespan_s() - expect).abs() < 1e-9);
        assert_eq!(r.blocked_s[0], 0.0);
        assert!(r.starved_s[1] > 0.0, "consumer should starve");
    }

    #[test]
    fn buffer_one_serializes_tightly() {
        // capacity 1: producer can produce chunk k only after consumer
        // STARTS chunk k-1 -> still pipelined but tighter than cap 4.
        let k = 40;
        let tight = chain(1.0, 1.0, k, 1, 0.0).simulate().makespan_s();
        let loose = chain(1.0, 1.0, k, 8, 0.0).simulate().makespan_s();
        assert!(tight >= loose - 1e-9);
        // equal-rate stages: both ~ k+1
        assert!((loose - (k as f64 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn fan_out_to_two_consumers() {
        // GS -> {fast, slow}: makespan set by the slow branch.
        let k = 30;
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "src".into(),
                    t_chunk_s: vec![1.0; k],
                    nodes: 2,
                },
                Stage {
                    name: "fast".into(),
                    t_chunk_s: vec![0.2; k],
                    nodes: 1,
                },
                Stage {
                    name: "slow".into(),
                    t_chunk_s: vec![2.5; k],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.0,
                    capacity: 2,
                },
                Edge {
                    from: 0,
                    to: 2,
                    t_transfer_s: 0.0,
                    capacity: 2,
                },
            ],
        };
        let r = p.simulate();
        let expect = 1.0 + 2.5 * k as f64; // slow branch dominates
        assert!((r.makespan_s() - expect).abs() < 1e-9);
        assert!(r.blocked_s[0] > 0.0, "src backpressured by slow branch");
    }

    #[test]
    fn three_stage_chain_rate_is_bottleneck() {
        let k = 60;
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "a".into(),
                    t_chunk_s: vec![0.5; k],
                    nodes: 1,
                },
                Stage {
                    name: "b".into(),
                    t_chunk_s: vec![1.5; k],
                    nodes: 1,
                },
                Stage {
                    name: "c".into(),
                    t_chunk_s: vec![0.25; k],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.05,
                    capacity: 3,
                },
                Edge {
                    from: 1,
                    to: 2,
                    t_transfer_s: 0.05,
                    capacity: 3,
                },
            ],
        };
        let r = p.simulate();
        // bottleneck stage b: rate 1.5/chunk dominates makespan
        let lower = 1.5 * k as f64;
        let upper = lower + 3.0; // fill + drain
        assert!(r.makespan_s() > lower && r.makespan_s() < upper);
    }

    #[test]
    fn per_chunk_noise_accumulates() {
        let k = 10;
        let mut p = chain(1.0, 0.1, k, 4, 0.0);
        p.stages[0].t_chunk_s[3] = 5.0; // one slow chunk
        let r = p.simulate();
        let expect = (k - 1) as f64 * 1.0 + 5.0 + 0.1;
        assert!((r.makespan_s() - expect).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "a".into(),
                    t_chunk_s: vec![1.0],
                    nodes: 1,
                },
                Stage {
                    name: "b".into(),
                    t_chunk_s: vec![1.0],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.0,
                    capacity: 1,
                },
                Edge {
                    from: 1,
                    to: 0,
                    t_transfer_s: 0.0,
                    capacity: 1,
                },
            ],
        };
        p.simulate();
    }
}
