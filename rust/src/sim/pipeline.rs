//! Streaming-pipeline discrete-event model: the in-situ coupling
//! substrate (ADIOS-style staging) the paper's workflows run on.
//!
//! A workflow is a DAG of *stages* (component applications) connected by
//! *edges* (staging channels with a finite buffer and a per-chunk
//! transfer time).  `K` data chunks flow from the source stage through
//! every downstream stage in order.  The model captures the coupling
//! effects that make in-situ tuning hard (§2.2):
//!
//! * **backpressure** — a producer blocks when a channel's buffer is
//!   full (its next production cannot start until the consumer has
//!   started draining the chunk `capacity` positions back);
//! * **starvation** — a consumer idles until a chunk has been produced
//!   and transferred;
//! * **rate matching** — steady-state throughput is set by the slowest
//!   stage, so per-component optima do not compose into a workflow
//!   optimum.
//!
//! Chunks move strictly in order, which lets the schedule be computed by
//! exact recurrences chunk-by-chunk in topological order — equivalent to
//! an event-queue simulation of this network.
//!
//! # Structure / workspace split (the collector's hot path)
//!
//! Every training sample the auto-tuner collects is one simulated run,
//! and every experiment cell measures a whole pool of configurations —
//! so this file keeps the measurement path *allocation-free*:
//!
//! * [`PipelineStructure`] is the immutable per-workflow topology
//!   (stage names as `&'static str`, edge endpoints, topological order,
//!   in/out edge index lists).  It is built once per
//!   [`WorkflowSim`](crate::sim::WorkflowSim) and shared by every run.
//! * [`SimWorkspace`] owns every buffer a run needs: per-stage chunk
//!   times, per-edge transfer times and capacities, and the schedule
//!   arrays.  A collector reuses one workspace across all of its runs;
//!   after the first run warms the buffer capacities, `fill` + simulate
//!   performs **zero heap allocations**.
//!
//! The start/finish matrices of the naive recurrence are `n × K`; the
//! recurrence only ever looks back `capacity` chunks (backpressure) and
//! one chunk (the stage's own previous finish), so the workspace keeps a
//! **rolling window** of `max(capacity) + 1` columns indexed by
//! `k % window` — O(n·K) time, O(n·cap) memory.
//!
//! # Steady-state fast path
//!
//! Noise-free runs ([`WorkflowSim::expected`](crate::sim::WorkflowSim))
//! have constant per-stage chunk times, and a constant-time pipeline
//! reaches a periodic regime after a warmup transient: every stage's
//! start time advances by the same period `P` (the slowest stage's
//! effective rate) each chunk.  [`PipelineStructure::simulate`] detects
//! this — all per-stage start deltas equal for `window` consecutive
//! chunks — and extrapolates the remaining chunks in closed form
//! (`start += remaining · P`, likewise the per-chunk blocked/starved
//! increments), turning O(K) chunk iterations into O(warmup).  The fast
//! path is differentially pinned against the exact recurrence by
//! property tests below; runs with per-chunk noise always take the
//! exact recurrence and match the reference implementation bit-for-bit.
//!
//! [`Pipeline`] + [`Pipeline::simulate`] remain as the allocation-heavy
//! *reference implementation*: built per run, simulated with full
//! `n × K` matrices.  Tests pin the workspace path against it, and the
//! benches keep it as the before/after baseline.

/// One component application in the pipeline (reference representation).
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    /// Processing time per chunk (already includes any per-chunk noise).
    pub t_chunk_s: Vec<f64>,
    /// Nodes this stage occupies (bookkeeping for computer time).
    pub nodes: u64,
}

/// A staging channel between two stages.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    /// Per-chunk transfer time (bytes / effective bandwidth + latency).
    pub t_transfer_s: f64,
    /// Buffer capacity in chunks (>= 1). The producer of chunk `k` may
    /// not start until the consumer has started chunk `k - capacity`.
    pub capacity: usize,
}

/// A fully-assembled pipeline — the *reference* representation used by
/// differential tests and the benches' baseline rows.  The measurement
/// hot path uses [`PipelineStructure`] + [`SimWorkspace`] instead.
#[derive(Clone, Debug)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
    pub edges: Vec<Edge>,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Wall-clock finish time of each stage's last chunk.
    pub finish_s: Vec<f64>,
    /// Total time each stage spent blocked on backpressure.
    pub blocked_s: Vec<f64>,
    /// Total time each stage spent starved waiting for input.
    pub starved_s: Vec<f64>,
}

impl PipelineResult {
    /// Workflow makespan (longest component wall-clock).
    pub fn makespan_s(&self) -> f64 {
        self.finish_s.iter().cloned().fold(0.0, f64::max)
    }
}

impl Pipeline {
    /// Number of chunks (identical across stages; asserted).
    pub fn n_chunks(&self) -> usize {
        let k = self.stages[0].t_chunk_s.len();
        debug_assert!(
            self.stages.iter().all(|s| s.t_chunk_s.len() == k),
            "all stages must process the same chunk count"
        );
        k
    }

    /// Topological order of stage indices; panics on cycles (workflow
    /// DAGs are acyclic by construction).
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.stages.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            assert!(e.from < n && e.to < n && e.from != e.to, "bad edge");
            indeg[e.to] += 1;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for e in self.edges.iter().filter(|e| e.from == u) {
                indeg[e.to] -= 1;
                if indeg[e.to] == 0 {
                    queue.push(e.to);
                }
            }
        }
        assert_eq!(order.len(), n, "pipeline graph has a cycle");
        order
    }

    /// Run the in-order streaming schedule (reference implementation:
    /// allocates full `n × K` matrices; the hot path is
    /// [`PipelineStructure::simulate`]).
    pub fn simulate(&self) -> PipelineResult {
        let n = self.stages.len();
        let k_chunks = self.n_chunks();
        let order = self.topo_order();
        // in/out edge index lists per stage
        let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, e) in self.edges.iter().enumerate() {
            assert!(e.capacity >= 1, "edge capacity must be >= 1");
            in_edges[e.to].push(i);
            out_edges[e.from].push(i);
        }

        // start[u][k]: when stage u begins processing chunk k
        let mut start = vec![vec![0.0f64; k_chunks]; n];
        let mut finish = vec![vec![0.0f64; k_chunks]; n];
        let mut blocked = vec![0.0f64; n];
        let mut starved = vec![0.0f64; n];

        for k in 0..k_chunks {
            for &u in &order {
                let prev_done = if k == 0 { 0.0 } else { finish[u][k - 1] };
                // Input availability: all in-edges must have delivered
                // chunk k (producer finish + transfer).
                let mut ready = prev_done;
                let mut input_at: f64 = 0.0;
                for &ei in &in_edges[u] {
                    let e = &self.edges[ei];
                    input_at = input_at.max(finish[e.from][k] + e.t_transfer_s);
                }
                if !in_edges[u].is_empty() {
                    starved[u] += (input_at - prev_done).max(0.0);
                    ready = ready.max(input_at);
                }
                // Backpressure: every out-edge needs a free buffer slot.
                let mut slot_free: f64 = 0.0;
                for &ei in &out_edges[u] {
                    let e = &self.edges[ei];
                    if k >= e.capacity {
                        slot_free = slot_free.max(start[e.to][k - e.capacity]);
                    }
                }
                blocked[u] += (slot_free - ready).max(0.0);
                let s = ready.max(slot_free);
                start[u][k] = s;
                finish[u][k] = s + self.stages[u].t_chunk_s[k];
            }
        }

        PipelineResult {
            finish_s: (0..n).map(|u| finish[u][k_chunks - 1]).collect(),
            blocked_s: blocked,
            starved_s: starved,
        }
    }
}

/// Immutable pipeline topology: everything about a workflow's shape that
/// does not depend on the configuration being simulated.  Built once per
/// [`WorkflowSim`](crate::sim::WorkflowSim); every run shares it.
#[derive(Clone, Debug)]
pub struct PipelineStructure {
    names: Vec<&'static str>,
    /// Edge endpoints (from, to), in channel order — the same order
    /// `fill` writes transfer times and capacities.
    edges: Vec<(usize, usize)>,
    topo: Vec<usize>,
    in_edges: Vec<Vec<usize>>,
    out_edges: Vec<Vec<usize>>,
}

/// Relative tolerance for steady-state period detection: deltas are
/// float-recomputed each chunk and wobble in the last bits even once the
/// schedule is exactly periodic.
const STEADY_EPS: f64 = 1e-9;

#[inline]
fn steady_eq(a: f64, b: f64) -> bool {
    // NaN (uninitialized previous period) compares unequal.
    (a - b).abs() <= STEADY_EPS * a.abs().max(b.abs()).max(1.0)
}

impl PipelineStructure {
    /// Assemble a topology from stage names and edge endpoint pairs;
    /// panics on cycles (workflow DAGs are acyclic by construction).
    pub fn new(names: Vec<&'static str>, edges: Vec<(usize, usize)>) -> PipelineStructure {
        let n = names.len();
        let mut indeg = vec![0usize; n];
        for &(from, to) in &edges {
            assert!(from < n && to < n && from != to, "bad edge");
            indeg[to] += 1;
        }
        let mut topo: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut head = 0;
        while head < topo.len() {
            let u = topo[head];
            head += 1;
            for &(from, to) in &edges {
                if from == u {
                    indeg[to] -= 1;
                    if indeg[to] == 0 {
                        topo.push(to);
                    }
                }
            }
        }
        assert_eq!(topo.len(), n, "pipeline graph has a cycle");
        let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, &(from, to)) in edges.iter().enumerate() {
            in_edges[to].push(i);
            out_edges[from].push(i);
        }
        PipelineStructure {
            names,
            edges,
            topo,
            in_edges,
            out_edges,
        }
    }

    pub fn n_stages(&self) -> usize {
        self.names.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn stage_name(&self, u: usize) -> &'static str {
        self.names[u]
    }

    /// Run the streaming schedule over a prepared workspace.  Reads the
    /// chunk times / edge parameters set since [`SimWorkspace::begin`],
    /// leaves finish/blocked/starved accounting in the workspace, and
    /// performs no heap allocation once the workspace buffers have
    /// reached their high-water capacity.
    pub fn simulate(&self, ws: &mut SimWorkspace) {
        let n = self.n_stages();
        assert_eq!(ws.n_stages, n, "workspace prepared for a different structure");
        let kc = ws.n_chunks;
        assert!(kc >= 1, "pipeline needs at least one chunk");
        let w = ws.capacity.iter().copied().max().unwrap_or(1) + 1;
        ws.window = w;
        reset(&mut ws.start, n * w);
        reset(&mut ws.finish, n * w);
        reset(&mut ws.finish_last, n);
        reset(&mut ws.blocked, n);
        reset(&mut ws.starved, n);
        ws.fast_path = false;

        // Periodicity detection only pays off (and is only exact enough)
        // for constant chunk times; noisy runs take the full recurrence.
        let detect = ws.uniform && kc > w + 1;
        if detect {
            reset(&mut ws.blocked_base, n);
            reset(&mut ws.starved_base, n);
        }
        let mut stable_run = 0usize;
        let mut period = f64::NAN;

        for k in 0..kc {
            let col = k % w;
            if detect {
                ws.blocked_base.copy_from_slice(&ws.blocked);
                ws.starved_base.copy_from_slice(&ws.starved);
            }
            for &u in &self.topo {
                let prev_done = if k == 0 {
                    0.0
                } else {
                    ws.finish[u * w + (k - 1) % w]
                };
                // Input availability: all in-edges must have delivered
                // chunk k (producer finish + transfer).
                let mut ready = prev_done;
                let mut input_at: f64 = 0.0;
                for &ei in &self.in_edges[u] {
                    let from = self.edges[ei].0;
                    input_at = input_at.max(ws.finish[from * w + col] + ws.t_transfer[ei]);
                }
                if !self.in_edges[u].is_empty() {
                    ws.starved[u] += (input_at - prev_done).max(0.0);
                    ready = ready.max(input_at);
                }
                // Backpressure: every out-edge needs a free buffer slot.
                let mut slot_free: f64 = 0.0;
                for &ei in &self.out_edges[u] {
                    let cap = ws.capacity[ei];
                    if k >= cap {
                        let to = self.edges[ei].1;
                        slot_free = slot_free.max(ws.start[to * w + (k - cap) % w]);
                    }
                }
                ws.blocked[u] += (slot_free - ready).max(0.0);
                let s = ready.max(slot_free);
                ws.start[u * w + col] = s;
                let t = if ws.uniform {
                    ws.t_base[u]
                } else {
                    ws.t_chunk[u * kc + k]
                };
                ws.finish[u * w + col] = s + t;
            }

            if detect && k >= 1 {
                let pcol = (k - 1) % w;
                let p = ws.start[col] - ws.start[pcol];
                let mut stable = steady_eq(p, period);
                if stable {
                    for u in 1..n {
                        let d = ws.start[u * w + col] - ws.start[u * w + pcol];
                        if !steady_eq(d, p) {
                            stable = false;
                            break;
                        }
                    }
                }
                stable_run = if stable { stable_run + 1 } else { 0 };
                period = p;
                // The recurrence looks back at most `w - 1` chunks, so
                // once every stage has advanced by the same period for a
                // full window the regime is provably periodic: close the
                // remaining chunks in one step.
                if stable_run >= w && k + 1 < kc {
                    let rem = (kc - 1 - k) as f64;
                    for u in 0..n {
                        ws.finish_last[u] = ws.start[u * w + col] + rem * p + ws.t_base[u];
                        ws.blocked[u] += rem * (ws.blocked[u] - ws.blocked_base[u]);
                        ws.starved[u] += rem * (ws.starved[u] - ws.starved_base[u]);
                    }
                    ws.fast_path = true;
                    return;
                }
            }
        }

        let last = (kc - 1) % w;
        for u in 0..n {
            ws.finish_last[u] = ws.finish[u * w + last];
        }
    }
}

/// `v.clear()` + `v.resize(n, 0.0)`: zero-fill without giving back the
/// allocation, so a warmed workspace never reallocates.
#[inline]
fn reset(v: &mut Vec<f64>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

/// Reusable simulation state: per-run pipeline parameters plus every
/// schedule buffer.  One workspace per collector; reusing it across runs
/// is what makes the measurement path allocation-free.
#[derive(Clone, Debug, Default)]
pub struct SimWorkspace {
    n_stages: usize,
    n_chunks: usize,
    window: usize,
    /// True while all stages have constant per-chunk times (`t_base`);
    /// flips to false when noise materializes `t_chunk`.
    uniform: bool,
    /// Per-stage constant chunk time (always filled).
    t_base: Vec<f64>,
    /// Row-major `n_stages × n_chunks` per-chunk times (noisy runs).
    t_chunk: Vec<f64>,
    /// Per-edge transfer time, in structure edge order.
    t_transfer: Vec<f64>,
    /// Per-edge buffer capacity (>= 1), in structure edge order.
    capacity: Vec<usize>,
    /// Rolling schedule windows, `n_stages × window`, column `k % window`.
    start: Vec<f64>,
    finish: Vec<f64>,
    /// Outputs of the last simulate call.
    finish_last: Vec<f64>,
    blocked: Vec<f64>,
    starved: Vec<f64>,
    /// Per-chunk increment scratch for steady-state extrapolation.
    blocked_base: Vec<f64>,
    starved_base: Vec<f64>,
    fast_path: bool,
}

impl SimWorkspace {
    pub fn new() -> SimWorkspace {
        SimWorkspace::default()
    }

    /// Start describing a run of `structure` with `n_chunks` chunks.
    /// Stage times default to 0 and must be set via
    /// [`set_stage_time`](Self::set_stage_time); edges default to
    /// (0 transfer, capacity 1) and are set via [`set_edge`](Self::set_edge).
    pub fn begin(&mut self, structure: &PipelineStructure, n_chunks: usize) {
        assert!(n_chunks >= 1, "pipeline needs at least one chunk");
        self.n_stages = structure.n_stages();
        self.n_chunks = n_chunks;
        self.uniform = true;
        reset(&mut self.t_base, self.n_stages);
        reset(&mut self.t_transfer, structure.n_edges());
        self.capacity.clear();
        self.capacity.resize(structure.n_edges(), 1);
    }

    /// Constant per-chunk processing time of stage `u`.
    pub fn set_stage_time(&mut self, u: usize, t_chunk_s: f64) {
        self.t_base[u] = t_chunk_s;
    }

    pub fn stage_time(&self, u: usize) -> f64 {
        self.t_base[u]
    }

    /// Transfer time and buffer capacity of edge `ei` (structure order).
    pub fn set_edge(&mut self, ei: usize, t_transfer_s: f64, capacity: usize) {
        assert!(capacity >= 1, "edge capacity must be >= 1");
        self.t_transfer[ei] = t_transfer_s;
        self.capacity[ei] = capacity;
    }

    /// Switch to per-chunk times, materialized from the constant stage
    /// times; individual chunks are then adjusted via
    /// [`scale_chunk`](Self::scale_chunk) / [`set_chunk_time`](Self::set_chunk_time).
    pub fn make_per_chunk(&mut self) {
        self.t_chunk.clear();
        for u in 0..self.n_stages {
            let t = self.t_base[u];
            self.t_chunk.resize(self.t_chunk.len() + self.n_chunks, t);
        }
        self.uniform = false;
    }

    /// Multiply stage `u`'s chunk `k` time by `factor` (noise).
    pub fn scale_chunk(&mut self, u: usize, k: usize, factor: f64) {
        debug_assert!(!self.uniform, "call make_per_chunk first");
        self.t_chunk[u * self.n_chunks + k] *= factor;
    }

    /// Set stage `u`'s chunk `k` time outright.
    pub fn set_chunk_time(&mut self, u: usize, k: usize, t: f64) {
        debug_assert!(!self.uniform, "call make_per_chunk first");
        self.t_chunk[u * self.n_chunks + k] = t;
    }

    pub fn n_chunks(&self) -> usize {
        self.n_chunks
    }

    /// Workflow makespan of the last simulate (longest component).
    pub fn makespan_s(&self) -> f64 {
        self.finish_last.iter().cloned().fold(0.0, f64::max)
    }

    /// Wall-clock finish time of each stage's last chunk.
    pub fn finish_s(&self) -> &[f64] {
        &self.finish_last
    }

    /// Total time each stage spent blocked on backpressure.
    pub fn blocked_s(&self) -> &[f64] {
        &self.blocked
    }

    /// Total time each stage spent starved waiting for input.
    pub fn starved_s(&self) -> &[f64] {
        &self.starved
    }

    /// Whether the last simulate closed out via steady-state
    /// extrapolation rather than iterating every chunk.
    pub fn took_fast_path(&self) -> bool {
        self.fast_path
    }

    /// Allocate a [`PipelineResult`] from the last simulate (tests and
    /// diagnostics; the hot path reads the slice accessors instead).
    pub fn result(&self) -> PipelineResult {
        PipelineResult {
            finish_s: self.finish_last.clone(),
            blocked_s: self.blocked.clone(),
            starved_s: self.starved.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, assert_prop, check};
    use crate::util::rng::Pcg32;

    fn chain(t0: f64, t1: f64, k: usize, cap: usize, xfer: f64) -> Pipeline {
        Pipeline {
            stages: vec![
                Stage {
                    name: "prod".into(),
                    t_chunk_s: vec![t0; k],
                    nodes: 1,
                },
                Stage {
                    name: "cons".into(),
                    t_chunk_s: vec![t1; k],
                    nodes: 1,
                },
            ],
            edges: vec![Edge {
                from: 0,
                to: 1,
                t_transfer_s: xfer,
                capacity: cap,
            }],
        }
    }

    #[test]
    fn consumer_bound_throughput() {
        // Slow consumer: steady-state rate = consumer rate; producer
        // blocks on the buffer.
        let k = 100;
        let p = chain(1.0, 3.0, k, 2, 0.0);
        let r = p.simulate();
        // consumer starts first chunk at t=1, then runs back-to-back
        let expect = 1.0 + 3.0 * k as f64;
        assert!((r.makespan_s() - expect).abs() < 1e-9, "{}", r.makespan_s());
        assert!(r.blocked_s[0] > 0.0, "producer should be backpressured");
        assert!(r.starved_s[1] <= 1.0 + 1e-9);
    }

    #[test]
    fn producer_bound_throughput() {
        let k = 50;
        let p = chain(2.0, 0.5, k, 4, 0.1);
        let r = p.simulate();
        // producer finishes at 2k; last chunk transfers + processes after
        let expect = 2.0 * k as f64 + 0.1 + 0.5;
        assert!((r.makespan_s() - expect).abs() < 1e-9);
        assert_eq!(r.blocked_s[0], 0.0);
        assert!(r.starved_s[1] > 0.0, "consumer should starve");
    }

    #[test]
    fn buffer_one_serializes_tightly() {
        // capacity 1: producer can produce chunk k only after consumer
        // STARTS chunk k-1 -> still pipelined but tighter than cap 4.
        let k = 40;
        let tight = chain(1.0, 1.0, k, 1, 0.0).simulate().makespan_s();
        let loose = chain(1.0, 1.0, k, 8, 0.0).simulate().makespan_s();
        assert!(tight >= loose - 1e-9);
        // equal-rate stages: both ~ k+1
        assert!((loose - (k as f64 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn fan_out_to_two_consumers() {
        // GS -> {fast, slow}: makespan set by the slow branch.
        let k = 30;
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "src".into(),
                    t_chunk_s: vec![1.0; k],
                    nodes: 2,
                },
                Stage {
                    name: "fast".into(),
                    t_chunk_s: vec![0.2; k],
                    nodes: 1,
                },
                Stage {
                    name: "slow".into(),
                    t_chunk_s: vec![2.5; k],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.0,
                    capacity: 2,
                },
                Edge {
                    from: 0,
                    to: 2,
                    t_transfer_s: 0.0,
                    capacity: 2,
                },
            ],
        };
        let r = p.simulate();
        let expect = 1.0 + 2.5 * k as f64; // slow branch dominates
        assert!((r.makespan_s() - expect).abs() < 1e-9);
        assert!(r.blocked_s[0] > 0.0, "src backpressured by slow branch");
    }

    #[test]
    fn three_stage_chain_rate_is_bottleneck() {
        let k = 60;
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "a".into(),
                    t_chunk_s: vec![0.5; k],
                    nodes: 1,
                },
                Stage {
                    name: "b".into(),
                    t_chunk_s: vec![1.5; k],
                    nodes: 1,
                },
                Stage {
                    name: "c".into(),
                    t_chunk_s: vec![0.25; k],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.05,
                    capacity: 3,
                },
                Edge {
                    from: 1,
                    to: 2,
                    t_transfer_s: 0.05,
                    capacity: 3,
                },
            ],
        };
        let r = p.simulate();
        // bottleneck stage b: rate 1.5/chunk dominates makespan
        let lower = 1.5 * k as f64;
        let upper = lower + 3.0; // fill + drain
        assert!(r.makespan_s() > lower && r.makespan_s() < upper);
    }

    #[test]
    fn per_chunk_noise_accumulates() {
        let k = 10;
        let mut p = chain(1.0, 0.1, k, 4, 0.0);
        p.stages[0].t_chunk_s[3] = 5.0; // one slow chunk
        let r = p.simulate();
        let expect = (k - 1) as f64 * 1.0 + 5.0 + 0.1;
        assert!((r.makespan_s() - expect).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let p = Pipeline {
            stages: vec![
                Stage {
                    name: "a".into(),
                    t_chunk_s: vec![1.0],
                    nodes: 1,
                },
                Stage {
                    name: "b".into(),
                    t_chunk_s: vec![1.0],
                    nodes: 1,
                },
            ],
            edges: vec![
                Edge {
                    from: 0,
                    to: 1,
                    t_transfer_s: 0.0,
                    capacity: 1,
                },
                Edge {
                    from: 1,
                    to: 0,
                    t_transfer_s: 0.0,
                    capacity: 1,
                },
            ],
        };
        p.simulate();
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn structure_cycle_detected() {
        PipelineStructure::new(vec!["a", "b"], vec![(0, 1), (1, 0)]);
    }

    // ----- structure/workspace differential tests -----

    const NAMES: [&str; 6] = ["s0", "s1", "s2", "s3", "s4", "s5"];

    /// Random DAG: a spanning tree (each stage u >= 1 consumes from a
    /// random earlier stage — chains and fan-outs), plus a few extra
    /// forward edges so fan-*in* merges (multiple in-edges per stage)
    /// are exercised too; random capacities and transfer times.
    fn random_topology(rng: &mut Pcg32) -> (usize, Vec<(usize, usize)>, Vec<(f64, usize)>) {
        let n = 2 + rng.gen_range(4) as usize;
        let mut edges = Vec::new();
        let mut params = Vec::new();
        for to in 1..n {
            let from = rng.gen_range(to as u64) as usize;
            edges.push((from, to));
            params.push((rng.f64() * 0.2, 1 + rng.gen_range(4) as usize));
        }
        for _ in 0..rng.gen_range(3) {
            // forward edges keep the graph acyclic; duplicates of a tree
            // edge are allowed (parallel channels with their own buffer)
            let to = 1 + rng.gen_range(n as u64 - 1) as usize;
            let from = rng.gen_range(to as u64) as usize;
            edges.push((from, to));
            params.push((rng.f64() * 0.2, 1 + rng.gen_range(4) as usize));
        }
        (n, edges, params)
    }

    fn reference_pipeline(
        edges: &[(usize, usize)],
        params: &[(f64, usize)],
        times: &[Vec<f64>],
    ) -> Pipeline {
        Pipeline {
            stages: times
                .iter()
                .enumerate()
                .map(|(u, t)| Stage {
                    name: NAMES[u].to_string(),
                    t_chunk_s: t.clone(),
                    nodes: 1,
                })
                .collect(),
            edges: edges
                .iter()
                .zip(params)
                .map(|(&(from, to), &(xfer, cap))| Edge {
                    from,
                    to,
                    t_transfer_s: xfer,
                    capacity: cap,
                })
                .collect(),
        }
    }

    /// The workspace recurrence must equal the reference implementation
    /// *bitwise* on arbitrary per-chunk times (the noisy-run hot path),
    /// blocked/starved accounting included, with the workspace reused
    /// across cases.
    #[test]
    fn simulate_workspace_equals_reference() {
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("workspace == reference (per-chunk times)", 60, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let (n, edges, params) = random_topology(rng);
            let kc = 1 + rng.gen_range(60) as usize;
            let times: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..kc).map(|_| 0.05 + rng.f64() * 2.0).collect())
                .collect();
            let reference = reference_pipeline(&edges, &params, &times).simulate();

            let st = PipelineStructure::new(NAMES[..n].to_vec(), edges);
            ws.begin(&st, kc);
            for (ei, &(xfer, cap)) in params.iter().enumerate() {
                ws.set_edge(ei, xfer, cap);
            }
            ws.make_per_chunk();
            for (u, row) in times.iter().enumerate() {
                for (k, &t) in row.iter().enumerate() {
                    ws.set_chunk_time(u, k, t);
                }
            }
            st.simulate(&mut ws);
            assert_prop(!ws.took_fast_path(), "noisy runs must not extrapolate")?;
            for u in 0..n {
                assert_prop(
                    ws.finish_s()[u] == reference.finish_s[u],
                    format!("finish[{u}]: {} vs {}", ws.finish_s()[u], reference.finish_s[u]),
                )?;
                assert_prop(
                    ws.blocked_s()[u] == reference.blocked_s[u],
                    format!("blocked[{u}]: {} vs {}", ws.blocked_s()[u], reference.blocked_s[u]),
                )?;
                assert_prop(
                    ws.starved_s()[u] == reference.starved_s[u],
                    format!("starved[{u}]: {} vs {}", ws.starved_s()[u], reference.starved_s[u]),
                )?;
            }
            Ok(())
        });
    }

    /// The steady-state fast path (constant chunk times) is pinned
    /// against the exact recurrence within extrapolation tolerance.
    #[test]
    fn steady_state_fast_path_matches_recurrence() {
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("steady-state extrapolation == recurrence", 60, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let (n, edges, params) = random_topology(rng);
            let kc = 2 + rng.gen_range(200) as usize;
            let times: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![0.05 + rng.f64() * 2.0; kc])
                .collect();
            let reference = reference_pipeline(&edges, &params, &times).simulate();

            let st = PipelineStructure::new(NAMES[..n].to_vec(), edges);
            ws.begin(&st, kc);
            for (u, row) in times.iter().enumerate() {
                ws.set_stage_time(u, row[0]);
            }
            for (ei, &(xfer, cap)) in params.iter().enumerate() {
                ws.set_edge(ei, xfer, cap);
            }
            st.simulate(&mut ws);
            for u in 0..n {
                assert_close(ws.finish_s()[u], reference.finish_s[u], 1e-6, "finish")?;
                assert_close(ws.blocked_s()[u], reference.blocked_s[u], 1e-6, "blocked")?;
                assert_close(ws.starved_s()[u], reference.starved_s[u], 1e-6, "starved")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fast_path_triggers_on_long_uniform_chain() {
        let st = PipelineStructure::new(vec!["a", "b"], vec![(0, 1)]);
        let mut ws = SimWorkspace::new();
        let kc = 500;
        ws.begin(&st, kc);
        ws.set_stage_time(0, 1.0);
        ws.set_stage_time(1, 3.0);
        ws.set_edge(0, 0.0, 2);
        st.simulate(&mut ws);
        assert!(ws.took_fast_path(), "long constant chain should extrapolate");
        // consumer-bound: 1 + 3k (see consumer_bound_throughput)
        let expect = 1.0 + 3.0 * kc as f64;
        assert!(
            (ws.makespan_s() - expect).abs() < 1e-6 * expect,
            "{} vs {expect}",
            ws.makespan_s()
        );
        // workspace reuse: a second, different run on the same buffers
        ws.begin(&st, 10);
        ws.set_stage_time(0, 2.0);
        ws.set_stage_time(1, 0.5);
        ws.set_edge(0, 0.1, 4);
        st.simulate(&mut ws);
        let expect2 = 2.0 * 10.0 + 0.1 + 0.5;
        assert!((ws.makespan_s() - expect2).abs() < 1e-9, "{}", ws.makespan_s());
    }
}
