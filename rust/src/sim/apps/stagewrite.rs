//! Stage Write model (HS's analysis component): receives snapshots from
//! staging and writes them to the parallel filesystem.
//!
//! Parameters (Table 1): `procs` 2..1085, `ppn` 1..35.
//!
//! Model: per-chunk time = deserialization (parallel across ranks) +
//! filesystem write at min(aggregate client bandwidth, shared FS
//! bandwidth with many-writer degradation) + a *linear-in-p*
//! coordination cost (file open/offset negotiation, metadata server
//! pressure) — so hundreds of writer ranks (the expert HS config uses
//! 560) are strongly counterproductive.

use super::ConsumerProfile;
use crate::sim::machine::Machine;

/// Per-rank filesystem client bandwidth, GB/s.
pub const CLIENT_BW_GBPS: f64 = 0.30;
/// Many-writer FS degradation half-constant (ranks).
pub const FS_HALF_WRITERS: f64 = 96.0;
/// Coordination cost per rank per chunk, seconds.
pub const K_COORD: f64 = 0.010;
/// Deserialization bandwidth per node, GB/s.
pub const DESER_BW_GBPS: f64 = 2.0;

/// cfg = [procs, ppn]; `bytes_in` = snapshot size.
pub fn profile(cfg: &[i64], bytes_in: f64, m: &Machine) -> ConsumerProfile {
    let (p, ppn) = (cfg[0], cfg[1]);
    let pf = p as f64;
    let nodes = m.nodes_for(p, ppn);

    let t_deser = bytes_in / (DESER_BW_GBPS * 1e9 * nodes as f64);
    let fs_bw = m.fs_bw_gbps * 1e9 / (1.0 + pf / FS_HALF_WRITERS);
    let agg_bw = (pf * CLIENT_BW_GBPS * 1e9).min(fs_bw);
    let t_write = bytes_in / agg_bw;
    let t_coord = K_COORD * pf;

    ConsumerProfile {
        t_chunk_s: t_deser + t_write + t_coord,
        bytes_per_chunk_out: 0.0,
        procs: p,
        ppn,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::apps::heat;

    fn t(cfg: &[i64]) -> f64 {
        profile(cfg, heat::snapshot_bytes(), &Machine::default()).t_chunk_s
    }

    #[test]
    fn u_shaped_in_writers() {
        let few = t(&[2, 2]);
        let mid = t(&[20, 5]);
        let many = t(&[560, 35]);
        assert!(mid < few, "some parallelism helps: {few} vs {mid}");
        assert!(many > mid, "560 writers must thrash: {mid} vs {many}");
    }

    #[test]
    fn calibration_magnitude() {
        // Best-exec-like Stage config (19 procs): well under a second.
        let best = t(&[19, 3]);
        assert!(best < 1.0, "best {best}");
        // Expert config (560, 35): several seconds per snapshot so the
        // expert workflow lands near Table 2's 28 s with 4 writes.
        let expert = t(&[560, 35]);
        assert!(expert > 4.0 && expert < 9.0, "expert {expert}");
    }
}
