//! Voro++ Voronoi-tesselation model (LV's analysis component).
//!
//! Parameters (Table 1): `procs` 2..1085, `ppn` 1..35, `tpp` 1..4.
//! Consumes LAMMPS frames from staging; per frame it deserializes,
//! redistributes particles, computes the tesselation and renders cell
//! statistics.
//!
//! Model: per-chunk time = serial fraction (I/O + merge on rank 0) +
//! parallel tesselation (∝ atoms·ln(atoms)/procs with weak thread
//! scaling) + *linear-in-p* redistribution cost: the all-to-all particle
//! exchange makes large process counts counterproductive — the optimum
//! sits at a moderate p, which is what makes LV's joint tuning
//! non-trivial (a big Voro++ allocation wastes nodes AND slows the
//! pipeline).

use super::{thread_speedup, ConsumerProfile};
use crate::sim::machine::Machine;

/// Serial per-frame overhead, seconds.
pub const SERIAL_S: f64 = 0.30;
/// Parallel tesselation work, proc·seconds per frame (16k atoms).
pub const W_PARALLEL: f64 = 80.0;
/// All-to-all redistribution coefficient, seconds per proc per frame.
pub const K_REDIST: f64 = 0.021;
/// Thread-scaling exponent (Voro++ threads poorly).
pub const THREAD_EXP: f64 = 0.30;
/// Memory demand per busy core, GB/s (tesselation is compute-heavy).
pub const GB_PER_CORE: f64 = 1.5;
/// Ingest deserialization bandwidth, GB/s per node.
pub const INGEST_BW_GBPS: f64 = 1.2;

/// cfg = [procs, ppn, tpp]; `bytes_in` = frame size from the producer.
pub fn profile(cfg: &[i64], bytes_in: f64, m: &Machine) -> ConsumerProfile {
    let (p, ppn, tpp) = (cfg[0], cfg[1], cfg[2]);
    let pf = p as f64;
    let nodes = m.nodes_for(p, ppn);

    let speedup = pf * thread_speedup(tpp, THREAD_EXP);
    let mem = 1.0 / m.mem_factor(ppn, tpp, GB_PER_CORE);
    let oversub = m.oversub_factor(ppn, tpp);
    let t_parallel = W_PARALLEL / speedup * mem * oversub;
    let t_redist = K_REDIST * pf;
    let t_ingest = bytes_in / (INGEST_BW_GBPS * 1e9 * nodes as f64);

    ConsumerProfile {
        t_chunk_s: SERIAL_S + t_parallel + t_redist + t_ingest,
        bytes_per_chunk_out: 0.0,
        procs: p,
        ppn,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::apps::lammps;

    fn t(cfg: &[i64]) -> f64 {
        let m = Machine::default();
        profile(cfg, lammps::N_ATOMS * lammps::BYTES_PER_ATOM, &m).t_chunk_s
    }

    #[test]
    fn u_shaped_in_procs() {
        let small = t(&[8, 8, 1]);
        let mid = t(&[88, 10, 1]);
        let large = t(&[700, 20, 1]);
        assert!(mid < small, "more procs should help at first: {small} vs {mid}");
        assert!(
            large > mid,
            "redistribution must dominate at large p: {mid} vs {large}"
        );
    }

    #[test]
    fn threads_help_weakly() {
        // ppn 8 so 4 threads stay under the 36-core node budget
        let t1 = t(&[64, 8, 1]);
        let t4 = t(&[64, 8, 4]);
        assert!(t4 < t1, "threads should help: {t1} vs {t4}");
        assert!(t4 > t1 * 0.55, "but only weakly (exp 0.3): {t1} vs {t4}");
    }

    #[test]
    fn oversubscribed_threads_hurt() {
        let ok = t(&[64, 16, 1]);
        let over = t(&[64, 16, 4]); // 64 threads on 36 cores
        assert!(over > ok, "oversubscription must cost: {ok} vs {over}");
    }

    #[test]
    fn calibration_magnitude() {
        // Best-exec Voro config (88, 10, 4): a frame should take a few
        // seconds so 7 frames fit under LAMMPS' ~25 s busy time.
        let best = t(&[88, 10, 4]);
        assert!(best > 1.0 && best < 4.0, "best {best}");
        // Expert (288, 18, 2): several times slower per frame.
        let expert = t(&[288, 18, 2]);
        assert!(expert > 6.0 && expert < 12.0, "expert {expert}");
    }
}
