//! G-Plot and P-Plot models (GP's visualization sinks).
//!
//! Neither is configurable (Table 1: one process each).  G-Plot renders
//! every Gray-Scott dump and is GP's hard bottleneck: the paper notes
//! that running it alone takes 97.0 s, which is why many GP
//! configurations share nearly identical execution times.  P-Plot
//! renders the (tiny) PDF output and is fast.

use super::ConsumerProfile;
use crate::sim::machine::Machine;

/// G-Plot total rendering time across all chunks, seconds (paper: 97.0).
pub const GPLOT_TOTAL_S: f64 = 97.0;
/// P-Plot total rendering time across all chunks, seconds.
pub const PPLOT_TOTAL_S: f64 = 9.0;

/// G-Plot profile for a run of `n_chunks` dumps.
pub fn gplot_profile(n_chunks: usize, _m: &Machine) -> ConsumerProfile {
    ConsumerProfile {
        t_chunk_s: GPLOT_TOTAL_S / n_chunks as f64,
        bytes_per_chunk_out: 0.0,
        procs: 1,
        ppn: 1,
        nodes: 0, // colocated with the analysis allocation
    }
}

/// P-Plot profile for a run of `n_chunks` PDF outputs.
pub fn pplot_profile(n_chunks: usize, _m: &Machine) -> ConsumerProfile {
    ConsumerProfile {
        t_chunk_s: PPLOT_TOTAL_S / n_chunks as f64,
        bytes_per_chunk_out: 0.0,
        procs: 1,
        ppn: 1,
        nodes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gplot_total_is_fixed() {
        let m = Machine::default();
        for k in [5usize, 20, 40] {
            let p = gplot_profile(k, &m);
            let total = p.t_chunk_s * k as f64;
            assert!((total - GPLOT_TOTAL_S).abs() < 1e-9);
        }
    }

    #[test]
    fn pplot_much_faster() {
        let m = Machine::default();
        assert!(pplot_profile(20, &m).t_chunk_s < gplot_profile(20, &m).t_chunk_s);
    }
}
