//! LAMMPS molecular-dynamics model (LV's simulation component).
//!
//! Parameters (Table 1): `procs` 2..1085, `ppn` 1..35, `tpp` 1..4,
//! `io_steps` 50..400 step 50.  Workload: 16 000 atoms, 2000 timesteps,
//! dumping positions + velocities every `io_steps` steps over staging.
//!
//! Model: per-step time = spatial-decomposition compute (∝ atoms/proc,
//! hybrid MPI+OpenMP with sublinear thread scaling, memory-bandwidth
//! contention at high ppn, oversubscription penalty past 36 threads per
//! node) + communication (logarithmic collectives + halo surface term).
//! Each dump serializes the frame and pays a per-dump overhead.

use super::{thread_speedup, SourceProfile};
use crate::sim::machine::Machine;

/// Atoms in the benchmark problem (paper: 16 000).
pub const N_ATOMS: f64 = 16_000.0;
/// Total MD timesteps per run.
pub const N_STEPS: f64 = 2_000.0;
/// Bytes per atom per frame (3D pos + vel, f64).
pub const BYTES_PER_ATOM: f64 = 48.0;

/// Per-atom-step work coefficient (proc·s per atom per step).
pub const K_COMPUTE: f64 = 1.8e-4;
/// Collective-communication coefficient (s × log2(p) per step).
pub const K_COLLECTIVE: f64 = 5.0e-4;
/// Halo-exchange coefficient (s per (atoms/proc)^(2/3) per step).
pub const K_HALO: f64 = 6.0e-6;
/// Thread-scaling exponent (LAMMPS OpenMP threads help, sublinearly).
pub const THREAD_EXP: f64 = 0.75;
/// Memory-bandwidth demand per busy core, GB/s.
pub const GB_PER_CORE: f64 = 1.7;
/// Frame serialization bandwidth, GB/s (gather + pack on ranks).
pub const SER_BW_GBPS: f64 = 0.5;
/// Fixed per-dump overhead, seconds (ADIOS open/close + metadata).
pub const DUMP_FIXED_S: f64 = 0.03;

/// cfg = [procs, ppn, tpp, io_steps]
pub fn profile(cfg: &[i64], m: &Machine) -> SourceProfile {
    let (p, ppn, tpp, io) = (cfg[0], cfg[1], cfg[2], cfg[3]);
    let pf = p as f64;

    let speedup = pf * thread_speedup(tpp, THREAD_EXP);
    let mem = 1.0 / m.mem_factor(ppn, tpp, GB_PER_CORE);
    let oversub = m.oversub_factor(ppn, tpp);
    let t_compute = K_COMPUTE * N_ATOMS / speedup * mem * oversub;

    let t_collective = K_COLLECTIVE * pf.log2();
    let t_halo = K_HALO * (N_ATOMS / pf).powf(2.0 / 3.0);
    let t_step = t_compute + t_collective + t_halo;

    let bytes = N_ATOMS * BYTES_PER_ATOM;
    let t_dump = bytes / (SER_BW_GBPS * 1e9) + DUMP_FIXED_S;

    let n_chunks = (N_STEPS / io as f64).ceil() as usize;
    SourceProfile {
        n_chunks,
        t_chunk_s: io as f64 * t_step + t_dump,
        bytes_per_chunk: bytes,
        procs: p,
        ppn,
        nodes: m.nodes_for(p, ppn),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_busy(cfg: &[i64]) -> f64 {
        let m = Machine::default();
        let pr = profile(cfg, &m);
        pr.n_chunks as f64 * pr.t_chunk_s
    }

    #[test]
    fn more_procs_faster_until_comm_dominates() {
        let small = total_busy(&[16, 16, 1, 200]);
        let mid = total_busy(&[256, 16, 1, 200]);
        let large = total_busy(&[1024, 32, 1, 200]);
        assert!(mid < small, "scaling up should help: {small} -> {mid}");
        // at 1024 procs the log collective term keeps it from improving
        // proportionally (16 atoms/proc)
        assert!(large > mid * 0.5, "comm floor: {mid} -> {large}");
    }

    #[test]
    fn oversubscription_hurts() {
        let ok = total_busy(&[140, 35, 1, 200]); // 35 threads/node
        let bad = total_busy(&[140, 35, 4, 200]); // 140 threads/node
        assert!(
            bad > ok,
            "4 threads on an oversubscribed node must be slower: {ok} vs {bad}"
        );
    }

    #[test]
    fn io_interval_trades_dumps() {
        let m = Machine::default();
        let frequent = profile(&[200, 20, 1, 50], &m);
        let rare = profile(&[200, 20, 1, 400], &m);
        assert_eq!(frequent.n_chunks, 40);
        assert_eq!(rare.n_chunks, 5);
        let busy_frequent = frequent.n_chunks as f64 * frequent.t_chunk_s;
        let busy_rare = rare.n_chunks as f64 * rare.t_chunk_s;
        // more dumps -> more serialization overhead
        assert!(busy_frequent > busy_rare);
    }

    #[test]
    fn calibration_magnitude() {
        // Best-exec-like config should complete its busy time in tens of
        // seconds (Table 2: 27.2 s wall-clock for the workflow).
        let busy = total_busy(&[430, 23, 1, 300]);
        assert!(busy > 10.0 && busy < 45.0, "busy {busy}");
        // Expert-comp-like config (18 procs) runs minutes.
        let busy_small = total_busy(&[18, 18, 2, 400]);
        assert!(busy_small > 100.0 && busy_small < 400.0, "busy {busy_small}");
    }
}
