//! Heat Transfer mini-app model (HS's simulation component).
//!
//! Parameters (Table 1): `px` 2..32, `py` 2..32 (2-D process grid,
//! procs = px·py), `ppn` 1..35, `io_writes` 4..32 step 4 (how many full
//! state snapshots are streamed out), `buffer_mb` 1..40 (ADIOS staging
//! buffer).
//!
//! Model: 5-point stencil over a fixed global grid — per-step time is
//! memory-bandwidth-bound compute (∝ cells/proc, strong ppn contention)
//! plus halo exchange proportional to the local perimeter
//! (favoring square-ish px×py aspect ratios).  Each of the `io_writes`
//! snapshots pays a staging-write cost whose effective bandwidth grows
//! with the ADIOS buffer size (small buffers force many synchronous
//! flushes).

use super::SourceProfile;
use crate::sim::machine::Machine;

/// Global grid edge (cells); state is GRID² f64 values.
pub const GRID: f64 = 4096.0;
/// Total time steps per run.
pub const N_STEPS: f64 = 200.0;
/// Per-cell-step compute coefficient, proc·s per cell.
pub const K_COMPUTE: f64 = 2.4e-7;
/// Halo-exchange coefficient, seconds per boundary cell per step.
pub const K_HALO: f64 = 1.6e-6;
/// Memory demand per busy core, GB/s (stencils are bandwidth-bound).
pub const GB_PER_CORE: f64 = 6.0;
/// Buffer half-saturation constant, MB: write bandwidth =
/// nic · buf/(buf + BUF_HALF_MB).
pub const BUF_HALF_MB: f64 = 24.0;
/// Fixed per-write overhead, seconds.
pub const WRITE_FIXED_S: f64 = 0.05;

/// Snapshot size in bytes.
pub fn snapshot_bytes() -> f64 {
    GRID * GRID * 8.0
}

/// Staging-buffer efficiency factor in (0, 1].
pub fn buffer_efficiency(buffer_mb: i64) -> f64 {
    let b = buffer_mb as f64;
    b / (b + BUF_HALF_MB)
}

/// Pipeline buffer slots granted by `buffer_mb` (1..4).
pub fn buffer_slots(buffer_mb: i64) -> usize {
    ((buffer_mb as f64 / 10.0).ceil() as usize).clamp(1, 4)
}

/// cfg = [px, py, ppn, io_writes, buffer_mb]
pub fn profile(cfg: &[i64], m: &Machine) -> SourceProfile {
    let (px, py, ppn, writes, buf) = (cfg[0], cfg[1], cfg[2], cfg[3], cfg[4]);
    let procs = px * py;
    let nodes = m.nodes_for(procs, ppn);

    let cells_per_proc = GRID * GRID / procs as f64;
    let mem = 1.0 / m.mem_factor(ppn, 1, GB_PER_CORE);
    let oversub = m.oversub_factor(ppn, 1);
    let t_compute = K_COMPUTE * cells_per_proc * mem * oversub;
    // local block perimeter: favor balanced aspect ratios
    let t_halo = K_HALO * (GRID / px as f64 + GRID / py as f64);
    let t_step = t_compute + t_halo;

    let steps_per_write = N_STEPS / writes as f64;
    let write_bw = m.nic_bw_gbps * 1e9 * buffer_efficiency(buf) * nodes as f64;
    let t_write = snapshot_bytes() / write_bw + WRITE_FIXED_S;

    SourceProfile {
        n_chunks: writes as usize,
        t_chunk_s: steps_per_write * t_step + t_write,
        bytes_per_chunk: snapshot_bytes(),
        procs,
        ppn,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy(cfg: &[i64]) -> f64 {
        let m = Machine::default();
        let p = profile(cfg, &m);
        p.n_chunks as f64 * p.t_chunk_s
    }

    #[test]
    fn aspect_ratio_matters() {
        // same procs, skewed vs square decomposition
        let square = busy(&[16, 16, 16, 8, 20]);
        let skewed = busy(&[2, 32, 16, 8, 20]); // 64 procs vs 256 -> use same
        let skewed_same = busy(&[8, 32, 16, 8, 20]);
        let square_same = busy(&[16, 16, 16, 8, 20]);
        assert!(square_same < skewed_same, "{square_same} vs {skewed_same}");
        let _ = (square, skewed);
    }

    #[test]
    fn ppn_contention_hurts_stencil() {
        // same procs spread thin vs packed dense
        let thin = busy(&[16, 16, 8, 8, 20]); // 32 nodes
        let dense = busy(&[16, 16, 32, 8, 20]); // 8 nodes
        assert!(dense > thin, "memory contention: {thin} vs {dense}");
    }

    #[test]
    fn buffer_efficiency_monotone() {
        assert!(buffer_efficiency(1) < buffer_efficiency(20));
        assert!(buffer_efficiency(20) < buffer_efficiency(40));
        assert!(buffer_slots(1) == 1);
        assert!(buffer_slots(40) == 4);
    }

    #[test]
    fn calibration_magnitude() {
        // Best-exec-like config (13, 17, 14, 4, 29): ~4-6 s busy.
        let best = busy(&[13, 17, 14, 4, 29]);
        assert!(best > 2.5 && best < 7.0, "best {best}");
        // Expert-comp config (8, 4, 32, 4, 20): tens of seconds.
        let small = busy(&[8, 4, 32, 4, 20]);
        assert!(small > 25.0 && small < 80.0, "small {small}");
    }
}
