//! Gray-Scott reaction-diffusion model (GP's simulation component).
//!
//! Parameters (Table 1): `procs` 2..1085, `ppn` 1..35.
//!
//! Model: 3-D stencil + reaction over a fixed grid, dumping the U field
//! every `IO_INTERVAL` steps (GP's dump cadence is not configurable).
//! Per-step time = compute (∝ cells/proc with memory contention) +
//! halo surface term + collectives.

use super::SourceProfile;
use crate::sim::machine::Machine;

/// Grid cells (3-D, 384³ ≈ 56.6 M).
pub const CELLS: f64 = 384.0 * 384.0 * 384.0;
/// Total simulation steps.
pub const N_STEPS: f64 = 1_000.0;
/// Steps between dumps (fixed by the workflow, not a Table 1 param).
pub const IO_INTERVAL: f64 = 50.0;
/// Per-cell-step compute coefficient, proc·s per cell.
pub const K_COMPUTE: f64 = 1.23e-7;
/// Halo coefficient, seconds per (cells/proc)^(2/3) per step.
pub const K_HALO: f64 = 1.1e-6;
/// Collective coefficient, s·log2(p) per step.
pub const K_COLLECTIVE: f64 = 8.0e-5;
/// Memory demand per busy core, GB/s.
pub const GB_PER_CORE: f64 = 5.0;
/// Dump serialization bandwidth, GB/s per node.
pub const SER_BW_GBPS: f64 = 1.5;

/// Bytes per dump (U field, f64).
pub fn dump_bytes() -> f64 {
    CELLS * 8.0
}

/// cfg = [procs, ppn]
pub fn profile(cfg: &[i64], m: &Machine) -> SourceProfile {
    let (p, ppn) = (cfg[0], cfg[1]);
    let pf = p as f64;
    let nodes = m.nodes_for(p, ppn);

    let cells_per_proc = CELLS / pf;
    let mem = 1.0 / m.mem_factor(ppn, 1, GB_PER_CORE);
    let oversub = m.oversub_factor(ppn, 1);
    let t_compute = K_COMPUTE * cells_per_proc * mem * oversub;
    let t_halo = K_HALO * cells_per_proc.powf(2.0 / 3.0);
    let t_step = t_compute + t_halo + K_COLLECTIVE * pf.log2();

    let t_dump = dump_bytes() / (SER_BW_GBPS * 1e9 * nodes as f64);

    SourceProfile {
        n_chunks: (N_STEPS / IO_INTERVAL) as usize,
        t_chunk_s: IO_INTERVAL * t_step + t_dump,
        bytes_per_chunk: dump_bytes(),
        procs: p,
        ppn,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy(cfg: &[i64]) -> f64 {
        let m = Machine::default();
        let p = profile(cfg, &m);
        p.n_chunks as f64 * p.t_chunk_s
    }

    #[test]
    fn scaling_helps_then_flattens() {
        let tiny = busy(&[35, 35]);
        let mid = busy(&[175, 13]);
        let big = busy(&[525, 35]);
        assert!(mid < tiny, "{tiny} vs {mid}");
        assert!(big < mid * 1.2, "{mid} vs {big}");
    }

    #[test]
    fn calibration_magnitude() {
        // Expert-comp config (35, 35): minutes of busy time (Table 2:
        // 292 s exec at 2 nodes).
        let small = busy(&[35, 35]);
        assert!(small > 200.0 && small < 400.0, "small {small}");
        // Best-comp config (66, 34): ~150-190 s.
        let mid = busy(&[66, 34]);
        assert!(mid > 120.0 && mid < 220.0, "mid {mid}");
        // Best-exec config (175, 13): under the 97 s G-Plot floor.
        let fast = busy(&[175, 13]);
        assert!(fast < 95.0, "fast {fast}");
    }
}
