//! Per-component performance models — the substitutes for the paper's
//! real applications (§7.1).  Each module exposes a `profile` function
//! mapping the component's Table 1 parameters (plus the incoming data
//! rate for consumers) to a per-chunk processing profile consumed by
//! the pipeline DES.
//!
//! The models are analytic (Amdahl-style scaling, communication terms,
//! memory-bandwidth contention, CPU oversubscription) with constants
//! calibrated so Table 2's magnitudes and winners are reproduced.  The
//! auto-tuner treats them as black boxes, exactly as the paper treats
//! its applications.

pub mod grayscott;
pub mod heat;
pub mod lammps;
pub mod pdfcalc;
pub mod plots;
pub mod stagewrite;
pub mod voro;

/// Profile of a source stage (simulation): generates `n_chunks` chunks.
#[derive(Clone, Copy, Debug)]
pub struct SourceProfile {
    pub n_chunks: usize,
    /// Deterministic per-chunk compute + emit time, seconds.
    pub t_chunk_s: f64,
    /// Bytes streamed downstream per chunk.
    pub bytes_per_chunk: f64,
    pub procs: i64,
    pub ppn: i64,
    pub nodes: u64,
}

/// Profile of a consumer stage (analysis / visualization / writer).
#[derive(Clone, Copy, Debug)]
pub struct ConsumerProfile {
    /// Deterministic per-chunk processing time, seconds.
    pub t_chunk_s: f64,
    /// Bytes this stage emits downstream per chunk (0 for sinks).
    pub bytes_per_chunk_out: f64,
    pub procs: i64,
    pub ppn: i64,
    pub nodes: u64,
}

/// Thread-scaling efficiency: `tpp^exponent` speedup (exponent < 1
/// models synchronization + serial fractions; lower = worse threading).
pub fn thread_speedup(tpp: i64, exponent: f64) -> f64 {
    (tpp as f64).powf(exponent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_speedup_monotone_sublinear() {
        let s1 = thread_speedup(1, 0.75);
        let s2 = thread_speedup(2, 0.75);
        let s4 = thread_speedup(4, 0.75);
        assert_eq!(s1, 1.0);
        assert!(s2 > 1.0 && s2 < 2.0);
        assert!(s4 > s2 && s4 < 4.0);
    }
}
