//! PDF-calculator model (GP's analysis component): computes per-slice
//! probability-density histograms of the Gray-Scott U field.
//!
//! Parameters (Table 1): `procs` 1..512, `ppn` 1..35.
//!
//! Model: per-chunk time = ingest + embarrassingly-parallel histogram
//! (∝ cells/proc) + a reduction that grows logarithmically with p.
//! Output (the PDF itself) is tiny.

use super::ConsumerProfile;
use crate::sim::machine::Machine;

/// Histogram work coefficient, proc·s per cell per chunk.
pub const K_HIST: f64 = 3.0e-9;
/// Reduction coefficient, s·log2(p+1) per chunk.
pub const K_REDUCE: f64 = 8.0e-3;
/// Ingest bandwidth per node, GB/s.
pub const INGEST_BW_GBPS: f64 = 2.0;
/// PDF output bytes per chunk (bins × slices × f64).
pub const OUT_BYTES: f64 = 1000.0 * 384.0 * 8.0;

/// cfg = [procs, ppn]; `bytes_in` = Gray-Scott dump size.
pub fn profile(cfg: &[i64], bytes_in: f64, m: &Machine) -> ConsumerProfile {
    let (p, ppn) = (cfg[0], cfg[1]);
    let pf = p as f64;
    let nodes = m.nodes_for(p, ppn);

    let cells = bytes_in / 8.0;
    let mem = 1.0 / m.mem_factor(ppn, 1, 2.0);
    let t_hist = K_HIST * cells / pf * mem;
    let t_reduce = K_REDUCE * (pf + 1.0).log2();
    let t_ingest = bytes_in / (INGEST_BW_GBPS * 1e9 * nodes as f64);

    ConsumerProfile {
        t_chunk_s: t_ingest + t_hist + t_reduce,
        bytes_per_chunk_out: OUT_BYTES,
        procs: p,
        ppn,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::apps::grayscott;

    fn t(cfg: &[i64]) -> f64 {
        profile(cfg, grayscott::dump_bytes(), &Machine::default()).t_chunk_s
    }

    #[test]
    fn parallelism_helps() {
        assert!(t(&[64, 16]) < t(&[1, 1]));
    }

    #[test]
    fn never_dominates_gp() {
        // PDF should stay well under G-Plot's 4.85 s/chunk at sane sizes.
        for cfg in [[1, 1], [24, 23], [256, 32], [512, 35]] {
            let v = t(&cfg);
            assert!(v < 4.0, "pdf {cfg:?} -> {v}");
        }
    }
}
