//! Data-driven workflow registry: workflows are *declared* as tables,
//! not coded as branches.
//!
//! The paper's method (Alg. 1, Eqns 1-2) is workflow-structure-generic —
//! per-component models combined over a DAG — so the simulator should
//! be, too.  A [`WorkflowDef`] describes one workflow as pure data:
//!
//! * one [`ComponentDef`] per component application — its parameter
//!   space ([`ComponentSpec`]), a profile function mapping a parameter
//!   slice (plus the upstream data rate) to a per-chunk
//!   [`StageProfile`], a node-allocation rule, and how the component is
//!   run *in isolation* for component-model training ([`IsoRun`]);
//! * DAG edges ([`EdgeDef`]) carrying staging-buffer rules
//!   ([`BufferRule`]) derived from the producer's configuration.
//!
//! From that table alone, [`WorkflowSim`](crate::sim::WorkflowSim)
//! derives everything the auto-tuners consume: the pipeline topology,
//! `fill_pipeline`/`build_pipeline`, node accounting, feasibility,
//! isolated component runs, and the joint
//! [`WorkflowSpec`](crate::config::WorkflowSpec).
//!
//! The process-wide [`WorkflowRegistry`] is string-keyed: a
//! [`WorkflowId`] is a thin alias over a registered name.  The paper
//! trio (LV / HS / GP, Table 1) and the synthetic scenario families
//! (CH5 / DM4) are registered at startup from
//! [`defs`](crate::sim::defs); new scenarios register one more table
//! entry and flow untouched through pool generation, the low-fidelity
//! structure function, every tuner, campaigns, and the CLI.

use std::sync::{Arc, Mutex, OnceLock};

use super::machine::Machine;
use crate::config::{ComponentSpec, WorkflowSpec, F_MAX};

/// Upper bound on stages per workflow: lets the simulation hot path
/// keep per-stage profile scratch on the stack (no per-run allocation).
pub const MAX_STAGES: usize = 8;

/// Default buffer slots for ADIOS staging channels whose depth is not a
/// tunable parameter.
pub const DEFAULT_BUFFER_SLOTS: usize = 4;

/// Workflow identifier: a thin, `Copy` alias over a registry name.
/// Equality/hashing are by name, so it keys pool caches and campaign
/// cells exactly as the old enum did.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkflowId(&'static str);

impl WorkflowId {
    /// The paper trio (Table 1).  Experiments that reproduce paper
    /// figures iterate these; the registry may hold more — see
    /// [`WorkflowRegistry::ids`].
    pub const ALL: [WorkflowId; 3] = [WorkflowId::LV, WorkflowId::HS, WorkflowId::GP];

    /// LAMMPS + Voro++ via staging.
    pub const LV: WorkflowId = WorkflowId("LV");
    /// Heat Transfer + Stage Write.
    pub const HS: WorkflowId = WorkflowId("HS");
    /// Gray-Scott + PDF calc + two plotters.
    pub const GP: WorkflowId = WorkflowId("GP");
    /// Synthetic 5-stage deep analysis chain.
    pub const CH5: WorkflowId = WorkflowId("CH5");
    /// Synthetic diamond fan-out/fan-in with a shared-NIC producer.
    pub const DM4: WorkflowId = WorkflowId("DM4");

    pub fn name(&self) -> &'static str {
        self.0
    }

    /// Resolve a registered workflow by name (case-insensitive).
    pub fn from_name(name: &str) -> Option<WorkflowId> {
        WorkflowRegistry::global().resolve(name)
    }

    /// The workflow's registered definition table.
    pub fn def(&self) -> Arc<WorkflowDef> {
        WorkflowRegistry::global().get(*self).unwrap_or_else(|| {
            panic!(
                "workflow '{}' is not registered (registered: {})",
                self.0,
                WorkflowRegistry::global().names().join(", ")
            )
        })
    }

    /// The workflow's joint parameter space, derived from its table.
    pub fn spec(&self) -> WorkflowSpec {
        self.def().spec()
    }
}

impl std::fmt::Display for WorkflowId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

/// Unified per-chunk processing profile of one stage, as computed by a
/// [`ProfileFn`] from the component's parameter slice.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageProfile {
    /// Deterministic per-chunk processing time, seconds.
    pub t_chunk_s: f64,
    /// Chunks this stage *generates*.  Only the workflow's source sets
    /// this (> 0); consumers leave it 0 and inherit the run's count.
    pub n_chunks: usize,
    /// Bytes streamed downstream per chunk (0 for sinks).
    pub bytes_out: f64,
    /// Nodes the stage occupies (0 = colocated with another allocation).
    pub nodes: u64,
}

/// Upstream context handed to a [`ProfileFn`]: the aggregate incoming
/// bytes per chunk (summed over in-edges) and the run's chunk count
/// (0 when profiling the source itself, which defines it).
#[derive(Clone, Copy, Debug)]
pub struct Upstream {
    pub bytes: f64,
    pub n_chunks: usize,
}

/// Per-component profile rule: parameter slice + upstream context +
/// machine → per-chunk profile.
pub type ProfileFn = fn(&[i64], Upstream, &Machine) -> StageProfile;

/// Per-component node-allocation rule: parameter slice + machine →
/// nodes charged against the allocation budget.
pub type NodesFn = fn(&[i64], &Machine) -> u64;

/// How a component runs *in isolation* for component-model training
/// (Alg. 1 lines 1-6).
#[derive(Clone, Copy, Debug)]
pub enum IsoRun {
    /// Sources derive their own chunk count from the configuration and
    /// run against a sink that never blocks.
    Source,
    /// Consumers run fed from staged input that never starves: `bytes`
    /// per chunk for `chunks` canonical chunks.  The producer's cadence
    /// is not part of a consumer's own configuration — precisely the
    /// approximation that keeps component models low-fidelity.
    Consumer { bytes: f64, chunks: usize },
}

/// Staging-buffer behaviour of one edge, derived from the *producer's*
/// parameter slice by a [`BufferRuleFn`].
#[derive(Clone, Copy, Debug)]
pub struct BufferRule {
    /// The raw transfer time is divided by this efficiency factor
    /// (1.0 = no modifier; HS divides by its ADIOS buffer efficiency).
    pub xfer_divisor: f64,
    /// Buffer capacity in chunks (>= 1).
    pub capacity: usize,
}

impl Default for BufferRule {
    fn default() -> Self {
        BufferRule {
            xfer_divisor: 1.0,
            capacity: DEFAULT_BUFFER_SLOTS,
        }
    }
}

/// Edge buffer rule: producer parameter slice → buffer behaviour.
pub type BufferRuleFn = fn(&[i64]) -> BufferRule;

fn default_buffer_rule(_producer_cfg: &[i64]) -> BufferRule {
    BufferRule::default()
}

/// One component application's table entry.
#[derive(Clone, Debug)]
pub struct ComponentDef {
    /// Parameter space (name + Table-1-style parameter list; may be
    /// empty for fixed components like GP's plotters).
    pub spec: ComponentSpec,
    /// Stage label used by the pipeline topology and reports.  Must
    /// match `spec.name` (validated at registration); kept separately
    /// because topology labels are `&'static str`.
    pub stage_name: &'static str,
    pub profile: ProfileFn,
    pub nodes: NodesFn,
    pub iso: IsoRun,
}

/// One staging channel's table entry.  Components must be listed in
/// topological order, so edges always point forward (`from < to`) —
/// which also makes every definition trivially acyclic.
#[derive(Clone, Copy, Debug)]
pub struct EdgeDef {
    pub from: usize,
    pub to: usize,
    /// Buffer rule evaluated on the producer's parameter slice.
    pub buffer: BufferRuleFn,
}

impl EdgeDef {
    /// A plain staging channel: default depth, no transfer modifier.
    pub fn staged(from: usize, to: usize) -> EdgeDef {
        EdgeDef {
            from,
            to,
            buffer: default_buffer_rule,
        }
    }
}

/// A complete declarative workflow definition.
#[derive(Clone, Debug)]
pub struct WorkflowDef {
    pub name: &'static str,
    /// Components in topological order; component 0 is the source.
    pub components: Vec<ComponentDef>,
    /// DAG edges in channel order (forward-pointing; validated).
    pub edges: Vec<EdgeDef>,
    /// Reference (expert) configuration per objective — the baseline
    /// campaigns measure improvement against (paper Table 2 for the
    /// trio; hand-picked mid-range configurations for synthetic
    /// scenarios).
    pub expert_exec: Vec<i64>,
    pub expert_comp: Vec<i64>,
}

impl WorkflowDef {
    pub fn id(&self) -> WorkflowId {
        WorkflowId(self.name)
    }

    /// The joint parameter space this table induces.
    pub fn spec(&self) -> WorkflowSpec {
        WorkflowSpec::new(
            self.name,
            self.components.iter().map(|c| c.spec.clone()).collect(),
        )
    }

    pub fn n_params(&self) -> usize {
        self.components.iter().map(|c| c.spec.params.len()).sum()
    }

    /// Structural validation — every invariant the generic simulation
    /// path relies on.  Registration refuses invalid tables.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.components.len();
        if self.name.is_empty() || !self.name.chars().all(|c| c.is_ascii_alphanumeric()) {
            return Err(format!(
                "workflow name '{}' must be non-empty ASCII alphanumeric",
                self.name
            ));
        }
        if n == 0 || n > MAX_STAGES {
            return Err(format!(
                "{}: {} components (must be 1..={MAX_STAGES})",
                self.name, n
            ));
        }
        for c in &self.components {
            if c.stage_name != c.spec.name {
                return Err(format!(
                    "{}: stage name '{}' != component spec name '{}'",
                    self.name, c.stage_name, c.spec.name
                ));
            }
        }
        let total = self.n_params();
        if total > F_MAX {
            return Err(format!(
                "{}: {total} joint parameters exceed F_MAX={F_MAX}",
                self.name
            ));
        }
        // Edges: forward-pointing (topological listing ⇒ acyclic),
        // in-range, and exactly one root — component 0, the source
        // that defines the run's chunk count.
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            if e.to >= n || e.from >= e.to {
                return Err(format!(
                    "{}: edge {}->{} must point forward within {} components",
                    self.name, e.from, e.to, n
                ));
            }
            indeg[e.to] += 1;
        }
        let roots: Vec<usize> = (0..n).filter(|&u| indeg[u] == 0).collect();
        if roots != [0] {
            return Err(format!(
                "{}: components {roots:?} have no in-edge; exactly component 0 \
                 must be the (single) source",
                self.name
            ));
        }
        // Expert configurations: correct arity, admissible values,
        // feasible on the default machine, with sane buffer rules.
        let m = Machine::default();
        for (label, cfg) in [("expert_exec", &self.expert_exec), ("expert_comp", &self.expert_comp)]
        {
            if cfg.len() != total {
                return Err(format!(
                    "{}: {label} arity {} != {total} joint parameters",
                    self.name,
                    cfg.len()
                ));
            }
            let mut off = 0;
            let mut nodes = 0u64;
            for c in &self.components {
                let slice = &cfg[off..off + c.spec.params.len()];
                for (p, &v) in c.spec.params.iter().zip(slice) {
                    if p.index_of(v).is_none() {
                        return Err(format!(
                            "{}: {label} {}={v} not admissible for {}",
                            self.name, p.name, c.spec.name
                        ));
                    }
                }
                nodes += (c.nodes)(slice, &m);
                off += c.spec.params.len();
            }
            if nodes > m.max_nodes {
                return Err(format!(
                    "{}: {label} allocates {nodes} nodes (> {} cap)",
                    self.name, m.max_nodes
                ));
            }
            for e in &self.edges {
                let poff: usize = self.components[..e.from]
                    .iter()
                    .map(|c| c.spec.params.len())
                    .sum();
                let pslice = &cfg[poff..poff + self.components[e.from].spec.params.len()];
                self.check_buffer_rule(e, pslice)?;
            }
        }
        // Buffer rules must hold across the producer's whole space, not
        // just the expert picks: probe a fixed-seed random sample of
        // producer configurations per edge, so a rule that misbehaves
        // on some admissible value fails at registration instead of
        // panicking deep inside pool generation.
        let mut rng = crate::util::rng::Pcg32::new(0xB0F4_0001, 17);
        for e in &self.edges {
            let producer = &self.components[e.from].spec;
            for _ in 0..64 {
                let slice = producer.sample(&mut rng);
                self.check_buffer_rule(e, &slice)?;
            }
        }
        Ok(())
    }

    fn check_buffer_rule(&self, e: &EdgeDef, producer_cfg: &[i64]) -> Result<(), String> {
        let rule = (e.buffer)(producer_cfg);
        if rule.capacity < 1 || rule.xfer_divisor.is_nan() || rule.xfer_divisor <= 0.0 {
            return Err(format!(
                "{}: edge {}->{} buffer rule gave capacity {} / divisor {} \
                 for producer config {producer_cfg:?}",
                self.name, e.from, e.to, rule.capacity, rule.xfer_divisor
            ));
        }
        Ok(())
    }
}

/// Process-wide, string-keyed store of workflow definitions.  Built-in
/// tables register on first use; callers may [`register`] more at any
/// time (e.g. test scenarios) — names are unique, case-insensitively.
///
/// [`register`]: WorkflowRegistry::register
pub struct WorkflowRegistry {
    defs: Mutex<Vec<Arc<WorkflowDef>>>,
}

impl WorkflowRegistry {
    /// The process-wide registry, with the built-in definitions from
    /// [`defs`](crate::sim::defs) pre-registered.
    pub fn global() -> &'static WorkflowRegistry {
        static GLOBAL: OnceLock<WorkflowRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let reg = WorkflowRegistry {
                defs: Mutex::new(Vec::new()),
            };
            for def in super::defs::builtin_defs() {
                reg.register(def).expect("built-in workflow table invalid");
            }
            reg
        })
    }

    /// An empty registry (tests).
    pub fn empty() -> WorkflowRegistry {
        WorkflowRegistry {
            defs: Mutex::new(Vec::new()),
        }
    }

    /// Validate and add a definition; returns its id.  Dynamic names
    /// can be made `'static` with `Box::leak` — registry entries live
    /// for the process anyway.
    pub fn register(&self, def: WorkflowDef) -> Result<WorkflowId, String> {
        def.validate()?;
        let mut defs = self.defs.lock().unwrap();
        if defs.iter().any(|d| d.name.eq_ignore_ascii_case(def.name)) {
            return Err(format!("workflow '{}' is already registered", def.name));
        }
        let id = def.id();
        defs.push(Arc::new(def));
        Ok(id)
    }

    /// Case-insensitive name lookup.
    pub fn resolve(&self, name: &str) -> Option<WorkflowId> {
        self.defs
            .lock()
            .unwrap()
            .iter()
            .find(|d| d.name.eq_ignore_ascii_case(name))
            .map(|d| d.id())
    }

    pub fn get(&self, id: WorkflowId) -> Option<Arc<WorkflowDef>> {
        self.defs
            .lock()
            .unwrap()
            .iter()
            .find(|d| d.name.eq_ignore_ascii_case(id.0))
            .map(Arc::clone)
    }

    /// Registered ids, in registration order.
    pub fn ids(&self) -> Vec<WorkflowId> {
        self.defs.lock().unwrap().iter().map(|d| d.id()).collect()
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.defs.lock().unwrap().iter().map(|d| d.name).collect()
    }

    /// Snapshot of every registered definition.
    pub fn defs(&self) -> Vec<Arc<WorkflowDef>> {
        self.defs.lock().unwrap().iter().map(Arc::clone).collect()
    }

    pub fn len(&self) -> usize {
        self.defs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, ParamDef};
    use crate::sim::WorkflowSim;
    use crate::util::rng::Pcg32;

    fn toy_component(name: &'static str, params: Vec<ParamDef>) -> ComponentDef {
        fn profile(_: &[i64], up: Upstream, _: &Machine) -> StageProfile {
            StageProfile {
                t_chunk_s: 1.0,
                n_chunks: if up.n_chunks == 0 { 4 } else { 0 },
                bytes_out: 1.0,
                nodes: 1,
            }
        }
        fn nodes(_: &[i64], _: &Machine) -> u64 {
            1
        }
        ComponentDef {
            spec: ComponentSpec::new(name, params),
            stage_name: name,
            profile,
            nodes,
            iso: IsoRun::Source,
        }
    }

    fn toy_def() -> WorkflowDef {
        WorkflowDef {
            name: "TOY",
            components: vec![
                toy_component("a", vec![ParamDef::range("p", 1, 4)]),
                toy_component("b", vec![ParamDef::range("q", 1, 4)]),
            ],
            edges: vec![EdgeDef::staged(0, 1)],
            expert_exec: vec![2, 2],
            expert_comp: vec![1, 1],
        }
    }

    #[test]
    fn register_resolve_and_lookup() {
        let reg = WorkflowRegistry::empty();
        assert!(reg.is_empty());
        let id = reg.register(toy_def()).unwrap();
        assert_eq!(id.name(), "TOY");
        assert_eq!(reg.resolve("toy"), Some(id));
        assert_eq!(reg.resolve("nope"), None);
        assert_eq!(reg.names(), vec!["TOY"]);
        assert!(reg.get(id).is_some());
        // duplicate names are refused, case-insensitively
        let mut dup = toy_def();
        dup.name = "Toy";
        assert!(reg.register(dup).unwrap_err().contains("already registered"));
    }

    #[test]
    fn validation_rejects_bad_tables() {
        // backward edge (cycle under the topological-listing rule)
        let mut d = toy_def();
        d.edges = vec![EdgeDef::staged(0, 1), EdgeDef { from: 1, to: 0, buffer: |_| BufferRule::default() }];
        assert!(d.validate().unwrap_err().contains("forward"));
        // two roots
        let mut d = toy_def();
        d.edges.clear();
        assert!(d.validate().unwrap_err().contains("source"));
        // expert arity mismatch
        let mut d = toy_def();
        d.expert_exec = vec![2];
        assert!(d.validate().unwrap_err().contains("arity"));
        // inadmissible expert value
        let mut d = toy_def();
        d.expert_comp = vec![9, 1];
        assert!(d.validate().unwrap_err().contains("not admissible"));
        // stage name / spec name mismatch
        let mut d = toy_def();
        d.components[0].stage_name = "wrong";
        assert!(d.validate().unwrap_err().contains("spec name"));
        // a sane table passes
        assert!(toy_def().validate().is_ok());
    }

    #[test]
    fn global_registry_has_builtins() {
        let reg = WorkflowRegistry::global();
        for id in [WorkflowId::LV, WorkflowId::HS, WorkflowId::GP, WorkflowId::CH5, WorkflowId::DM4]
        {
            assert!(reg.get(id).is_some(), "{id} missing from global registry");
            assert_eq!(WorkflowId::from_name(id.name()), Some(id));
        }
        assert_eq!(WorkflowId::from_name("ch5"), Some(WorkflowId::CH5));
        assert_eq!(WorkflowId::from_name("zz"), None);
    }

    /// Satellite invariants: every registered workflow has acyclic
    /// (forward) edges, a single source, spec arity matching its
    /// components, valid+feasible expert configurations, and at least
    /// one feasible configuration (joint and per configurable
    /// component).
    #[test]
    fn registered_workflows_satisfy_invariants() {
        for def in WorkflowRegistry::global().defs() {
            assert!(def.validate().is_ok(), "{}: {:?}", def.name, def.validate());
            let spec = def.spec();
            assert_eq!(
                spec.n_params(),
                def.n_params(),
                "{}: spec arity diverged from table",
                def.name
            );
            let sim = WorkflowSim::new(def.id());
            let mut rng = Pcg32::new(0xFEA5, 7);
            let feasible = |c: &Config| sim.feasible(c);
            let cfg = sim
                .spec
                .try_sample_feasible(&mut rng, &feasible, 100_000)
                .unwrap_or_else(|e| panic!("{}: {e}", def.name));
            assert!(sim.feasible(&cfg) && sim.spec.validate(&cfg).is_ok());
            for &j in &sim.spec.configurable() {
                let comp_cfg = sim
                    .sample_component_feasible(j, &mut rng)
                    .unwrap_or_else(|e| panic!("{}: {e}", def.name));
                assert!(sim.component_feasible(j, &comp_cfg));
            }
            for cfg in [&def.expert_exec, &def.expert_comp] {
                let cfg = Config(cfg.clone());
                assert!(sim.spec.validate(&cfg).is_ok(), "{}: expert invalid", def.name);
                assert!(sim.feasible(&cfg), "{}: expert infeasible", def.name);
            }
        }
    }
}
