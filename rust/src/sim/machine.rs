//! Cluster model: the substitute for the paper's 600-node Broadwell
//! cluster with Omni-Path interconnect (§7.1). Workflows run with
//! exclusive access to allocations of up to [`Machine::max_nodes`].

/// Static machine parameters. Defaults mirror the paper's testbed:
/// 2×18-core E5-2695v4 (36 cores, no hyperthreading), 128 GB DDR4,
/// 100 Gb/s Omni-Path, and a parallel filesystem shared per allocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Machine {
    /// Largest allocation a workflow may use (paper: 32).
    pub max_nodes: u64,
    /// Physical cores per node (paper: 36).
    pub cores_per_node: u64,
    /// Aggregate per-node memory bandwidth, GB/s (DDR4-2400 4ch ×2).
    pub mem_bw_gbps: f64,
    /// Per-node network injection bandwidth, GB/s (100 Gb OPA ≈ 12.3).
    pub nic_bw_gbps: f64,
    /// Aggregate filesystem write bandwidth, GB/s.
    pub fs_bw_gbps: f64,
    /// Per-message network latency, seconds.
    pub net_latency_s: f64,
    /// Job launch overhead: fixed + per-node, seconds.
    pub startup_fixed_s: f64,
    pub startup_per_node_s: f64,
}

impl Default for Machine {
    fn default() -> Self {
        Machine {
            max_nodes: 32,
            cores_per_node: 36,
            mem_bw_gbps: 120.0,
            nic_bw_gbps: 12.3,
            fs_bw_gbps: 6.0,
            net_latency_s: 2.0e-6,
            startup_fixed_s: 1.2,
            startup_per_node_s: 0.02,
        }
    }
}

impl Machine {
    /// Nodes needed to host `procs` ranks at `ppn` ranks per node.
    pub fn nodes_for(&self, procs: i64, ppn: i64) -> u64 {
        assert!(procs > 0 && ppn > 0, "procs/ppn must be positive");
        ((procs + ppn - 1) / ppn) as u64
    }

    /// Startup (launch + connection establishment) for an allocation.
    pub fn startup_s(&self, nodes: u64) -> f64 {
        self.startup_fixed_s + self.startup_per_node_s * nodes as f64
    }

    /// Memory-bandwidth contention factor for `ppn` ranks × `tpp`
    /// threads of a kernel needing `gb_per_core` GB/s per active core:
    /// 1.0 when the node's bandwidth covers demand, < 1.0 otherwise.
    pub fn mem_factor(&self, ppn: i64, tpp: i64, gb_per_core: f64) -> f64 {
        let demand = (ppn * tpp) as f64 * gb_per_core;
        if demand <= self.mem_bw_gbps {
            1.0
        } else {
            self.mem_bw_gbps / demand
        }
    }

    /// CPU oversubscription penalty: running `ppn*tpp` busy threads on
    /// `cores_per_node` cores. 1.0 when not oversubscribed; grows a bit
    /// super-linearly with the oversubscription ratio (context-switch
    /// and cache thrash).
    pub fn oversub_factor(&self, ppn: i64, tpp: i64) -> f64 {
        let load = (ppn * tpp) as f64 / self.cores_per_node as f64;
        if load <= 1.0 {
            1.0
        } else {
            load
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nodes_for_rounds_up() {
        let m = Machine::default();
        assert_eq!(m.nodes_for(36, 36), 1);
        assert_eq!(m.nodes_for(37, 36), 2);
        assert_eq!(m.nodes_for(430, 23), 19);
        assert_eq!(m.nodes_for(1, 35), 1);
    }

    #[test]
    fn mem_factor_saturates() {
        let m = Machine::default();
        assert_eq!(m.mem_factor(4, 1, 2.0), 1.0);
        let f = m.mem_factor(35, 4, 2.0); // demand 280 GB/s > 120
        assert!(f < 0.5 && f > 0.3, "{f}");
    }

    #[test]
    fn oversub_kicks_in_past_full() {
        let m = Machine::default();
        assert_eq!(m.oversub_factor(35, 1), 1.0);
        assert_eq!(m.oversub_factor(36, 1), 1.0);
        let f = m.oversub_factor(35, 4); // 140 threads on 36 cores
        assert!(f > 3.8, "{f}");
    }

    #[test]
    fn startup_grows_with_nodes() {
        let m = Machine::default();
        assert!(m.startup_s(32) > m.startup_s(1));
    }
}
