//! Measurement and optimization-objective types.
//!
//! The paper evaluates two objectives (§7.1): *execution time* — the
//! longest component end-to-end wall-clock time — and *computer time* —
//! execution time × nodes × cores-per-node (core-hours).
//!
//! [`Measurement`] is `Copy` by design: the collector's hot path
//! ([`WorkflowSim::run_with`](crate::sim::WorkflowSim::run_with) through
//! a reused [`SimWorkspace`](crate::sim::SimWorkspace)) returns it by
//! value with no heap traffic.

/// Result of running a workflow (or an isolated component) once.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// Wall-clock seconds (longest component).
    pub exec_time_s: f64,
    /// Core-hours consumed: exec_time × nodes × cores_per_node / 3600.
    pub computer_time_core_h: f64,
    /// Compute nodes allocated.
    pub nodes: u64,
}

impl Measurement {
    pub fn new(exec_time_s: f64, nodes: u64, cores_per_node: u64) -> Self {
        Measurement {
            exec_time_s,
            computer_time_core_h: exec_time_s * nodes as f64 * cores_per_node as f64
                / 3600.0,
            nodes,
        }
    }
}

/// The optimization objective of a tuning campaign.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Minimize wall-clock execution time (bottleneck metric → Eqn 1,
    /// combine component predictions with `max`).
    ExecTime,
    /// Minimize core-hours (aggregate metric → Eqn 2, combine with
    /// `sum`).
    CompTime,
}

impl Objective {
    pub const ALL: [Objective; 2] = [Objective::ExecTime, Objective::CompTime];

    /// Extract this objective's scalar from a measurement (lower is
    /// better for both).
    pub fn value(&self, m: &Measurement) -> f64 {
        match self {
            Objective::ExecTime => m.exec_time_s,
            Objective::CompTime => m.computer_time_core_h,
        }
    }

    /// Combination-mode scalar fed to the `lowfi_score` artifact:
    /// 1.0 selects max (Eqn 1), 0.0 selects sum (Eqn 2).
    pub fn mode(&self) -> f32 {
        match self {
            Objective::ExecTime => 1.0,
            Objective::CompTime => 0.0,
        }
    }

    /// Combine per-component predictions on the native path (must match
    /// the artifact semantics bit-for-bit in spirit: max vs sum).
    pub fn combine(&self, parts: &[f64]) -> f64 {
        match self {
            Objective::ExecTime => parts.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            Objective::CompTime => parts.iter().sum(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::ExecTime => "exec_time",
            Objective::CompTime => "comp_time",
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Objective::ExecTime => "s",
            Objective::CompTime => "core-h",
        }
    }

    pub fn from_name(name: &str) -> Option<Objective> {
        match name.to_ascii_lowercase().as_str() {
            "exec" | "exec_time" | "execution" => Some(Objective::ExecTime),
            "comp" | "comp_time" | "computer" => Some(Objective::CompTime),
            _ => None,
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computer_time_formula() {
        // 100 s on 1 node of 36 cores = 1 core-hour.
        let m = Measurement::new(100.0, 1, 36);
        assert!((m.computer_time_core_h - 1.0).abs() < 1e-12);
        // scales linearly with nodes
        let m10 = Measurement::new(100.0, 10, 36);
        assert!((m10.computer_time_core_h - 10.0).abs() < 1e-12);
    }

    #[test]
    fn objective_extraction_and_mode() {
        let m = Measurement::new(50.0, 4, 36);
        assert_eq!(Objective::ExecTime.value(&m), 50.0);
        assert!((Objective::CompTime.value(&m) - 2.0).abs() < 1e-12);
        assert_eq!(Objective::ExecTime.mode(), 1.0);
        assert_eq!(Objective::CompTime.mode(), 0.0);
    }

    #[test]
    fn combination_functions() {
        let parts = [3.0, 7.0, 2.0];
        assert_eq!(Objective::ExecTime.combine(&parts), 7.0);
        assert_eq!(Objective::CompTime.combine(&parts), 12.0);
    }

    #[test]
    fn names_roundtrip() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("comp"), Some(Objective::CompTime));
    }
}
