//! Measurement and optimization-objective types.
//!
//! The paper evaluates two objectives (§7.1): *execution time* — the
//! longest component end-to-end wall-clock time — and *computer time* —
//! execution time × nodes × cores-per-node (core-hours).
//!
//! [`Measurement`] is `Copy` by design: the collector's hot path
//! ([`WorkflowSim::run_with`](crate::sim::WorkflowSim::run_with) through
//! a reused [`SimWorkspace`](crate::sim::SimWorkspace)) returns it by
//! value with no heap traffic.

/// Result of running a workflow (or an isolated component) once.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// Wall-clock seconds (longest component).
    pub exec_time_s: f64,
    /// Core-hours consumed: exec_time × nodes × cores_per_node / 3600.
    pub computer_time_core_h: f64,
    /// Compute nodes allocated.
    pub nodes: u64,
}

impl Measurement {
    pub fn new(exec_time_s: f64, nodes: u64, cores_per_node: u64) -> Self {
        Measurement {
            exec_time_s,
            computer_time_core_h: exec_time_s * nodes as f64 * cores_per_node as f64
                / 3600.0,
            nodes,
        }
    }
}

/// Why a measurement attempt produced no usable reading.
///
/// The taxonomy follows the in-situ deployment failure modes the
/// simulator's fault layer ([`crate::tuner::faults`]) injects: the run
/// itself can die, the reading can be lost between the workflow and
/// the tuner, or a reading can arrive but be recognisably wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FailureKind {
    /// The workflow (or isolated component) run crashed before
    /// producing a reading.
    Crash,
    /// The run finished but its reading was lost in transport (e.g. a
    /// staging/daemon hop dropped it).
    Transport,
    /// A reading arrived but was detected as corrupted and discarded
    /// by the evaluator itself (silent corruption that survives
    /// delivery is instead handled by the sessions' outlier gate).
    CorruptedReading,
}

impl FailureKind {
    /// Stable short name (used by the v2 session-trace format).
    pub fn name(&self) -> &'static str {
        match self {
            FailureKind::Crash => "crash",
            FailureKind::Transport => "transport",
            FailureKind::CorruptedReading => "corrupt",
        }
    }

    pub fn from_name(name: &str) -> Option<FailureKind> {
        match name {
            "crash" => Some(FailureKind::Crash),
            "transport" => Some(FailureKind::Transport),
            "corrupt" => Some(FailureKind::CorruptedReading),
            _ => None,
        }
    }
}

/// The outcome of one measurement attempt: a usable objective value,
/// a failure, or a deadline miss.  Sessions treat [`Failed`] and
/// [`TimedOut`] identically for retry purposes but account them
/// separately in traces (a timeout's wall-clock charge is real spent
/// time, not an estimate).
///
/// [`Failed`]: MeasurementOutcome::Failed
/// [`TimedOut`]: MeasurementOutcome::TimedOut
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MeasurementOutcome {
    /// The attempt delivered a reading (possibly noisy or silently
    /// corrupted — delivery says nothing about trustworthiness).
    Ok(f64),
    /// The attempt produced no reading.
    Failed(FailureKind),
    /// The attempt exceeded its deadline and was abandoned.
    TimedOut,
}

impl MeasurementOutcome {
    /// The delivered value, if any.
    pub fn value(&self) -> Option<f64> {
        match self {
            MeasurementOutcome::Ok(v) => Some(*v),
            _ => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, MeasurementOutcome::Ok(_))
    }

    /// Stable short name for trace serialization; `None` for [`Ok`]
    /// outcomes (they serialize as their numeric value).
    ///
    /// [`Ok`]: MeasurementOutcome::Ok
    pub fn fault_name(&self) -> Option<&'static str> {
        match self {
            MeasurementOutcome::Ok(_) => None,
            MeasurementOutcome::Failed(k) => Some(k.name()),
            MeasurementOutcome::TimedOut => Some("timeout"),
        }
    }

    /// Inverse of [`fault_name`](Self::fault_name) for trace parsing.
    pub fn from_fault_name(name: &str) -> Option<MeasurementOutcome> {
        if name == "timeout" {
            return Some(MeasurementOutcome::TimedOut);
        }
        FailureKind::from_name(name).map(MeasurementOutcome::Failed)
    }
}

/// The optimization objective of a tuning campaign.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Minimize wall-clock execution time (bottleneck metric → Eqn 1,
    /// combine component predictions with `max`).
    ExecTime,
    /// Minimize core-hours (aggregate metric → Eqn 2, combine with
    /// `sum`).
    CompTime,
}

impl Objective {
    pub const ALL: [Objective; 2] = [Objective::ExecTime, Objective::CompTime];

    /// Extract this objective's scalar from a measurement (lower is
    /// better for both).
    pub fn value(&self, m: &Measurement) -> f64 {
        match self {
            Objective::ExecTime => m.exec_time_s,
            Objective::CompTime => m.computer_time_core_h,
        }
    }

    /// Combination-mode scalar fed to the `lowfi_score` artifact:
    /// 1.0 selects max (Eqn 1), 0.0 selects sum (Eqn 2).
    pub fn mode(&self) -> f32 {
        match self {
            Objective::ExecTime => 1.0,
            Objective::CompTime => 0.0,
        }
    }

    /// Combine per-component predictions on the native path (must match
    /// the artifact semantics bit-for-bit in spirit: max vs sum).
    pub fn combine(&self, parts: &[f64]) -> f64 {
        match self {
            Objective::ExecTime => parts.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            Objective::CompTime => parts.iter().sum(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::ExecTime => "exec_time",
            Objective::CompTime => "comp_time",
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Objective::ExecTime => "s",
            Objective::CompTime => "core-h",
        }
    }

    pub fn from_name(name: &str) -> Option<Objective> {
        match name.to_ascii_lowercase().as_str() {
            "exec" | "exec_time" | "execution" => Some(Objective::ExecTime),
            "comp" | "comp_time" | "computer" => Some(Objective::CompTime),
            _ => None,
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computer_time_formula() {
        // 100 s on 1 node of 36 cores = 1 core-hour.
        let m = Measurement::new(100.0, 1, 36);
        assert!((m.computer_time_core_h - 1.0).abs() < 1e-12);
        // scales linearly with nodes
        let m10 = Measurement::new(100.0, 10, 36);
        assert!((m10.computer_time_core_h - 10.0).abs() < 1e-12);
    }

    #[test]
    fn objective_extraction_and_mode() {
        let m = Measurement::new(50.0, 4, 36);
        assert_eq!(Objective::ExecTime.value(&m), 50.0);
        assert!((Objective::CompTime.value(&m) - 2.0).abs() < 1e-12);
        assert_eq!(Objective::ExecTime.mode(), 1.0);
        assert_eq!(Objective::CompTime.mode(), 0.0);
    }

    #[test]
    fn combination_functions() {
        let parts = [3.0, 7.0, 2.0];
        assert_eq!(Objective::ExecTime.combine(&parts), 7.0);
        assert_eq!(Objective::CompTime.combine(&parts), 12.0);
    }

    #[test]
    fn names_roundtrip() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("comp"), Some(Objective::CompTime));
    }

    #[test]
    fn outcome_accessors_and_fault_names() {
        let ok = MeasurementOutcome::Ok(4.25);
        assert!(ok.is_ok());
        assert_eq!(ok.value(), Some(4.25));
        assert_eq!(ok.fault_name(), None);

        for outcome in [
            MeasurementOutcome::Failed(FailureKind::Crash),
            MeasurementOutcome::Failed(FailureKind::Transport),
            MeasurementOutcome::Failed(FailureKind::CorruptedReading),
            MeasurementOutcome::TimedOut,
        ] {
            assert!(!outcome.is_ok());
            assert_eq!(outcome.value(), None);
            let name = outcome.fault_name().expect("non-ok outcomes have names");
            assert_eq!(MeasurementOutcome::from_fault_name(name), Some(outcome));
        }
        assert_eq!(MeasurementOutcome::from_fault_name("nope"), None);
    }
}
