//! Workflow assembly: LV / HS / GP wired onto the pipeline DES, plus
//! isolated component runs (the collector for component-model training)
//! and the feasibility rule (allocations ≤ 32 nodes, §7.1).
//!
//! The measurement hot path is allocation-free: each [`WorkflowSim`]
//! precomputes its immutable [`PipelineStructure`] once, and
//! [`fill_pipeline`](WorkflowSim::fill_pipeline) writes a run's
//! parameters into a caller-owned [`SimWorkspace`].  Collectors hold one
//! workspace and thread it through [`run_with`](WorkflowSim::run_with) /
//! [`expected_with`](WorkflowSim::expected_with); the argument-free
//! [`run`](WorkflowSim::run) / [`expected`](WorkflowSim::expected)
//! wrappers build a throwaway workspace for one-off calls.

use super::apps::{grayscott, heat, lammps, pdfcalc, plots, stagewrite};
use super::machine::Machine;
use super::measurement::Measurement;
use super::pipeline::{Edge, Pipeline, PipelineStructure, SimWorkspace, Stage};
use crate::config::{Config, WorkflowId, WorkflowSpec};
use crate::util::rng::Pcg32;

/// Default buffer slots for ADIOS staging channels whose depth is not a
/// tunable parameter (LV and GP edges).
pub const DEFAULT_BUFFER_SLOTS: usize = 4;
/// Default run-to-run noise (lognormal sigma on per-chunk times).
pub const DEFAULT_NOISE_SIGMA: f64 = 0.03;
/// Canonical chunk counts for isolated consumer runs (the producer's
/// cadence is not part of a consumer's own configuration — this is
/// precisely the approximation that keeps component models low-fidelity).
pub const ISO_CHUNKS_VORO: usize = 8;
pub const ISO_CHUNKS_STAGEWRITE: usize = 8;
pub const ISO_CHUNKS_PDF: usize = 10;

/// The in-situ workflow simulator: the collector's backend.
#[derive(Clone, Debug)]
pub struct WorkflowSim {
    pub id: WorkflowId,
    pub spec: WorkflowSpec,
    pub machine: Machine,
    pub noise_sigma: f64,
    /// Immutable topology shared by every run of this workflow.
    structure: PipelineStructure,
}

impl WorkflowSim {
    pub fn new(id: WorkflowId) -> Self {
        let structure = match id {
            WorkflowId::Lv => PipelineStructure::new(vec!["LAMMPS", "Voro++"], vec![(0, 1)]),
            WorkflowId::Hs => {
                PipelineStructure::new(vec!["HeatTransfer", "StageWrite"], vec![(0, 1)])
            }
            WorkflowId::Gp => PipelineStructure::new(
                vec!["GrayScott", "PDFcalc", "G-Plot", "P-Plot"],
                vec![(0, 1), (0, 2), (1, 3)],
            ),
        };
        WorkflowSim {
            id,
            spec: id.spec(),
            machine: Machine::default(),
            noise_sigma: DEFAULT_NOISE_SIGMA,
            structure,
        }
    }

    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// The workflow's immutable pipeline topology.
    pub fn structure(&self) -> &PipelineStructure {
        &self.structure
    }

    /// Total nodes a configuration allocates (sum over components; the
    /// plotters colocate with the analysis allocation).
    pub fn nodes(&self, cfg: &Config) -> u64 {
        match self.id {
            WorkflowId::Lv => {
                let l = self.spec.component_slice(cfg, 0);
                let v = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(l[0], l[1]) + self.machine.nodes_for(v[0], v[1])
            }
            WorkflowId::Hs => {
                let h = self.spec.component_slice(cfg, 0);
                let s = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(h[0] * h[1], h[2])
                    + self.machine.nodes_for(s[0], s[1])
            }
            WorkflowId::Gp => {
                let g = self.spec.component_slice(cfg, 0);
                let p = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(g[0], g[1]) + self.machine.nodes_for(p[0], p[1])
            }
        }
    }

    /// The paper's pools contain only runnable configurations:
    /// allocation must fit the 32-node budget.
    pub fn feasible(&self, cfg: &Config) -> bool {
        self.nodes(cfg) <= self.machine.max_nodes
    }

    /// Nodes an *isolated* run of configurable component `j` allocates.
    pub fn component_nodes(&self, j: usize, comp_cfg: &[i64]) -> u64 {
        match (self.id, j) {
            (WorkflowId::Hs, 0) => self.machine.nodes_for(comp_cfg[0] * comp_cfg[1], comp_cfg[2]),
            _ => self.machine.nodes_for(comp_cfg[0], comp_cfg[1]),
        }
    }

    /// Isolated component runs are subject to the same allocation cap
    /// as workflow runs (§7.1: allocations up to 32 nodes).
    pub fn component_feasible(&self, j: usize, comp_cfg: &[i64]) -> bool {
        self.component_nodes(j, comp_cfg) <= self.machine.max_nodes
    }

    /// Rejection-sample a feasible configuration for component `j`.
    pub fn sample_component_feasible(&self, j: usize, rng: &mut Pcg32) -> Vec<i64> {
        let cs = &self.spec.components[j];
        for _ in 0..100_000 {
            let cfg = cs.sample(rng);
            if self.component_feasible(j, &cfg) {
                return cfg;
            }
        }
        panic!("{}: no feasible config for component {j}", self.id);
    }

    /// Write the deterministic pipeline parameters for `cfg` into `ws`
    /// (stage chunk times, edge transfer times, buffer capacities) —
    /// zero allocations once the workspace is warmed.
    pub fn fill_pipeline(&self, cfg: &Config, ws: &mut SimWorkspace) {
        let m = &self.machine;
        match self.id {
            WorkflowId::Lv => {
                let lp = lammps::profile(self.spec.component_slice(cfg, 0), m);
                let vp =
                    voro::profile(self.spec.component_slice(cfg, 1), lp.bytes_per_chunk, m);
                let xfer = transfer_time(m, lp.bytes_per_chunk, lp.nodes, vp.nodes, 1);
                ws.begin(&self.structure, lp.n_chunks);
                ws.set_stage_time(0, lp.t_chunk_s);
                ws.set_stage_time(1, vp.t_chunk_s);
                ws.set_edge(0, xfer, DEFAULT_BUFFER_SLOTS);
            }
            WorkflowId::Hs => {
                let hcfg = self.spec.component_slice(cfg, 0);
                let hp = heat::profile(hcfg, m);
                let sp = stagewrite::profile(
                    self.spec.component_slice(cfg, 1),
                    hp.bytes_per_chunk,
                    m,
                );
                let xfer = transfer_time(m, hp.bytes_per_chunk, hp.nodes, sp.nodes, 1)
                    / heat::buffer_efficiency(hcfg[4]);
                ws.begin(&self.structure, hp.n_chunks);
                ws.set_stage_time(0, hp.t_chunk_s);
                ws.set_stage_time(1, sp.t_chunk_s);
                ws.set_edge(0, xfer, heat::buffer_slots(hcfg[4]));
            }
            WorkflowId::Gp => {
                let gp = grayscott::profile(self.spec.component_slice(cfg, 0), m);
                let pp = pdfcalc::profile(
                    self.spec.component_slice(cfg, 1),
                    gp.bytes_per_chunk,
                    m,
                );
                let k = gp.n_chunks;
                let gplot = plots::gplot_profile(k, m);
                let pplot = plots::pplot_profile(k, m);
                // Gray-Scott fans out to PDF and G-Plot: its NIC is shared.
                let xfer_pdf =
                    transfer_time(m, gp.bytes_per_chunk, gp.nodes, pp.nodes, 2);
                let xfer_gplot = transfer_time(m, gp.bytes_per_chunk, gp.nodes, 1, 2);
                let xfer_pplot = transfer_time(m, pp.bytes_per_chunk_out, pp.nodes, 1, 1);
                ws.begin(&self.structure, k);
                ws.set_stage_time(0, gp.t_chunk_s);
                ws.set_stage_time(1, pp.t_chunk_s);
                ws.set_stage_time(2, gplot.t_chunk_s);
                ws.set_stage_time(3, pplot.t_chunk_s);
                ws.set_edge(0, xfer_pdf, DEFAULT_BUFFER_SLOTS);
                ws.set_edge(1, xfer_gplot, DEFAULT_BUFFER_SLOTS);
                ws.set_edge(2, xfer_pplot, DEFAULT_BUFFER_SLOTS);
            }
        }
    }

    /// Assemble the deterministic pipeline for `cfg` — the reference
    /// (allocation-heavy) counterpart of
    /// [`fill_pipeline`](Self::fill_pipeline), kept for differential
    /// tests and the benches' before/after baseline.
    pub fn build_pipeline(&self, cfg: &Config) -> Pipeline {
        let m = &self.machine;
        match self.id {
            WorkflowId::Lv => {
                let lp = lammps::profile(self.spec.component_slice(cfg, 0), m);
                let vp =
                    voro::profile(self.spec.component_slice(cfg, 1), lp.bytes_per_chunk, m);
                let k = lp.n_chunks;
                let xfer = transfer_time(m, lp.bytes_per_chunk, lp.nodes, vp.nodes, 1);
                Pipeline {
                    stages: vec![
                        stage("LAMMPS", lp.t_chunk_s, k, lp.nodes),
                        stage("Voro++", vp.t_chunk_s, k, vp.nodes),
                    ],
                    edges: vec![Edge {
                        from: 0,
                        to: 1,
                        t_transfer_s: xfer,
                        capacity: DEFAULT_BUFFER_SLOTS,
                    }],
                }
            }
            WorkflowId::Hs => {
                let hcfg = self.spec.component_slice(cfg, 0);
                let hp = heat::profile(hcfg, m);
                let sp = stagewrite::profile(
                    self.spec.component_slice(cfg, 1),
                    hp.bytes_per_chunk,
                    m,
                );
                let k = hp.n_chunks;
                let xfer = transfer_time(m, hp.bytes_per_chunk, hp.nodes, sp.nodes, 1)
                    / heat::buffer_efficiency(hcfg[4]);
                Pipeline {
                    stages: vec![
                        stage("HeatTransfer", hp.t_chunk_s, k, hp.nodes),
                        stage("StageWrite", sp.t_chunk_s, k, sp.nodes),
                    ],
                    edges: vec![Edge {
                        from: 0,
                        to: 1,
                        t_transfer_s: xfer,
                        capacity: heat::buffer_slots(hcfg[4]),
                    }],
                }
            }
            WorkflowId::Gp => {
                let gp = grayscott::profile(self.spec.component_slice(cfg, 0), m);
                let pp = pdfcalc::profile(
                    self.spec.component_slice(cfg, 1),
                    gp.bytes_per_chunk,
                    m,
                );
                let k = gp.n_chunks;
                let gplot = plots::gplot_profile(k, m);
                let pplot = plots::pplot_profile(k, m);
                // Gray-Scott fans out to PDF and G-Plot: its NIC is shared.
                let xfer_pdf =
                    transfer_time(m, gp.bytes_per_chunk, gp.nodes, pp.nodes, 2);
                let xfer_gplot = transfer_time(m, gp.bytes_per_chunk, gp.nodes, 1, 2);
                let xfer_pplot = transfer_time(m, pp.bytes_per_chunk_out, pp.nodes, 1, 1);
                Pipeline {
                    stages: vec![
                        stage("GrayScott", gp.t_chunk_s, k, gp.nodes),
                        stage("PDFcalc", pp.t_chunk_s, k, pp.nodes),
                        stage("G-Plot", gplot.t_chunk_s, k, gplot.nodes),
                        stage("P-Plot", pplot.t_chunk_s, k, pplot.nodes),
                    ],
                    edges: vec![
                        Edge {
                            from: 0,
                            to: 1,
                            t_transfer_s: xfer_pdf,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                        Edge {
                            from: 0,
                            to: 2,
                            t_transfer_s: xfer_gplot,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                        Edge {
                            from: 1,
                            to: 3,
                            t_transfer_s: xfer_pplot,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                    ],
                }
            }
        }
    }

    /// One noisy in-situ run through a caller-owned workspace — the
    /// collector's "run the workflow with configuration c and measure"
    /// (§2.1).  Allocation-free once `ws` is warmed.
    pub fn run_with(&self, cfg: &Config, rng: &mut Pcg32, ws: &mut SimWorkspace) -> Measurement {
        self.fill_pipeline(cfg, ws);
        self.apply_noise_ws(ws, rng);
        let nodes = self.nodes(cfg);
        self.structure.simulate(ws);
        let exec = ws.makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// Noise-free run through a caller-owned workspace (ground-truth
    /// expectation; constant chunk times take the steady-state fast
    /// path).
    pub fn expected_with(&self, cfg: &Config, ws: &mut SimWorkspace) -> Measurement {
        self.fill_pipeline(cfg, ws);
        let nodes = self.nodes(cfg);
        self.structure.simulate(ws);
        let exec = ws.makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// One noisy in-situ run (convenience wrapper over a per-thread
    /// scratch workspace; collectors hold their own and use
    /// [`run_with`](Self::run_with)).
    pub fn run(&self, cfg: &Config, rng: &mut Pcg32) -> Measurement {
        SCRATCH.with(|ws| self.run_with(cfg, rng, &mut ws.borrow_mut()))
    }

    /// Noise-free run (ground-truth expectation; used by experiments to
    /// rank pool configurations reproducibly).
    pub fn expected(&self, cfg: &Config) -> Measurement {
        SCRATCH.with(|ws| self.expected_with(cfg, &mut ws.borrow_mut()))
    }

    /// One noisy *isolated* run of configurable component `j` with its
    /// own parameter slice — the collector for component-model training
    /// (Alg. 1 lines 1-6). Sources run with a sink that never blocks;
    /// consumers run fed from staged input that never starves.
    pub fn run_component(&self, j: usize, comp_cfg: &[i64], rng: &mut Pcg32) -> Measurement {
        let m = &self.machine;
        let (t_chunk, k, nodes) = match (self.id, j) {
            (WorkflowId::Lv, 0) => {
                let p = lammps::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Lv, 1) => {
                let p = voro::profile(
                    comp_cfg,
                    lammps::N_ATOMS * lammps::BYTES_PER_ATOM,
                    m,
                );
                (p.t_chunk_s, ISO_CHUNKS_VORO, p.nodes)
            }
            (WorkflowId::Hs, 0) => {
                let p = heat::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Hs, 1) => {
                let p = stagewrite::profile(comp_cfg, heat::snapshot_bytes(), m);
                (p.t_chunk_s, ISO_CHUNKS_STAGEWRITE, p.nodes)
            }
            (WorkflowId::Gp, 0) => {
                let p = grayscott::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Gp, 1) => {
                let p = pdfcalc::profile(comp_cfg, grayscott::dump_bytes(), m);
                (p.t_chunk_s, ISO_CHUNKS_PDF, p.nodes)
            }
            (id, j) => panic!("{id}: component {j} is not configurable"),
        };
        let run_factor = rng.lognormal_factor(self.noise_sigma);
        let mut busy = 0.0;
        for _ in 0..k {
            busy += t_chunk * run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
        }
        let exec = busy + m.startup_s(nodes.max(1));
        Measurement::new(exec, nodes.max(1), m.cores_per_node)
    }

    /// Per-chunk multiplicative noise on a filled workspace: one run
    /// factor per stage, one chunk factor per chunk.  Draw order and
    /// arithmetic match [`apply_noise`](Self::apply_noise) exactly, so
    /// workspace runs reproduce the reference path bit-for-bit.
    fn apply_noise_ws(&self, ws: &mut SimWorkspace, rng: &mut Pcg32) {
        if self.noise_sigma <= 0.0 {
            return;
        }
        ws.make_per_chunk();
        let kc = ws.n_chunks();
        for u in 0..self.structure.n_stages() {
            let run_factor = rng.lognormal_factor(self.noise_sigma);
            for k in 0..kc {
                ws.scale_chunk(u, k, run_factor * rng.lognormal_factor(self.noise_sigma * 0.5));
            }
        }
    }

    /// Reference-path noise application (differential tests pin
    /// [`run_with`](Self::run_with) against `build_pipeline` +
    /// `apply_noise` + `simulate` with the same RNG).
    pub fn apply_noise(&self, pipeline: &mut Pipeline, rng: &mut Pcg32) {
        if self.noise_sigma <= 0.0 {
            return;
        }
        for s in &mut pipeline.stages {
            let run_factor = rng.lognormal_factor(self.noise_sigma);
            for t in &mut s.t_chunk_s {
                *t *= run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
            }
        }
    }
}

use super::apps::voro;

std::thread_local! {
    /// Per-thread scratch workspace backing the argument-free
    /// [`WorkflowSim::run`] / [`WorkflowSim::expected`] wrappers, so
    /// even one-off calls stop allocating once the thread is warm.
    static SCRATCH: std::cell::RefCell<SimWorkspace> =
        std::cell::RefCell::new(SimWorkspace::new());
}

fn stage(name: &str, t_chunk: f64, k: usize, nodes: u64) -> Stage {
    Stage {
        name: name.to_string(),
        t_chunk_s: vec![t_chunk; k],
        nodes,
    }
}

/// Per-chunk staging transfer time: aggregate NIC bandwidth of the
/// smaller side, split across the producer's concurrent out-streams.
fn transfer_time(m: &Machine, bytes: f64, nodes_from: u64, nodes_to: u64, out_degree: u64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    let agg = m.nic_bw_gbps * 1e9 * nodes_from.min(nodes_to).max(1) as f64
        / out_degree.max(1) as f64;
    bytes / agg + m.net_latency_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, assert_prop, check};

    fn lv_cfg(v: &[i64]) -> Config {
        Config(v.to_vec())
    }

    #[test]
    fn nodes_and_feasibility() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let best_exec = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        assert_eq!(sim.nodes(&best_exec), 19 + 9);
        assert!(sim.feasible(&best_exec));
        let infeasible = lv_cfg(&[1085, 1, 1, 300, 2, 1, 1]);
        assert!(!sim.feasible(&infeasible));
    }

    #[test]
    fn lv_best_exec_beats_expert() {
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[430, 23, 1, 300, 88, 10, 4]));
        let expert = sim.expected(&lv_cfg(&[288, 18, 2, 400, 288, 18, 2]));
        assert!(
            best.exec_time_s < expert.exec_time_s,
            "best {} vs expert {}",
            best.exec_time_s,
            expert.exec_time_s
        );
        // magnitudes in the Table 2 ballpark (27.2 s / 36.8 s)
        assert!(best.exec_time_s > 15.0 && best.exec_time_s < 45.0);
        assert!(expert.exec_time_s > 25.0 && expert.exec_time_s < 60.0);
    }

    #[test]
    fn lv_comp_time_favors_packed_small_allocations() {
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[175, 35, 2, 400, 38, 29, 3]));
        let expert = sim.expected(&lv_cfg(&[18, 18, 2, 400, 18, 18, 2]));
        assert!(
            best.computer_time_core_h < expert.computer_time_core_h,
            "best {} vs expert {}",
            best.computer_time_core_h,
            expert.computer_time_core_h
        );
    }

    #[test]
    fn hs_expert_writer_storm_is_slow() {
        let sim = WorkflowSim::new(WorkflowId::Hs).with_noise(0.0);
        let best = sim.expected(&Config(vec![13, 17, 14, 4, 29, 19, 3]));
        let expert = sim.expected(&Config(vec![32, 17, 34, 4, 20, 560, 35]));
        assert!(best.exec_time_s < 12.0, "best {}", best.exec_time_s);
        assert!(
            expert.exec_time_s > 2.0 * best.exec_time_s,
            "expert {} best {}",
            expert.exec_time_s,
            best.exec_time_s
        );
    }

    #[test]
    fn gp_execution_floor_is_gplot() {
        let sim = WorkflowSim::new(WorkflowId::Gp).with_noise(0.0);
        // A large, fast Gray-Scott allocation: G-Plot dominates at ~97 s.
        let fast = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            fast.exec_time_s > 95.0 && fast.exec_time_s < 125.0,
            "fast {}",
            fast.exec_time_s
        );
        // A tiny Gray-Scott allocation is simulation-bound instead.
        let slow = sim.expected(&Config(vec![35, 35, 35, 35]));
        assert!(slow.exec_time_s > 200.0, "slow {}", slow.exec_time_s);
    }

    #[test]
    fn gp_expert_comp_time_is_competitive() {
        // Paper: experts do well on GP computer time (5.85 vs 6.95).
        let sim = WorkflowSim::new(WorkflowId::Gp).with_noise(0.0);
        let expert = sim.expected(&Config(vec![35, 35, 35, 35]));
        let big = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            expert.computer_time_core_h < big.computer_time_core_h,
            "expert {} vs big {}",
            expert.computer_time_core_h,
            big.computer_time_core_h
        );
    }

    #[test]
    fn noise_perturbs_but_preserves_ranking() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let cfg = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        let mut rng = Pcg32::new(11, 0);
        let a = sim.run(&cfg, &mut rng);
        let b = sim.run(&cfg, &mut rng);
        assert_ne!(a.exec_time_s, b.exec_time_s, "noise should differ");
        let exp = sim.expected(&cfg).exec_time_s;
        for m in [a, b] {
            assert!((m.exec_time_s / exp - 1.0).abs() < 0.25);
        }
    }

    #[test]
    fn isolated_component_runs() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let mut rng = Pcg32::new(3, 0);
        let lam = sim.run_component(0, &[430, 23, 1, 300], &mut rng);
        let vor = sim.run_component(1, &[88, 10, 4], &mut rng);
        assert!(lam.exec_time_s > 10.0 && lam.exec_time_s < 60.0);
        assert!(vor.exec_time_s > 5.0 && vor.exec_time_s < 60.0);
        assert!(lam.nodes >= 1 && vor.nodes >= 1);
    }

    #[test]
    #[should_panic(expected = "not configurable")]
    fn isolated_plot_panics() {
        let sim = WorkflowSim::new(WorkflowId::Gp);
        let mut rng = Pcg32::new(3, 0);
        sim.run_component(2, &[], &mut rng);
    }

    #[test]
    fn coupling_differs_from_isolated_max() {
        // The in-situ exec time exceeds the max of isolated busy times
        // when rates mismatch (backpressure) — the paper's core premise.
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        // slow Voro (few procs) against fast LAMMPS
        let cfg = lv_cfg(&[430, 23, 1, 50, 8, 8, 1]);
        let wf = sim.expected(&cfg);
        let lam = lammps::profile(&[430, 23, 1, 50], &sim.machine);
        let lam_busy = lam.n_chunks as f64 * lam.t_chunk_s;
        assert!(
            wf.exec_time_s > lam_busy * 1.5,
            "workflow {} should be stalled well past isolated LAMMPS {}",
            wf.exec_time_s,
            lam_busy
        );
    }

    /// Noisy workspace runs must reproduce the reference path
    /// (build_pipeline + apply_noise + simulate) bit-for-bit, with one
    /// workspace reused across every workflow and case.
    #[test]
    fn run_with_matches_reference_bitwise() {
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("run_with == reference", 24, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let id = *rng.choose(&WorkflowId::ALL);
            let sim = WorkflowSim::new(id);
            let feasible = |c: &Config| sim.feasible(c);
            let mut srng = rng.derive(1);
            let cfg = sim.spec.sample_feasible(&mut srng, &feasible, 100_000);

            let mut rng_ref = rng.derive(2);
            let mut rng_ws = rng_ref.clone();
            let mut pipeline = sim.build_pipeline(&cfg);
            sim.apply_noise(&mut pipeline, &mut rng_ref);
            let reference = pipeline.simulate();
            let nodes = sim.nodes(&cfg);
            let exec_ref = reference.makespan_s() + sim.machine.startup_s(nodes);

            let m = sim.run_with(&cfg, &mut rng_ws, &mut ws);
            assert_prop(
                m.exec_time_s == exec_ref,
                format!("{id}: exec {} vs reference {exec_ref}", m.exec_time_s),
            )?;
            for u in 0..sim.structure().n_stages() {
                assert_prop(
                    ws.blocked_s()[u] == reference.blocked_s[u]
                        && ws.starved_s()[u] == reference.starved_s[u],
                    format!("{id}: stage {u} blocked/starved accounting diverged"),
                )?;
            }
            Ok(())
        });
    }

    /// Noise-free workspace runs (steady-state fast path eligible) stay
    /// within extrapolation tolerance of the reference recurrence.
    #[test]
    fn expected_with_matches_reference() {
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("expected_with == reference", 24, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let id = *rng.choose(&WorkflowId::ALL);
            let sim = WorkflowSim::new(id).with_noise(0.0);
            let feasible = |c: &Config| sim.feasible(c);
            let mut srng = rng.derive(1);
            let cfg = sim.spec.sample_feasible(&mut srng, &feasible, 100_000);
            let nodes = sim.nodes(&cfg);
            let exec_ref =
                sim.build_pipeline(&cfg).simulate().makespan_s() + sim.machine.startup_s(nodes);
            let m = sim.expected_with(&cfg, &mut ws);
            assert_close(m.exec_time_s, exec_ref, 1e-6, &format!("{id} expected"))
        });
    }
}
