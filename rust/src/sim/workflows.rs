//! Workflow assembly: LV / HS / GP wired onto the pipeline DES, plus
//! isolated component runs (the collector for component-model training)
//! and the feasibility rule (allocations ≤ 32 nodes, §7.1).

use super::apps::{grayscott, heat, lammps, pdfcalc, plots, stagewrite};
use super::machine::Machine;
use super::measurement::Measurement;
use super::pipeline::{Edge, Pipeline, Stage};
use crate::config::{Config, WorkflowId, WorkflowSpec};
use crate::util::rng::Pcg32;

/// Default buffer slots for ADIOS staging channels whose depth is not a
/// tunable parameter (LV and GP edges).
pub const DEFAULT_BUFFER_SLOTS: usize = 4;
/// Default run-to-run noise (lognormal sigma on per-chunk times).
pub const DEFAULT_NOISE_SIGMA: f64 = 0.03;
/// Canonical chunk counts for isolated consumer runs (the producer's
/// cadence is not part of a consumer's own configuration — this is
/// precisely the approximation that keeps component models low-fidelity).
pub const ISO_CHUNKS_VORO: usize = 8;
pub const ISO_CHUNKS_STAGEWRITE: usize = 8;
pub const ISO_CHUNKS_PDF: usize = 10;

/// The in-situ workflow simulator: the collector's backend.
#[derive(Clone, Debug)]
pub struct WorkflowSim {
    pub id: WorkflowId,
    pub spec: WorkflowSpec,
    pub machine: Machine,
    pub noise_sigma: f64,
}

impl WorkflowSim {
    pub fn new(id: WorkflowId) -> Self {
        WorkflowSim {
            id,
            spec: id.spec(),
            machine: Machine::default(),
            noise_sigma: DEFAULT_NOISE_SIGMA,
        }
    }

    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Total nodes a configuration allocates (sum over components; the
    /// plotters colocate with the analysis allocation).
    pub fn nodes(&self, cfg: &Config) -> u64 {
        match self.id {
            WorkflowId::Lv => {
                let l = self.spec.component_slice(cfg, 0);
                let v = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(l[0], l[1]) + self.machine.nodes_for(v[0], v[1])
            }
            WorkflowId::Hs => {
                let h = self.spec.component_slice(cfg, 0);
                let s = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(h[0] * h[1], h[2])
                    + self.machine.nodes_for(s[0], s[1])
            }
            WorkflowId::Gp => {
                let g = self.spec.component_slice(cfg, 0);
                let p = self.spec.component_slice(cfg, 1);
                self.machine.nodes_for(g[0], g[1]) + self.machine.nodes_for(p[0], p[1])
            }
        }
    }

    /// The paper's pools contain only runnable configurations:
    /// allocation must fit the 32-node budget.
    pub fn feasible(&self, cfg: &Config) -> bool {
        self.nodes(cfg) <= self.machine.max_nodes
    }

    /// Nodes an *isolated* run of configurable component `j` allocates.
    pub fn component_nodes(&self, j: usize, comp_cfg: &[i64]) -> u64 {
        match (self.id, j) {
            (WorkflowId::Hs, 0) => self.machine.nodes_for(comp_cfg[0] * comp_cfg[1], comp_cfg[2]),
            _ => self.machine.nodes_for(comp_cfg[0], comp_cfg[1]),
        }
    }

    /// Isolated component runs are subject to the same allocation cap
    /// as workflow runs (§7.1: allocations up to 32 nodes).
    pub fn component_feasible(&self, j: usize, comp_cfg: &[i64]) -> bool {
        self.component_nodes(j, comp_cfg) <= self.machine.max_nodes
    }

    /// Rejection-sample a feasible configuration for component `j`.
    pub fn sample_component_feasible(&self, j: usize, rng: &mut Pcg32) -> Vec<i64> {
        let cs = &self.spec.components[j];
        for _ in 0..100_000 {
            let cfg = cs.sample(rng);
            if self.component_feasible(j, &cfg) {
                return cfg;
            }
        }
        panic!("{}: no feasible config for component {j}", self.id);
    }

    /// Assemble the deterministic pipeline for `cfg`.
    pub fn build_pipeline(&self, cfg: &Config) -> Pipeline {
        let m = &self.machine;
        match self.id {
            WorkflowId::Lv => {
                let lp = lammps::profile(self.spec.component_slice(cfg, 0), m);
                let vp =
                    voro::profile(self.spec.component_slice(cfg, 1), lp.bytes_per_chunk, m);
                let k = lp.n_chunks;
                let xfer = transfer_time(m, lp.bytes_per_chunk, lp.nodes, vp.nodes, 1);
                Pipeline {
                    stages: vec![
                        stage("LAMMPS", lp.t_chunk_s, k, lp.nodes),
                        stage("Voro++", vp.t_chunk_s, k, vp.nodes),
                    ],
                    edges: vec![Edge {
                        from: 0,
                        to: 1,
                        t_transfer_s: xfer,
                        capacity: DEFAULT_BUFFER_SLOTS,
                    }],
                }
            }
            WorkflowId::Hs => {
                let hcfg = self.spec.component_slice(cfg, 0);
                let hp = heat::profile(hcfg, m);
                let sp = stagewrite::profile(
                    self.spec.component_slice(cfg, 1),
                    hp.bytes_per_chunk,
                    m,
                );
                let k = hp.n_chunks;
                let xfer = transfer_time(m, hp.bytes_per_chunk, hp.nodes, sp.nodes, 1)
                    / heat::buffer_efficiency(hcfg[4]);
                Pipeline {
                    stages: vec![
                        stage("HeatTransfer", hp.t_chunk_s, k, hp.nodes),
                        stage("StageWrite", sp.t_chunk_s, k, sp.nodes),
                    ],
                    edges: vec![Edge {
                        from: 0,
                        to: 1,
                        t_transfer_s: xfer,
                        capacity: heat::buffer_slots(hcfg[4]),
                    }],
                }
            }
            WorkflowId::Gp => {
                let gp = grayscott::profile(self.spec.component_slice(cfg, 0), m);
                let pp = pdfcalc::profile(
                    self.spec.component_slice(cfg, 1),
                    gp.bytes_per_chunk,
                    m,
                );
                let k = gp.n_chunks;
                let gplot = plots::gplot_profile(k, m);
                let pplot = plots::pplot_profile(k, m);
                // Gray-Scott fans out to PDF and G-Plot: its NIC is shared.
                let xfer_pdf =
                    transfer_time(m, gp.bytes_per_chunk, gp.nodes, pp.nodes, 2);
                let xfer_gplot = transfer_time(m, gp.bytes_per_chunk, gp.nodes, 1, 2);
                let xfer_pplot = transfer_time(m, pp.bytes_per_chunk_out, pp.nodes, 1, 1);
                Pipeline {
                    stages: vec![
                        stage("GrayScott", gp.t_chunk_s, k, gp.nodes),
                        stage("PDFcalc", pp.t_chunk_s, k, pp.nodes),
                        stage("G-Plot", gplot.t_chunk_s, k, gplot.nodes),
                        stage("P-Plot", pplot.t_chunk_s, k, pplot.nodes),
                    ],
                    edges: vec![
                        Edge {
                            from: 0,
                            to: 1,
                            t_transfer_s: xfer_pdf,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                        Edge {
                            from: 0,
                            to: 2,
                            t_transfer_s: xfer_gplot,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                        Edge {
                            from: 1,
                            to: 3,
                            t_transfer_s: xfer_pplot,
                            capacity: DEFAULT_BUFFER_SLOTS,
                        },
                    ],
                }
            }
        }
    }

    /// One noisy in-situ run: the collector's "run the workflow with
    /// configuration c and measure" (§2.1).
    pub fn run(&self, cfg: &Config, rng: &mut Pcg32) -> Measurement {
        let mut pipeline = self.build_pipeline(cfg);
        self.apply_noise(&mut pipeline, rng);
        let nodes = self.nodes(cfg);
        let exec = pipeline.simulate().makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// Noise-free run (ground-truth expectation; used by experiments to
    /// rank pool configurations reproducibly).
    pub fn expected(&self, cfg: &Config) -> Measurement {
        let pipeline = self.build_pipeline(cfg);
        let nodes = self.nodes(cfg);
        let exec = pipeline.simulate().makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// One noisy *isolated* run of configurable component `j` with its
    /// own parameter slice — the collector for component-model training
    /// (Alg. 1 lines 1-6). Sources run with a sink that never blocks;
    /// consumers run fed from staged input that never starves.
    pub fn run_component(&self, j: usize, comp_cfg: &[i64], rng: &mut Pcg32) -> Measurement {
        let m = &self.machine;
        let (t_chunk, k, nodes) = match (self.id, j) {
            (WorkflowId::Lv, 0) => {
                let p = lammps::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Lv, 1) => {
                let p = voro::profile(
                    comp_cfg,
                    lammps::N_ATOMS * lammps::BYTES_PER_ATOM,
                    m,
                );
                (p.t_chunk_s, ISO_CHUNKS_VORO, p.nodes)
            }
            (WorkflowId::Hs, 0) => {
                let p = heat::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Hs, 1) => {
                let p = stagewrite::profile(comp_cfg, heat::snapshot_bytes(), m);
                (p.t_chunk_s, ISO_CHUNKS_STAGEWRITE, p.nodes)
            }
            (WorkflowId::Gp, 0) => {
                let p = grayscott::profile(comp_cfg, m);
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            (WorkflowId::Gp, 1) => {
                let p = pdfcalc::profile(comp_cfg, grayscott::dump_bytes(), m);
                (p.t_chunk_s, ISO_CHUNKS_PDF, p.nodes)
            }
            (id, j) => panic!("{id}: component {j} is not configurable"),
        };
        let run_factor = rng.lognormal_factor(self.noise_sigma);
        let mut busy = 0.0;
        for _ in 0..k {
            busy += t_chunk * run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
        }
        let exec = busy + m.startup_s(nodes.max(1));
        Measurement::new(exec, nodes.max(1), m.cores_per_node)
    }

    fn apply_noise(&self, pipeline: &mut Pipeline, rng: &mut Pcg32) {
        if self.noise_sigma <= 0.0 {
            return;
        }
        for s in &mut pipeline.stages {
            let run_factor = rng.lognormal_factor(self.noise_sigma);
            for t in &mut s.t_chunk_s {
                *t *= run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
            }
        }
    }
}

use super::apps::voro;

fn stage(name: &str, t_chunk: f64, k: usize, nodes: u64) -> Stage {
    Stage {
        name: name.to_string(),
        t_chunk_s: vec![t_chunk; k],
        nodes,
    }
}

/// Per-chunk staging transfer time: aggregate NIC bandwidth of the
/// smaller side, split across the producer's concurrent out-streams.
fn transfer_time(m: &Machine, bytes: f64, nodes_from: u64, nodes_to: u64, out_degree: u64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    let agg = m.nic_bw_gbps * 1e9 * nodes_from.min(nodes_to).max(1) as f64
        / out_degree.max(1) as f64;
    bytes / agg + m.net_latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lv_cfg(v: &[i64]) -> Config {
        Config(v.to_vec())
    }

    #[test]
    fn nodes_and_feasibility() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let best_exec = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        assert_eq!(sim.nodes(&best_exec), 19 + 9);
        assert!(sim.feasible(&best_exec));
        let infeasible = lv_cfg(&[1085, 1, 1, 300, 2, 1, 1]);
        assert!(!sim.feasible(&infeasible));
    }

    #[test]
    fn lv_best_exec_beats_expert() {
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[430, 23, 1, 300, 88, 10, 4]));
        let expert = sim.expected(&lv_cfg(&[288, 18, 2, 400, 288, 18, 2]));
        assert!(
            best.exec_time_s < expert.exec_time_s,
            "best {} vs expert {}",
            best.exec_time_s,
            expert.exec_time_s
        );
        // magnitudes in the Table 2 ballpark (27.2 s / 36.8 s)
        assert!(best.exec_time_s > 15.0 && best.exec_time_s < 45.0);
        assert!(expert.exec_time_s > 25.0 && expert.exec_time_s < 60.0);
    }

    #[test]
    fn lv_comp_time_favors_packed_small_allocations() {
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[175, 35, 2, 400, 38, 29, 3]));
        let expert = sim.expected(&lv_cfg(&[18, 18, 2, 400, 18, 18, 2]));
        assert!(
            best.computer_time_core_h < expert.computer_time_core_h,
            "best {} vs expert {}",
            best.computer_time_core_h,
            expert.computer_time_core_h
        );
    }

    #[test]
    fn hs_expert_writer_storm_is_slow() {
        let sim = WorkflowSim::new(WorkflowId::Hs).with_noise(0.0);
        let best = sim.expected(&Config(vec![13, 17, 14, 4, 29, 19, 3]));
        let expert = sim.expected(&Config(vec![32, 17, 34, 4, 20, 560, 35]));
        assert!(best.exec_time_s < 12.0, "best {}", best.exec_time_s);
        assert!(
            expert.exec_time_s > 2.0 * best.exec_time_s,
            "expert {} best {}",
            expert.exec_time_s,
            best.exec_time_s
        );
    }

    #[test]
    fn gp_execution_floor_is_gplot() {
        let sim = WorkflowSim::new(WorkflowId::Gp).with_noise(0.0);
        // A large, fast Gray-Scott allocation: G-Plot dominates at ~97 s.
        let fast = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            fast.exec_time_s > 95.0 && fast.exec_time_s < 125.0,
            "fast {}",
            fast.exec_time_s
        );
        // A tiny Gray-Scott allocation is simulation-bound instead.
        let slow = sim.expected(&Config(vec![35, 35, 35, 35]));
        assert!(slow.exec_time_s > 200.0, "slow {}", slow.exec_time_s);
    }

    #[test]
    fn gp_expert_comp_time_is_competitive() {
        // Paper: experts do well on GP computer time (5.85 vs 6.95).
        let sim = WorkflowSim::new(WorkflowId::Gp).with_noise(0.0);
        let expert = sim.expected(&Config(vec![35, 35, 35, 35]));
        let big = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            expert.computer_time_core_h < big.computer_time_core_h,
            "expert {} vs big {}",
            expert.computer_time_core_h,
            big.computer_time_core_h
        );
    }

    #[test]
    fn noise_perturbs_but_preserves_ranking() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let cfg = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        let mut rng = Pcg32::new(11, 0);
        let a = sim.run(&cfg, &mut rng);
        let b = sim.run(&cfg, &mut rng);
        assert_ne!(a.exec_time_s, b.exec_time_s, "noise should differ");
        let exp = sim.expected(&cfg).exec_time_s;
        for m in [a, b] {
            assert!((m.exec_time_s / exp - 1.0).abs() < 0.25);
        }
    }

    #[test]
    fn isolated_component_runs() {
        let sim = WorkflowSim::new(WorkflowId::Lv);
        let mut rng = Pcg32::new(3, 0);
        let lam = sim.run_component(0, &[430, 23, 1, 300], &mut rng);
        let vor = sim.run_component(1, &[88, 10, 4], &mut rng);
        assert!(lam.exec_time_s > 10.0 && lam.exec_time_s < 60.0);
        assert!(vor.exec_time_s > 5.0 && vor.exec_time_s < 60.0);
        assert!(lam.nodes >= 1 && vor.nodes >= 1);
    }

    #[test]
    #[should_panic(expected = "not configurable")]
    fn isolated_plot_panics() {
        let sim = WorkflowSim::new(WorkflowId::Gp);
        let mut rng = Pcg32::new(3, 0);
        sim.run_component(2, &[], &mut rng);
    }

    #[test]
    fn coupling_differs_from_isolated_max() {
        // The in-situ exec time exceeds the max of isolated busy times
        // when rates mismatch (backpressure) — the paper's core premise.
        let sim = WorkflowSim::new(WorkflowId::Lv).with_noise(0.0);
        // slow Voro (few procs) against fast LAMMPS
        let cfg = lv_cfg(&[430, 23, 1, 50, 8, 8, 1]);
        let wf = sim.expected(&cfg);
        let lam = lammps::profile(&[430, 23, 1, 50], &sim.machine);
        let lam_busy = lam.n_chunks as f64 * lam.t_chunk_s;
        assert!(
            wf.exec_time_s > lam_busy * 1.5,
            "workflow {} should be stalled well past isolated LAMMPS {}",
            wf.exec_time_s,
            lam_busy
        );
    }
}
