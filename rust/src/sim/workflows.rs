//! Generic workflow simulation over registry tables: any registered
//! [`WorkflowDef`] is wired onto the pipeline DES by a single
//! topology-driven loop — no per-workflow branches anywhere on the
//! simulation path.  Also hosts isolated component runs (the collector
//! for component-model training) and the feasibility rule
//! (allocations ≤ 32 nodes, §7.1).
//!
//! The measurement hot path is allocation-free: each [`WorkflowSim`]
//! precomputes its immutable [`PipelineStructure`] once, per-stage
//! profile scratch lives on the stack (bounded by
//! [`MAX_STAGES`](super::registry::MAX_STAGES)), and
//! [`fill_pipeline`](WorkflowSim::fill_pipeline) writes a run's
//! parameters into a caller-owned [`SimWorkspace`].  Collectors hold one
//! workspace and thread it through [`run_with`](WorkflowSim::run_with) /
//! [`expected_with`](WorkflowSim::expected_with); the argument-free
//! [`run`](WorkflowSim::run) / [`expected`](WorkflowSim::expected)
//! wrappers build a throwaway workspace for one-off calls.
//!
//! [`build_pipeline`](WorkflowSim::build_pipeline) derives from the
//! *same* table walk as `fill_pipeline` (they share
//! [`profiles_for`](WorkflowSim::profiles_for)'s output), and remains
//! the allocation-heavy reference path for differential tests and the
//! benches' before/after baseline.

use std::sync::Arc;

use super::machine::Machine;
use super::measurement::Measurement;
use super::pipeline::{Edge, Pipeline, PipelineStructure, SimWorkspace, Stage};
use super::registry::{IsoRun, StageProfile, Upstream, WorkflowDef, WorkflowId, MAX_STAGES};
use crate::config::{Config, WorkflowSpec};
use crate::util::rng::Pcg32;

pub use super::registry::DEFAULT_BUFFER_SLOTS;

/// Default run-to-run noise (lognormal sigma on per-chunk times).
pub const DEFAULT_NOISE_SIGMA: f64 = 0.03;

/// Rejection budget for feasibility samplers.
pub const FEASIBLE_SAMPLE_TRIES: usize = 100_000;

pub use crate::config::InfeasibleSpace;

/// The in-situ workflow simulator: the collector's backend, generic
/// over any registered workflow table.
#[derive(Clone, Debug)]
pub struct WorkflowSim {
    pub id: WorkflowId,
    pub spec: WorkflowSpec,
    pub machine: Machine,
    pub noise_sigma: f64,
    /// The declarative table everything below derives from.
    def: Arc<WorkflowDef>,
    /// Immutable topology shared by every run of this workflow.
    structure: PipelineStructure,
}

impl WorkflowSim {
    /// Build the simulator for a registered workflow.
    pub fn new(id: WorkflowId) -> Self {
        WorkflowSim::from_def(id.def())
    }

    /// Build the simulator directly from a definition table (useful for
    /// tables not (yet) in the global registry).  Panics on invalid
    /// tables — `profiles_for`'s forward walk relies on every invariant
    /// [`WorkflowDef::validate`] checks, so an unvalidated table must
    /// not reach the simulation path.
    pub fn from_def(def: Arc<WorkflowDef>) -> Self {
        def.validate()
            .unwrap_or_else(|e| panic!("invalid workflow table: {e}"));
        let structure = PipelineStructure::new(
            def.components.iter().map(|c| c.stage_name).collect(),
            def.edges.iter().map(|e| (e.from, e.to)).collect(),
        );
        let spec = def.spec();
        WorkflowSim {
            id: def.id(),
            spec,
            machine: Machine::default(),
            noise_sigma: DEFAULT_NOISE_SIGMA,
            def,
            structure,
        }
    }

    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// The workflow's definition table.
    pub fn def(&self) -> &Arc<WorkflowDef> {
        &self.def
    }

    /// The workflow's immutable pipeline topology.
    pub fn structure(&self) -> &PipelineStructure {
        &self.structure
    }

    /// Total nodes a configuration allocates: the sum of every
    /// component's node-allocation rule (colocated components
    /// contribute 0).
    pub fn nodes(&self, cfg: &Config) -> u64 {
        let mut total = 0u64;
        for (j, c) in self.def.components.iter().enumerate() {
            total += (c.nodes)(self.spec.component_slice(cfg, j), &self.machine);
        }
        total
    }

    /// The paper's pools contain only runnable configurations:
    /// allocation must fit the 32-node budget.
    pub fn feasible(&self, cfg: &Config) -> bool {
        self.nodes(cfg) <= self.machine.max_nodes
    }

    /// Nodes an *isolated* run of configurable component `j` allocates.
    pub fn component_nodes(&self, j: usize, comp_cfg: &[i64]) -> u64 {
        (self.def.components[j].nodes)(comp_cfg, &self.machine)
    }

    /// Isolated component runs are subject to the same allocation cap
    /// as workflow runs (§7.1: allocations up to 32 nodes).
    pub fn component_feasible(&self, j: usize, comp_cfg: &[i64]) -> bool {
        self.component_nodes(j, comp_cfg) <= self.machine.max_nodes
    }

    /// Rejection-sample a feasible configuration for component `j`.
    pub fn sample_component_feasible(
        &self,
        j: usize,
        rng: &mut Pcg32,
    ) -> Result<Vec<i64>, InfeasibleSpace> {
        let cs = &self.spec.components[j];
        for _ in 0..FEASIBLE_SAMPLE_TRIES {
            let cfg = cs.sample(rng);
            if self.component_feasible(j, &cfg) {
                return Ok(cfg);
            }
        }
        Err(InfeasibleSpace {
            workflow: self.id.name().to_string(),
            scope: format!("component {j} ({})", cs.name),
            tries: FEASIBLE_SAMPLE_TRIES,
        })
    }

    /// Evaluate every component's profile for `cfg`, walking the table
    /// in (topological) component order: each consumer sees the summed
    /// `bytes_out` of its in-edge producers and the source's chunk
    /// count.  Returns the per-stage profiles and the run's chunk
    /// count.  Stack-only — the hot path allocates nothing here.
    fn profiles_for(&self, cfg: &Config) -> ([StageProfile; MAX_STAGES], usize) {
        let mut profiles = [StageProfile::default(); MAX_STAGES];
        let n = self.def.components.len();
        debug_assert!(n <= MAX_STAGES);
        let mut k = 0usize;
        for (u, comp) in self.def.components.iter().enumerate() {
            let mut bytes_in = 0.0f64;
            for e in &self.def.edges {
                if e.to == u {
                    bytes_in += profiles[e.from].bytes_out;
                }
            }
            let p = (comp.profile)(
                self.spec.component_slice(cfg, u),
                Upstream {
                    bytes: bytes_in,
                    n_chunks: k,
                },
                &self.machine,
            );
            if u == 0 {
                k = p.n_chunks;
                // Hard assert (not debug): campaigns run in release, and
                // a 0-chunk source would otherwise silently poison pool
                // ground truth with inf/NaN downstream chunk times.
                assert!(k >= 1, "{}: source profile must define n_chunks >= 1", self.id);
            }
            profiles[u] = p;
        }
        (profiles, k)
    }

    /// One edge's pipeline parameters (transfer time, buffer capacity):
    /// staging transfer time over the producer's NIC — split across its
    /// concurrent out-streams, with out-degree read straight off the
    /// table's DAG — divided by the edge's buffer efficiency, plus the
    /// buffer depth, both from the table's per-edge rule.
    fn edge_params(&self, cfg: &Config, profiles: &[StageProfile], ei: usize) -> (f64, usize) {
        let e = &self.def.edges[ei];
        let out_degree = self.def.edges.iter().filter(|o| o.from == e.from).count() as u64;
        let rule = (e.buffer)(self.spec.component_slice(cfg, e.from));
        let xfer = transfer_time(
            &self.machine,
            profiles[e.from].bytes_out,
            profiles[e.from].nodes,
            profiles[e.to].nodes,
            out_degree,
        ) / rule.xfer_divisor;
        (xfer, rule.capacity)
    }

    /// Write the deterministic pipeline parameters for `cfg` into `ws`
    /// (stage chunk times, edge transfer times, buffer capacities) —
    /// zero allocations once the workspace is warmed.
    pub fn fill_pipeline(&self, cfg: &Config, ws: &mut SimWorkspace) {
        let (profiles, k) = self.profiles_for(cfg);
        ws.begin(&self.structure, k);
        for u in 0..self.def.components.len() {
            ws.set_stage_time(u, profiles[u].t_chunk_s);
        }
        for ei in 0..self.def.edges.len() {
            let (xfer, capacity) = self.edge_params(cfg, &profiles, ei);
            ws.set_edge(ei, xfer, capacity);
        }
    }

    /// Assemble the deterministic pipeline for `cfg` — the reference
    /// (allocation-heavy) counterpart of
    /// [`fill_pipeline`](Self::fill_pipeline), derived from the *same*
    /// table walk; kept for differential tests and the benches'
    /// before/after baseline.
    pub fn build_pipeline(&self, cfg: &Config) -> Pipeline {
        let (profiles, k) = self.profiles_for(cfg);
        Pipeline {
            stages: self
                .def
                .components
                .iter()
                .enumerate()
                .map(|(u, c)| stage(c.stage_name, profiles[u].t_chunk_s, k, profiles[u].nodes))
                .collect(),
            edges: (0..self.def.edges.len())
                .map(|ei| {
                    let (xfer, capacity) = self.edge_params(cfg, &profiles, ei);
                    Edge {
                        from: self.def.edges[ei].from,
                        to: self.def.edges[ei].to,
                        t_transfer_s: xfer,
                        capacity,
                    }
                })
                .collect(),
        }
    }

    /// One noisy in-situ run through a caller-owned workspace — the
    /// collector's "run the workflow with configuration c and measure"
    /// (§2.1).  Allocation-free once `ws` is warmed.
    pub fn run_with(&self, cfg: &Config, rng: &mut Pcg32, ws: &mut SimWorkspace) -> Measurement {
        self.fill_pipeline(cfg, ws);
        self.apply_noise_ws(ws, rng);
        let nodes = self.nodes(cfg);
        self.structure.simulate(ws);
        let exec = ws.makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// Noise-free run through a caller-owned workspace (ground-truth
    /// expectation; constant chunk times take the steady-state fast
    /// path).
    pub fn expected_with(&self, cfg: &Config, ws: &mut SimWorkspace) -> Measurement {
        self.fill_pipeline(cfg, ws);
        let nodes = self.nodes(cfg);
        self.structure.simulate(ws);
        let exec = ws.makespan_s() + self.machine.startup_s(nodes);
        Measurement::new(exec, nodes, self.machine.cores_per_node)
    }

    /// One noisy in-situ run (convenience wrapper over a per-thread
    /// scratch workspace; collectors hold their own and use
    /// [`run_with`](Self::run_with)).
    pub fn run(&self, cfg: &Config, rng: &mut Pcg32) -> Measurement {
        SCRATCH.with(|ws| self.run_with(cfg, rng, &mut ws.borrow_mut()))
    }

    /// Noise-free run (ground-truth expectation; used by experiments to
    /// rank pool configurations reproducibly).
    pub fn expected(&self, cfg: &Config) -> Measurement {
        SCRATCH.with(|ws| self.expected_with(cfg, &mut ws.borrow_mut()))
    }

    /// One noisy *isolated* run of configurable component `j` with its
    /// own parameter slice — the collector for component-model training
    /// (Alg. 1 lines 1-6).  The table's [`IsoRun`] entry says how:
    /// sources run with a sink that never blocks; consumers run fed
    /// from staged input that never starves.
    pub fn run_component(&self, j: usize, comp_cfg: &[i64], rng: &mut Pcg32) -> Measurement {
        let comp = &self.def.components[j];
        assert!(
            comp.spec.is_configurable(),
            "{}: component {j} is not configurable",
            self.id
        );
        let m = &self.machine;
        let (t_chunk, k, nodes) = match comp.iso {
            IsoRun::Source => {
                let p = (comp.profile)(
                    comp_cfg,
                    Upstream {
                        bytes: 0.0,
                        n_chunks: 0,
                    },
                    m,
                );
                (p.t_chunk_s, p.n_chunks, p.nodes)
            }
            IsoRun::Consumer { bytes, chunks } => {
                let p = (comp.profile)(
                    comp_cfg,
                    Upstream {
                        bytes,
                        n_chunks: chunks,
                    },
                    m,
                );
                (p.t_chunk_s, chunks, p.nodes)
            }
        };
        let run_factor = rng.lognormal_factor(self.noise_sigma);
        let mut busy = 0.0;
        for _ in 0..k {
            busy += t_chunk * run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
        }
        let exec = busy + m.startup_s(nodes.max(1));
        Measurement::new(exec, nodes.max(1), m.cores_per_node)
    }

    /// Per-chunk multiplicative noise on a filled workspace: one run
    /// factor per stage, one chunk factor per chunk.  Draw order and
    /// arithmetic match [`apply_noise`](Self::apply_noise) exactly, so
    /// workspace runs reproduce the reference path bit-for-bit.
    fn apply_noise_ws(&self, ws: &mut SimWorkspace, rng: &mut Pcg32) {
        if self.noise_sigma <= 0.0 {
            return;
        }
        ws.make_per_chunk();
        let kc = ws.n_chunks();
        for u in 0..self.structure.n_stages() {
            let run_factor = rng.lognormal_factor(self.noise_sigma);
            for k in 0..kc {
                ws.scale_chunk(u, k, run_factor * rng.lognormal_factor(self.noise_sigma * 0.5));
            }
        }
    }

    /// Reference-path noise application (differential tests pin
    /// [`run_with`](Self::run_with) against `build_pipeline` +
    /// `apply_noise` + `simulate` with the same RNG).
    pub fn apply_noise(&self, pipeline: &mut Pipeline, rng: &mut Pcg32) {
        if self.noise_sigma <= 0.0 {
            return;
        }
        for s in &mut pipeline.stages {
            let run_factor = rng.lognormal_factor(self.noise_sigma);
            for t in &mut s.t_chunk_s {
                *t *= run_factor * rng.lognormal_factor(self.noise_sigma * 0.5);
            }
        }
    }
}

std::thread_local! {
    /// Per-thread scratch workspace backing the argument-free
    /// [`WorkflowSim::run`] / [`WorkflowSim::expected`] wrappers, so
    /// even one-off calls stop allocating once the thread is warm.
    static SCRATCH: std::cell::RefCell<SimWorkspace> =
        std::cell::RefCell::new(SimWorkspace::new());
}

fn stage(name: &str, t_chunk: f64, k: usize, nodes: u64) -> Stage {
    Stage {
        name: name.to_string(),
        t_chunk_s: vec![t_chunk; k],
        nodes,
    }
}

/// Per-chunk staging transfer time: aggregate NIC bandwidth of the
/// smaller side, split across the producer's concurrent out-streams.
fn transfer_time(m: &Machine, bytes: f64, nodes_from: u64, nodes_to: u64, out_degree: u64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    let agg = m.nic_bw_gbps * 1e9 * nodes_from.min(nodes_to).max(1) as f64
        / out_degree.max(1) as f64;
    bytes / agg + m.net_latency_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::WorkflowRegistry;
    use crate::util::prop::{assert_close, assert_prop, check};

    fn lv_cfg(v: &[i64]) -> Config {
        Config(v.to_vec())
    }

    #[test]
    fn nodes_and_feasibility() {
        let sim = WorkflowSim::new(WorkflowId::LV);
        let best_exec = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        assert_eq!(sim.nodes(&best_exec), 19 + 9);
        assert!(sim.feasible(&best_exec));
        let infeasible = lv_cfg(&[1085, 1, 1, 300, 2, 1, 1]);
        assert!(!sim.feasible(&infeasible));
    }

    #[test]
    fn lv_best_exec_beats_expert() {
        let sim = WorkflowSim::new(WorkflowId::LV).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[430, 23, 1, 300, 88, 10, 4]));
        let expert = sim.expected(&lv_cfg(&[288, 18, 2, 400, 288, 18, 2]));
        assert!(
            best.exec_time_s < expert.exec_time_s,
            "best {} vs expert {}",
            best.exec_time_s,
            expert.exec_time_s
        );
        // magnitudes in the Table 2 ballpark (27.2 s / 36.8 s)
        assert!(best.exec_time_s > 15.0 && best.exec_time_s < 45.0);
        assert!(expert.exec_time_s > 25.0 && expert.exec_time_s < 60.0);
    }

    #[test]
    fn lv_comp_time_favors_packed_small_allocations() {
        let sim = WorkflowSim::new(WorkflowId::LV).with_noise(0.0);
        let best = sim.expected(&lv_cfg(&[175, 35, 2, 400, 38, 29, 3]));
        let expert = sim.expected(&lv_cfg(&[18, 18, 2, 400, 18, 18, 2]));
        assert!(
            best.computer_time_core_h < expert.computer_time_core_h,
            "best {} vs expert {}",
            best.computer_time_core_h,
            expert.computer_time_core_h
        );
    }

    #[test]
    fn hs_expert_writer_storm_is_slow() {
        let sim = WorkflowSim::new(WorkflowId::HS).with_noise(0.0);
        let best = sim.expected(&Config(vec![13, 17, 14, 4, 29, 19, 3]));
        let expert = sim.expected(&Config(vec![32, 17, 34, 4, 20, 560, 35]));
        assert!(best.exec_time_s < 12.0, "best {}", best.exec_time_s);
        assert!(
            expert.exec_time_s > 2.0 * best.exec_time_s,
            "expert {} best {}",
            expert.exec_time_s,
            best.exec_time_s
        );
    }

    #[test]
    fn gp_execution_floor_is_gplot() {
        let sim = WorkflowSim::new(WorkflowId::GP).with_noise(0.0);
        // A large, fast Gray-Scott allocation: G-Plot dominates at ~97 s.
        let fast = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            fast.exec_time_s > 95.0 && fast.exec_time_s < 125.0,
            "fast {}",
            fast.exec_time_s
        );
        // A tiny Gray-Scott allocation is simulation-bound instead.
        let slow = sim.expected(&Config(vec![35, 35, 35, 35]));
        assert!(slow.exec_time_s > 200.0, "slow {}", slow.exec_time_s);
    }

    #[test]
    fn gp_expert_comp_time_is_competitive() {
        // Paper: experts do well on GP computer time (5.85 vs 6.95).
        let sim = WorkflowSim::new(WorkflowId::GP).with_noise(0.0);
        let expert = sim.expected(&Config(vec![35, 35, 35, 35]));
        let big = sim.expected(&Config(vec![525, 35, 128, 32]));
        assert!(
            expert.computer_time_core_h < big.computer_time_core_h,
            "expert {} vs big {}",
            expert.computer_time_core_h,
            big.computer_time_core_h
        );
    }

    #[test]
    fn noise_perturbs_but_preserves_ranking() {
        let sim = WorkflowSim::new(WorkflowId::LV);
        let cfg = lv_cfg(&[430, 23, 1, 300, 88, 10, 4]);
        let mut rng = Pcg32::new(11, 0);
        let a = sim.run(&cfg, &mut rng);
        let b = sim.run(&cfg, &mut rng);
        assert_ne!(a.exec_time_s, b.exec_time_s, "noise should differ");
        let exp = sim.expected(&cfg).exec_time_s;
        for m in [a, b] {
            assert!((m.exec_time_s / exp - 1.0).abs() < 0.25);
        }
    }

    #[test]
    fn isolated_component_runs() {
        let sim = WorkflowSim::new(WorkflowId::LV);
        let mut rng = Pcg32::new(3, 0);
        let lam = sim.run_component(0, &[430, 23, 1, 300], &mut rng);
        let vor = sim.run_component(1, &[88, 10, 4], &mut rng);
        assert!(lam.exec_time_s > 10.0 && lam.exec_time_s < 60.0);
        assert!(vor.exec_time_s > 5.0 && vor.exec_time_s < 60.0);
        assert!(lam.nodes >= 1 && vor.nodes >= 1);
    }

    #[test]
    #[should_panic(expected = "not configurable")]
    fn isolated_plot_panics() {
        let sim = WorkflowSim::new(WorkflowId::GP);
        let mut rng = Pcg32::new(3, 0);
        sim.run_component(2, &[], &mut rng);
    }

    #[test]
    fn infeasible_component_space_returns_error() {
        // shrink the machine so no allocation fits: the sampler must
        // surface an error, not panic
        let mut sim = WorkflowSim::new(WorkflowId::LV);
        sim.machine.max_nodes = 0;
        let mut rng = Pcg32::new(5, 5);
        let err = sim.sample_component_feasible(0, &mut rng).unwrap_err();
        assert!(err.to_string().contains("no feasible configuration"), "{err}");
        assert_eq!(err.workflow, "LV");
    }

    #[test]
    fn coupling_differs_from_isolated_max() {
        // The in-situ exec time exceeds the max of isolated busy times
        // when rates mismatch (backpressure) — the paper's core premise.
        let sim = WorkflowSim::new(WorkflowId::LV).with_noise(0.0);
        // slow Voro (few procs) against fast LAMMPS
        let cfg = lv_cfg(&[430, 23, 1, 50, 8, 8, 1]);
        let wf = sim.expected(&cfg);
        let lam = super::super::apps::lammps::profile(&[430, 23, 1, 50], &sim.machine);
        let lam_busy = lam.n_chunks as f64 * lam.t_chunk_s;
        assert!(
            wf.exec_time_s > lam_busy * 1.5,
            "workflow {} should be stalled well past isolated LAMMPS {}",
            wf.exec_time_s,
            lam_busy
        );
    }

    /// Noisy workspace runs must reproduce the reference path
    /// (build_pipeline + apply_noise + simulate) bit-for-bit, with one
    /// workspace reused across *every registered workflow* (CH5 / DM4
    /// included) and case.
    #[test]
    fn run_with_matches_reference_bitwise() {
        let ids = WorkflowRegistry::global().ids();
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("run_with == reference", 40, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let id = *rng.choose(&ids);
            let sim = WorkflowSim::new(id);
            let feasible = |c: &Config| sim.feasible(c);
            let mut srng = rng.derive(1);
            let cfg = sim.spec.sample_feasible(&mut srng, &feasible, 100_000);

            let mut rng_ref = rng.derive(2);
            let mut rng_ws = rng_ref.clone();
            let mut pipeline = sim.build_pipeline(&cfg);
            sim.apply_noise(&mut pipeline, &mut rng_ref);
            let reference = pipeline.simulate();
            let nodes = sim.nodes(&cfg);
            let exec_ref = reference.makespan_s() + sim.machine.startup_s(nodes);

            let m = sim.run_with(&cfg, &mut rng_ws, &mut ws);
            assert_prop(
                m.exec_time_s == exec_ref,
                format!("{id}: exec {} vs reference {exec_ref}", m.exec_time_s),
            )?;
            for u in 0..sim.structure().n_stages() {
                assert_prop(
                    ws.blocked_s()[u] == reference.blocked_s[u]
                        && ws.starved_s()[u] == reference.starved_s[u],
                    format!("{id}: stage {u} blocked/starved accounting diverged"),
                )?;
            }
            Ok(())
        });
    }

    /// Noise-free workspace runs (steady-state fast path eligible) stay
    /// within extrapolation tolerance of the reference recurrence, for
    /// every registered workflow.
    #[test]
    fn expected_with_matches_reference() {
        let ids = WorkflowRegistry::global().ids();
        let shared_ws = std::cell::RefCell::new(SimWorkspace::new());
        check("expected_with == reference", 40, |rng| {
            let mut ws = shared_ws.borrow_mut();
            let id = *rng.choose(&ids);
            let sim = WorkflowSim::new(id).with_noise(0.0);
            let feasible = |c: &Config| sim.feasible(c);
            let mut srng = rng.derive(1);
            let cfg = sim.spec.sample_feasible(&mut srng, &feasible, 100_000);
            let nodes = sim.nodes(&cfg);
            let exec_ref =
                sim.build_pipeline(&cfg).simulate().makespan_s() + sim.machine.startup_s(nodes);
            let m = sim.expected_with(&cfg, &mut ws);
            assert_close(m.exec_time_s, exec_ref, 1e-6, &format!("{id} expected"))
        });
    }
}
