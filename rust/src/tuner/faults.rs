//! Deterministic fault injection for the measurement path.
//!
//! [`FaultInjector`] wraps any [`Evaluator`] and makes some of its
//! answers fail, time out, straggle or arrive silently corrupted,
//! according to a [`FaultPlan`].  The injector follows the same
//! derivation discipline as `Collector::measure_config_batch`'s
//! per-slot noise streams: every fault decision is drawn from a fresh
//! [`Pcg32`] keyed by `(injector seed, request fingerprint, attempt
//! number)`, never from a shared stream consumed across the batch.
//! Consequences:
//!
//! * the fault schedule is a pure function of the request sequence —
//!   the same session asking the same requests hits the same faults,
//!   bit for bit, regardless of thread count or batch packing;
//! * a *retry* of a request is a fresh attempt (the per-fingerprint
//!   occurrence counter advances), so transient failures are
//!   survivable rather than sticky;
//! * composing with [`TraceRecorder`](super::trace::TraceRecorder)
//!   records post-injection outcomes, so a faulted session replays
//!   bit-exactly without re-running the injector.
//!
//! Requests the injector fails outright (crash/timeout) are *not*
//! forwarded to the wrapped evaluator: the run never happened, so the
//! simulator's noise stream is not consumed for that slot.  Surviving
//! requests are forwarded as a sub-batch in the original mode and
//! order — safe under both batch modes because fan-out slots draw from
//! per-slot child streams (see the partial-batch notes in
//! [`super::session`]).

use std::collections::HashMap;

use crate::util::rng::{fnv1a, Pcg32};

use super::session::{
    BatchMode, Evaluator, EvaluatorState, FailureKind, MeasurementBatch, MeasurementRequest,
    MeasurementResult,
};

/// What to inject and how often.  All probabilities are independent
/// per measurement attempt, in `[0, 1)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Probability an attempt fails outright (crash or transport
    /// loss, split evenly).
    pub p_fail: f64,
    /// Probability an attempt exceeds its deadline.
    pub p_timeout: f64,
    /// Probability a delivered reading straggles: its observed cost is
    /// multiplied by `straggler_mult`.
    pub p_straggle: f64,
    pub straggler_mult: f64,
    /// Probability a delivered reading is silently corrupted: scaled
    /// by `corrupt_mult` or `1/corrupt_mult` (one more draw decides
    /// the direction), so corruption can fake both a terrible and a
    /// too-good-to-be-true configuration.
    pub p_corrupt: f64,
    pub corrupt_mult: f64,
    /// Isolated runs of this component index always crash (targeted
    /// per-component failure), if set.
    pub target_component: Option<usize>,
}

impl FaultPlan {
    /// No faults at all; wrapping an evaluator with this plan is an
    /// exact identity (pinned by a test below).
    pub fn none() -> FaultPlan {
        FaultPlan {
            p_fail: 0.0,
            p_timeout: 0.0,
            p_straggle: 0.0,
            straggler_mult: 1.0,
            p_corrupt: 0.0,
            corrupt_mult: 1.0,
            target_component: None,
        }
    }

    /// The CLI's `--faults p_fail,p_timeout,seed` plan: transient
    /// failures and timeouts, plus a light corruption/straggler tail
    /// scaled off the failure rate so the outlier gate has something
    /// real to catch.
    pub fn transient(p_fail: f64, p_timeout: f64) -> FaultPlan {
        FaultPlan {
            p_fail,
            p_timeout,
            p_straggle: p_fail / 4.0,
            straggler_mult: 3.0,
            p_corrupt: p_fail / 8.0,
            corrupt_mult: 50.0,
            target_component: None,
        }
    }

    pub fn is_none(&self) -> bool {
        *self == FaultPlan::none()
    }
}

/// A fault plan plus the seed of its schedule stream — everything
/// needed to reproduce a fault schedule exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub plan: FaultPlan,
    pub seed: u64,
}

impl FaultSpec {
    /// Per-repetition schedule seed: campaigns give every repetition
    /// its own independent fault stream, derived deterministically so
    /// rep-level parallelism cannot reorder schedules.
    pub fn seed_for_rep(&self, rep: usize) -> u64 {
        Pcg32::new(self.seed, rep as u64).next_u64()
    }
}

/// One decided fate for a request attempt.
enum Fate {
    /// Run it; scale the delivered reading by `mult` (1.0 = clean).
    Deliver { mult: f64 },
    Fail(FailureKind),
    TimeOut,
}

/// An [`Evaluator`] wrapper that injects deterministic faults (module
/// docs).  Compose as `TraceRecorder(FaultInjector(Collector))` to
/// record a faulted session.
pub struct FaultInjector<'e> {
    inner: &'e mut dyn Evaluator,
    plan: FaultPlan,
    seed: u64,
    /// Attempt count per request fingerprint: retries of an identical
    /// request draw a fresh fate.
    attempts: HashMap<u64, u64>,
}

impl<'e> FaultInjector<'e> {
    pub fn new(inner: &'e mut dyn Evaluator, plan: FaultPlan, seed: u64) -> FaultInjector<'e> {
        FaultInjector {
            inner,
            plan,
            seed,
            attempts: HashMap::new(),
        }
    }

    fn decide(&mut self, req: &MeasurementRequest) -> Fate {
        if let (Some(target), MeasurementRequest::Component { comp, .. }) =
            (self.plan.target_component, req)
        {
            if *comp == target {
                return Fate::Fail(FailureKind::Crash);
            }
        }
        let key = request_fingerprint(req);
        let attempt = self.attempts.entry(key).or_insert(0);
        let mut rng = Pcg32::new(self.seed ^ key, *attempt);
        *attempt += 1;
        // fixed draw order keeps schedules stable as plans evolve
        let u_fail = rng.f64();
        let u_timeout = rng.f64();
        let u_straggle = rng.f64();
        let u_corrupt = rng.f64();
        let u_aux = rng.f64();
        if u_fail < self.plan.p_fail {
            return Fate::Fail(if u_aux < 0.5 {
                FailureKind::Crash
            } else {
                FailureKind::Transport
            });
        }
        if u_timeout < self.plan.p_timeout {
            return Fate::TimeOut;
        }
        let mut mult = 1.0;
        if u_straggle < self.plan.p_straggle {
            mult *= self.plan.straggler_mult;
        }
        if u_corrupt < self.plan.p_corrupt {
            mult *= if u_aux < 0.5 {
                self.plan.corrupt_mult
            } else {
                1.0 / self.plan.corrupt_mult
            };
        }
        Fate::Deliver { mult }
    }

    /// Advance the attempt counter for `req` exactly as one
    /// [`decide`](Self::decide) call would, without drawing a fate —
    /// the crash-recovery fast-forward behind
    /// [`Evaluator::note_replayed`].  Mirrors `decide` precisely:
    /// targeted-component crashes return before touching the counter,
    /// so they are skipped here too.
    fn note_attempt(&mut self, req: &MeasurementRequest) {
        if let (Some(target), MeasurementRequest::Component { comp, .. }) =
            (self.plan.target_component, req)
        {
            if *comp == target {
                return;
            }
        }
        *self.attempts.entry(request_fingerprint(req)).or_insert(0) += 1;
    }
}

impl Evaluator for FaultInjector<'_> {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        let fates: Vec<Fate> = batch.requests.iter().map(|r| self.decide(r)).collect();
        let survivors: Vec<MeasurementRequest> = batch
            .requests
            .iter()
            .zip(&fates)
            .filter(|(_, f)| matches!(f, Fate::Deliver { .. }))
            .map(|(r, _)| r.clone())
            .collect();
        let mut delivered = if survivors.is_empty() {
            Vec::new()
        } else {
            self.inner.evaluate(&MeasurementBatch {
                mode: batch.mode,
                requests: survivors,
            })
        }
        .into_iter();
        fates
            .into_iter()
            .map(|fate| match fate {
                Fate::Deliver { mult } => {
                    let r = delivered
                        .next()
                        .expect("inner evaluator answered every surviving request");
                    match r.value() {
                        Some(v) if mult != 1.0 => MeasurementResult::ok(v * mult),
                        _ => r,
                    }
                }
                Fate::Fail(kind) => MeasurementResult::failed(kind),
                Fate::TimeOut => MeasurementResult::timed_out(),
            })
            .collect()
    }

    fn checkpoint_state(&mut self) -> Option<EvaluatorState> {
        self.inner.checkpoint_state()
    }

    fn restore_state(&mut self, state: &EvaluatorState) -> bool {
        self.inner.restore_state(state)
    }

    fn note_replayed(&mut self, req: &MeasurementRequest) {
        self.note_attempt(req);
        self.inner.note_replayed(req);
    }
}

/// Stable fingerprint of a request (what it *is*, not where it sits
/// in a batch): workflow requests hash their pool index, component
/// requests their component index and exact configuration.
fn request_fingerprint(req: &MeasurementRequest) -> u64 {
    let mut bytes = Vec::with_capacity(40);
    match req {
        MeasurementRequest::Workflow { pool_idx, .. } => {
            bytes.push(0u8);
            bytes.extend_from_slice(&(*pool_idx as u64).to_le_bytes());
        }
        MeasurementRequest::Component { comp, config } => {
            bytes.push(1u8);
            bytes.extend_from_slice(&(*comp as u64).to_le_bytes());
            for v in config {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, WorkflowId};
    use crate::sim::Objective;
    use crate::tuner::common::{Collector, Pool, Problem};
    use crate::tuner::session::MeasurementOutcome;

    fn workflow_batch(pool: &Pool, idxs: &[usize], mode: BatchMode) -> MeasurementBatch {
        MeasurementBatch {
            mode,
            requests: idxs
                .iter()
                .map(|&i| MeasurementRequest::Workflow {
                    pool_idx: i,
                    config: pool.configs[i].clone(),
                })
                .collect(),
        }
    }

    #[test]
    fn no_fault_plan_is_identity() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 30, 5);
        let rng = Pcg32::new(9, 0);
        let batch = workflow_batch(&pool, &[1, 4, 9, 16], BatchMode::FanOut);

        let mut bare = Collector::new(&prob, rng.clone());
        let want = bare.evaluate(&batch);
        let mut col = Collector::new(&prob, rng.clone());
        let mut inj = FaultInjector::new(&mut col, FaultPlan::none(), 123);
        let got = inj.evaluate(&batch);
        assert_eq!(got, want);
        assert_eq!(col.total_cost(), bare.total_cost());
    }

    #[test]
    fn schedule_is_deterministic_and_seed_sensitive() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 40, 5);
        let rng = Pcg32::new(2, 0);
        let plan = FaultPlan::transient(0.4, 0.1);
        let batch = workflow_batch(&pool, &(0..40).collect::<Vec<_>>(), BatchMode::FanOut);

        let run = |seed: u64| {
            let mut col = Collector::new(&prob, rng.clone());
            let mut inj = FaultInjector::new(&mut col, plan, seed);
            inj.evaluate(&batch)
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed, same schedule");
        assert_ne!(a, run(8), "different seed, different schedule");
        let failures = a.iter().filter(|r| !r.is_ok()).count();
        assert!(failures > 0, "a 40-request batch at p~0.5 must lose some");
        assert!(failures < 40, "... and keep some");
    }

    #[test]
    fn schedule_ignores_batch_packing() {
        // the same requests split across different batch shapes must
        // meet the same fates — the schedule keys on the request, not
        // on batch position
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 30, 5);
        let rng = Pcg32::new(4, 0);
        let plan = FaultPlan::transient(0.5, 0.1);

        let fates_of = |groups: &[&[usize]]| {
            let mut col = Collector::new(&prob, rng.clone());
            let mut inj = FaultInjector::new(&mut col, plan, 11);
            groups
                .iter()
                .flat_map(|g| {
                    inj.evaluate(&workflow_batch(&pool, g, BatchMode::FanOut))
                        .into_iter()
                        .map(|r| r.is_ok())
                        .collect::<Vec<_>>()
                })
                .collect::<Vec<bool>>()
        };
        assert_eq!(
            fates_of(&[&[3, 5, 8, 13, 21]]),
            fates_of(&[&[3], &[5, 8], &[13, 21]])
        );
    }

    #[test]
    fn retries_draw_fresh_fates() {
        let plan = FaultPlan {
            p_fail: 0.5,
            ..FaultPlan::none()
        };
        // a stub evaluator so fates are observable without a simulator
        struct Ones;
        impl Evaluator for Ones {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                batch.requests.iter().map(|_| MeasurementResult::ok(1.0)).collect()
            }
        }
        let mut inner = Ones;
        let mut inj = FaultInjector::new(&mut inner, plan, 3);
        let req = MeasurementRequest::Workflow {
            pool_idx: 17,
            config: Config(vec![]),
        };
        let batch = MeasurementBatch::sequential(vec![req]);
        let fates: Vec<bool> = (0..32).map(|_| inj.evaluate(&batch)[0].is_ok()).collect();
        assert!(fates.iter().any(|&b| b), "some attempt must survive");
        assert!(fates.iter().any(|&b| !b), "some attempt must fail");
    }

    /// Priming an injector with `note_replayed` must put its attempt
    /// counters exactly where a real evaluation would have — the
    /// post-resume fate stream continues the pre-crash one.
    #[test]
    fn note_replayed_primes_attempt_counters() {
        struct Ones;
        impl Evaluator for Ones {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                batch.requests.iter().map(|_| MeasurementResult::ok(1.0)).collect()
            }
        }
        let plan = FaultPlan {
            p_fail: 0.5,
            ..FaultPlan::none()
        };
        let batch = MeasurementBatch::sequential(
            (0..8)
                .map(|i| MeasurementRequest::Workflow {
                    pool_idx: i,
                    config: Config(vec![]),
                })
                .collect(),
        );
        let mut a_inner = Ones;
        let mut a = FaultInjector::new(&mut a_inner, plan, 3);
        let _first = a.evaluate(&batch);
        let want = a.evaluate(&batch);
        let mut b_inner = Ones;
        let mut b = FaultInjector::new(&mut b_inner, plan, 3);
        for req in &batch.requests {
            b.note_replayed(req);
        }
        assert_eq!(b.evaluate(&batch), want, "primed counters must continue the stream");
    }

    #[test]
    fn targeted_component_always_crashes() {
        struct Ones;
        impl Evaluator for Ones {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                batch.requests.iter().map(|_| MeasurementResult::ok(1.0)).collect()
            }
        }
        let plan = FaultPlan {
            target_component: Some(1),
            ..FaultPlan::none()
        };
        let mut inner = Ones;
        let mut inj = FaultInjector::new(&mut inner, plan, 0);
        let batch = MeasurementBatch::sequential(vec![
            MeasurementRequest::Component {
                comp: 0,
                config: vec![4],
            },
            MeasurementRequest::Component {
                comp: 1,
                config: vec![4],
            },
        ]);
        let res = inj.evaluate(&batch);
        assert_eq!(res[0].outcome, MeasurementOutcome::Ok(1.0));
        assert_eq!(
            res[1].outcome,
            MeasurementOutcome::Failed(FailureKind::Crash)
        );
    }

    #[test]
    fn corruption_scales_delivered_values() {
        struct Ones;
        impl Evaluator for Ones {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                batch.requests.iter().map(|_| MeasurementResult::ok(1.0)).collect()
            }
        }
        let plan = FaultPlan {
            p_corrupt: 1.0,
            corrupt_mult: 50.0,
            ..FaultPlan::none()
        };
        let mut inner = Ones;
        let mut inj = FaultInjector::new(&mut inner, plan, 5);
        let batch = MeasurementBatch::sequential(
            (0..16)
                .map(|i| MeasurementRequest::Workflow {
                    pool_idx: i,
                    config: Config(vec![]),
                })
                .collect(),
        );
        let res = inj.evaluate(&batch);
        for r in &res {
            let v = r.value().expect("corruption still delivers");
            assert!(v == 50.0 || v == 1.0 / 50.0, "scaled by the mult, got {v}");
        }
        // both directions occur across 16 independent draws
        assert!(res.iter().any(|r| r.value() == Some(50.0)));
        assert!(res.iter().any(|r| r.value() == Some(1.0 / 50.0)));
    }
}
