//! Ask/tell tuning sessions: the inversion-of-control boundary that
//! lets a driver *outside* this crate own the measurement loop.
//!
//! The monolithic `Tuner::run(prob, pool, scorer, m, rng)` could only
//! pull measurements synchronously from the simulator-backed
//! [`Collector`].  A [`TunerSession`] instead *asks* for a batch of
//! measurements ([`MeasurementRequest`]s), the caller performs them —
//! on the simulator, a batch scheduler, a workflow runner, anything —
//! and *tells* the observed values back.  [`drive`] is the trivial
//! driver loop; the simulator path is `drive(session, &mut Collector)`
//! and is bit-identical to the pre-session monolithic loops (pinned by
//! `tests/session_equivalence.rs` against [`super::legacy`]).
//!
//! # Determinism contract (mirrors the thread-invariance contract)
//!
//! A session's behaviour is a pure function of its construction
//! arguments and the told measurement values.  For an [`Evaluator`] to
//! reproduce the simulator campaigns bit-for-bit it must:
//!
//! * answer every request of a batch, in request order;
//! * honour [`MeasurementBatch::mode`]: a [`BatchMode::Sequential`]
//!   batch consumes the evaluator's noise stream one request at a time
//!   in order, while a [`BatchMode::FanOut`] batch (CEAL/ALpH's
//!   `C_meas` fan-out, Alg. 1 line 15) draws each slot from an
//!   independent child stream derived from (stream state, slot index)
//!   — see [`Collector::measure_config_batch`];
//! * never reorder, drop, coalesce or split batches.
//!
//! External drivers that measure on real systems have no noise stream
//! to keep in sync; for them the contract degenerates to "answer in
//! order".  Record/replay ([`super::trace`]) verifies the contract: a
//! replayed session re-issues exactly the recorded requests.
//!
//! # Partial batches and lost requests
//!
//! The arity contract is unconditional: an evaluator must return one
//! [`MeasurementResult`] per request, in request order, **even when a
//! measurement fails or never comes back**.  A lost, crashed or
//! timed-out request is answered *in its slot* with
//! [`MeasurementOutcome::Failed`] or [`MeasurementOutcome::TimedOut`]
//! — never dropped, which would misalign every later slot of the
//! batch.  The RNG contract is per-*attempt*, not per-value:
//!
//! * [`BatchMode::Sequential`]: each request consumes the noise stream
//!   in order only if the evaluator actually runs it.  An evaluator
//!   that fails a request *before* launching (the [`super::faults`]
//!   injector's crash/timeout path) consumes nothing for that slot;
//!   one that fails it *after* the run consumes the run's draws as
//!   usual.  Either way is deterministic as long as the evaluator
//!   itself is.
//! * [`BatchMode::FanOut`]: every slot draws from an independent child
//!   stream keyed by its slot index within the batch, so a failed slot
//!   never shifts a sibling's draws — partial fan-out batches are
//!   exactly why the per-slot derivation exists.
//!
//! Sessions re-request failed measurements themselves (bounded retry,
//! see [`FailurePolicy`]); an evaluator must treat a re-issued request
//! as a fresh attempt, not replay the failure.

use std::collections::HashSet;

use crate::config::{Config, F_MAX};
use crate::gbt::{Ensemble, IncrementalTrainer};
use crate::surrogate::lowfi::ComponentSamples;
use crate::surrogate::Scorer;
use crate::util::rng::{Pcg32, RngSnapshot};

use crate::util::stats;

use super::common::{Collector, Pool, Problem, TunerOutput};

pub use crate::sim::measurement::{FailureKind, MeasurementOutcome};

/// One measurement a session needs performed.
#[derive(Clone, Debug, PartialEq)]
pub enum MeasurementRequest {
    /// Run the whole workflow at a pool configuration.  `pool_idx`
    /// identifies the configuration to the session (and to replay);
    /// `config` carries the concrete parameter values so an external
    /// driver needs no pool access to launch the run.
    Workflow { pool_idx: usize, config: Config },
    /// Run configurable component `comp` (index into the workflow
    /// spec) in isolation at `config` (the component's own values).
    Component { comp: usize, config: Vec<i64> },
}

/// The result of one [`MeasurementRequest`]: either the measured
/// objective value (seconds or core-hours, per the problem's
/// objective) or the failure that prevented one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeasurementResult {
    pub outcome: MeasurementOutcome,
}

impl MeasurementResult {
    /// A delivered reading.
    pub fn ok(value: f64) -> MeasurementResult {
        MeasurementResult {
            outcome: MeasurementOutcome::Ok(value),
        }
    }

    /// A failed attempt (no reading).
    pub fn failed(kind: FailureKind) -> MeasurementResult {
        MeasurementResult {
            outcome: MeasurementOutcome::Failed(kind),
        }
    }

    /// An attempt abandoned at its deadline.
    pub fn timed_out() -> MeasurementResult {
        MeasurementResult {
            outcome: MeasurementOutcome::TimedOut,
        }
    }

    /// The delivered value, if any.
    pub fn value(&self) -> Option<f64> {
        self.outcome.value()
    }

    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }
}

/// How a session responds to failed measurements: bounded retry with
/// a backoff-shaped wall-clock charge, then substitution or skip, plus
/// an optional robust outlier gate over delivered readings.
///
/// Failed runs are not free — a crashed or timed-out run still burned
/// wall-clock before dying.  Each failed attempt is charged
/// `failed_cost_frac × expected_cost × min(backoff_growth^attempt,
/// max_backoff)` where `expected_cost` is the pool's expected
/// objective value for the configuration (components use the mean
/// observed component cost).  The growth term models retry backoff as
/// cost rather than wall-clock sleep, so budget-gated tuners
/// (BudgetedCeal) see retry spend in their per-sample gates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailurePolicy {
    /// Re-measure attempts allowed after the first failure of a
    /// request (0 = never retry).
    pub max_retries: usize,
    /// Fraction of the expected run cost charged per failed attempt.
    pub failed_cost_frac: f64,
    /// Multiplicative backoff of the charge per extra attempt.
    pub backoff_growth: f64,
    /// Cap on the backoff multiplier.
    pub max_backoff: f64,
    /// Enable the median/MAD outlier gate over delivered workflow
    /// readings (one deterministic re-measure per flagged point, then
    /// winsorized for surrogate fits and final selection).  Off by
    /// default: on a fault-free path the gate must not perturb the
    /// bit-pinned trajectories.
    pub outlier_gate: bool,
    /// Gate threshold in robust z-units on ln(y).
    pub outlier_k: f64,
    /// Rounds of substitute sampling a fixed-size session (random
    /// sampling) may use to replace permanently failed picks.
    pub substitute_rounds: usize,
}

impl Default for FailurePolicy {
    fn default() -> FailurePolicy {
        FailurePolicy {
            max_retries: 2,
            failed_cost_frac: 0.25,
            backoff_growth: 2.0,
            max_backoff: 4.0,
            outlier_gate: false,
            outlier_k: 6.0,
            substitute_rounds: 2,
        }
    }
}

impl FailurePolicy {
    /// The policy campaigns use under fault injection: default retry
    /// budget with the outlier gate armed.
    pub fn fault_tolerant() -> FailurePolicy {
        FailurePolicy {
            outlier_gate: true,
            ..FailurePolicy::default()
        }
    }

    /// Wall-clock charge for one failed attempt (`attempt` counts from
    /// 0 on the first failure of a request).
    pub(crate) fn failure_charge(&self, expected_cost: f64, attempt: usize) -> f64 {
        let backoff = self
            .backoff_growth
            .powi(attempt as i32)
            .min(self.max_backoff);
        expected_cost * self.failed_cost_frac * backoff
    }
}

/// How an evaluator must consume its randomness across a batch — part
/// of the determinism contract (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    /// Measure one request after another on a single noise stream.
    Sequential,
    /// Measure every request on an independent derived stream (the
    /// worker-pool fan-out of CEAL/ALpH batches).  Fan-out batches
    /// carry workflow requests only.
    FanOut,
}

/// A batch of measurements requested by one [`TunerSession::ask`].
#[derive(Clone, Debug, PartialEq)]
pub struct MeasurementBatch {
    pub mode: BatchMode,
    pub requests: Vec<MeasurementRequest>,
}

impl MeasurementBatch {
    pub fn sequential(requests: Vec<MeasurementRequest>) -> MeasurementBatch {
        MeasurementBatch {
            mode: BatchMode::Sequential,
            requests,
        }
    }

    pub fn fan_out(requests: Vec<MeasurementRequest>) -> MeasurementBatch {
        MeasurementBatch {
            mode: BatchMode::FanOut,
            requests,
        }
    }

    /// The empty batch: the session has nothing left to measure.
    pub fn empty() -> MeasurementBatch {
        MeasurementBatch::sequential(Vec::new())
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Where a session routes library warnings (e.g. "component space
/// admits no feasible configuration") instead of printing them
/// unconditionally: the embedding caller chooses.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum DiagSink {
    /// Print `warning: …` to stderr as they occur (the CLI default and
    /// the pre-session behaviour).
    #[default]
    Stderr,
    /// Discard warnings.
    Silent,
    /// Collect warnings for [`TunerSession::diagnostics`].
    Capture,
    /// Append `warning: …` lines to a file.  Multi-tenant drivers (the
    /// serve daemon, journaled campaign reps) point this at the
    /// session's own journal directory (`diag.log`) so warnings from
    /// concurrent sessions never interleave on the shared stderr.
    /// Falls back to stderr if the file cannot be written, so a bad
    /// path never swallows a diagnostic.
    File(std::path::PathBuf),
}

/// A session-owned warning sink (see [`DiagSink`]).
#[derive(Debug, Default)]
pub(crate) struct Diagnostics {
    sink: DiagSink,
    captured: Vec<String>,
}

impl Diagnostics {
    pub(crate) fn warn(&mut self, msg: String) {
        match &self.sink {
            DiagSink::Stderr => eprintln!("warning: {msg}"),
            DiagSink::Silent => {}
            DiagSink::Capture => self.captured.push(msg),
            DiagSink::File(path) => {
                use std::io::Write as _;
                let appended = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)
                    .and_then(|mut f| writeln!(f, "warning: {msg}"));
                if appended.is_err() {
                    eprintln!("warning: {msg}");
                }
            }
        }
    }

    pub(crate) fn set_sink(&mut self, sink: DiagSink) {
        self.sink = sink;
    }

    pub(crate) fn captured(&self) -> &[String] {
        &self.captured
    }
}

/// A progress snapshot of a session (informational; nothing in the
/// tuning path reads it back).
#[derive(Clone, Debug)]
pub struct SessionState {
    /// Current phase name ("components", "bootstrap", "refine", …).
    pub phase: &'static str,
    /// True once `ask` will only ever return the empty batch.
    pub done: bool,
    /// Batches asked / told so far.
    pub asked_batches: usize,
    pub told_batches: usize,
    /// Individual measurements performed so far.
    pub workflow_runs: usize,
    pub component_runs: usize,
    /// Σ objective over told measurements plus failure charges
    /// (budget accounting).
    pub collection_cost: f64,
    /// Failed/timed-out measurement attempts so far.
    pub failed_runs: usize,
    /// Surrogate (re)fits performed so far.
    pub model_refits: usize,
    /// Refit calls answered from the fingerprint-gated model cache
    /// (no training happened; the refit still counts above, keeping
    /// the digest/trajectory accounting identical to from-scratch).
    pub model_refit_skips: usize,
    /// CEAL-family switch detection: `Some(true)` once the
    /// high-fidelity model has overtaken the low-fidelity one.
    pub using_hifi: Option<bool>,
}

/// A bit-exact fingerprint of a mid-session tuner state, used by the
/// crash-safe journal ([`super::journal`]): after rebuilding a session
/// by replaying its journaled measurement exchanges, the rebuilt
/// digest must equal the one captured at checkpoint time, or the
/// resume is rejected as diverged (different build, seed, or a
/// corrupted checkpoint) instead of silently continuing from the
/// wrong state.
///
/// The digest covers everything [`SessionState`] reports — phase,
/// progress counters, the collection cost *to the bit* — plus the raw
/// position of the session's selection RNG stream, which determines
/// every future pick.  It deliberately does not embed the measured
/// set or surrogate models: those are pure functions of the replayed
/// exchanges, and the counters + cost bits + RNG position pin them
/// transitively.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionDigest {
    pub phase: String,
    pub done: bool,
    pub asked_batches: usize,
    pub told_batches: usize,
    pub workflow_runs: usize,
    pub component_runs: usize,
    pub failed_runs: usize,
    pub model_refits: usize,
    /// `collection_cost.to_bits()` — float equality is exact here.
    pub cost_bits: u64,
    /// Raw position of the selection stream.
    pub sel_rng: RngSnapshot,
    pub using_hifi: Option<bool>,
}

/// A stepwise tuning algorithm: ask for measurements, accept results,
/// repeat until the budget is spent, then finish into a
/// [`TunerOutput`].
///
/// Lifecycle: `ask` → (caller measures) → `tell`, strictly
/// alternating; an empty `ask` batch means the session is complete and
/// `finish` may be called.  Results passed to `tell` must answer the
/// immediately preceding batch, in request order.
pub trait TunerSession {
    fn name(&self) -> &'static str;

    /// Next batch of measurements the session needs; empty when the
    /// session is complete.  Panics if the previous batch has not been
    /// told yet.
    fn ask(&mut self) -> MeasurementBatch;

    /// Non-blocking variant of [`ask`](Self::ask): `None` when the
    /// previous batch has not been told yet (where `ask` would panic),
    /// `Some(batch)` otherwise.  This is the surface a multi-tenant
    /// driver uses when asks and tells arrive from different
    /// connections and strict alternation cannot be assumed at the
    /// call site.  Every built-in session counts `asked_batches` only
    /// for real (non-empty) issues, so the default implementation is
    /// exact for them.
    fn try_ask(&mut self) -> Option<MeasurementBatch> {
        let s = self.state();
        if s.asked_batches > s.told_batches {
            return None;
        }
        Some(self.ask())
    }

    /// Report the results of the last asked batch, in request order.
    fn tell(&mut self, results: &[MeasurementResult]);

    /// Progress snapshot (budget accounting, refits, switch state).
    fn state(&self) -> SessionState;

    /// Consume the session into the tuner's output.  Panics if called
    /// before the session measured enough to produce a model (i.e.
    /// before `ask` first returned the empty batch).
    fn finish(self: Box<Self>) -> TunerOutput;

    /// Route warnings (default: stderr, matching the monolithic API).
    fn set_diag_sink(&mut self, sink: DiagSink) {
        let _ = sink;
    }

    /// Configure how the session reacts to failed measurements.  Must
    /// be called before the first `ask`; the built-in sessions all
    /// honour it, the default is a no-op for sessions that never see
    /// failures.
    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        let _ = policy;
    }

    /// Warnings captured so far (only under [`DiagSink::Capture`]).
    fn diagnostics(&self) -> &[String] {
        &[]
    }

    /// Bit-exact state digest for crash-safe checkpointing (see
    /// [`SessionDigest`]).  All built-in sessions implement it;
    /// `None` (the default) means the session cannot be
    /// digest-verified on resume and the journal skips that check.
    fn digest(&self) -> Option<SessionDigest> {
        None
    }
}

/// Anything that can perform a session's measurement batches.  The
/// simulator-backed [`Collector`] is the canonical implementation; a
/// [`super::trace::TraceReplayer`] replays a recorded stream; external
/// embedders implement it over their own launch infrastructure.
pub trait Evaluator {
    /// Perform every request of `batch`, returning results in request
    /// order (see the module-level determinism contract).
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult>;

    /// Capture the evaluator-side stochastic state after a batch, for
    /// the crash journal.  The simulator-backed [`Collector`] returns
    /// its measurement-noise stream position; decorators forward to
    /// their inner evaluator; evaluators with no internal randomness
    /// (external drivers, replayers) keep the default `None`.
    fn checkpoint_state(&mut self) -> Option<EvaluatorState> {
        None
    }

    /// Restore state captured by
    /// [`checkpoint_state`](Self::checkpoint_state).  Returns whether
    /// anything was restored (the default restores nothing).
    fn restore_state(&mut self, state: &EvaluatorState) -> bool {
        let _ = state;
        false
    }

    /// Crash-recovery fast-forward: a journaled request is being
    /// replayed into a rebuilt session *without* re-measuring.
    /// Evaluators with per-request bookkeeping (the fault injector's
    /// attempt counters) advance it here so post-resume decisions sit
    /// at the same stream positions as the uninterrupted run; the
    /// default is a no-op.
    fn note_replayed(&mut self, req: &MeasurementRequest) {
        let _ = req;
    }
}

/// Durable evaluator-side state captured into the crash journal with
/// every tell record: the raw measurement-noise stream position of the
/// innermost stochastic evaluator.  Restoring it on resume makes
/// post-resume live measurements draw the same noise as the
/// uninterrupted run would have.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvaluatorState {
    pub rng: RngSnapshot,
}

impl Evaluator for Collector<'_> {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        match batch.mode {
            BatchMode::Sequential => batch
                .requests
                .iter()
                .map(|req| {
                    let value = match req {
                        MeasurementRequest::Workflow { config, .. } => self.measure(config),
                        MeasurementRequest::Component { comp, config } => {
                            self.measure_component(*comp, config)
                        }
                    };
                    MeasurementResult::ok(value)
                })
                .collect(),
            BatchMode::FanOut => {
                let cfgs: Vec<&Config> = batch
                    .requests
                    .iter()
                    .map(|req| match req {
                        MeasurementRequest::Workflow { config, .. } => config,
                        MeasurementRequest::Component { .. } => {
                            panic!("fan-out batches carry workflow requests only")
                        }
                    })
                    .collect();
                self.measure_config_batch(&cfgs)
                    .into_iter()
                    .map(MeasurementResult::ok)
                    .collect()
            }
        }
    }

    fn checkpoint_state(&mut self) -> Option<EvaluatorState> {
        Some(EvaluatorState {
            rng: self.rng().snapshot(),
        })
    }

    fn restore_state(&mut self, state: &EvaluatorState) -> bool {
        *self.rng() = Pcg32::from_snapshot(state.rng);
        true
    }
}

/// The generic driver: the whole of the old monolithic `Tuner::run`,
/// now decoupled from *what* performs the measurements.
pub fn drive(
    mut session: Box<dyn TunerSession + '_>,
    evaluator: &mut dyn Evaluator,
) -> TunerOutput {
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        let results = evaluator.evaluate(&batch);
        assert_eq!(
            results.len(),
            batch.len(),
            "evaluator must answer every request of a batch"
        );
        session.tell(&results);
    }
    session.finish()
}

/// State shared by every built-in session: problem/pool/scorer
/// references, the selection RNG stream, the measured set, and the
/// budget accounting that used to live on the [`Collector`].
///
/// Accounting is bit-compatible with the collector's: workflow and
/// component costs accumulate in told order into separate sums, and
/// `total_cost` adds the two — exactly the float operations of the
/// monolithic path, so session-produced `collection_cost` matches the
/// legacy output bitwise.
pub(crate) struct SessionCore<'a> {
    pub(crate) prob: &'a Problem,
    pub(crate) pool: &'a Pool,
    pub(crate) scorer: &'a Scorer,
    /// Selection stream, derived exactly as the monolithic loops did.
    pub(crate) sel_rng: Pcg32,
    pub(crate) measured: Vec<(usize, f64)>,
    pub(crate) measured_set: HashSet<usize>,
    pub(crate) workflow_runs: usize,
    pub(crate) component_runs: usize,
    workflow_cost: f64,
    component_cost: f64,
    /// Failure charges, kept apart from the successful-run sums so the
    /// fault-free accounting stays bitwise identical to the pinned
    /// legacy trajectories (adding `+ 0.0` to a non-negative sum is a
    /// bitwise no-op).
    failed_workflow_cost: f64,
    failed_component_cost: f64,
    pub(crate) failed_runs: usize,
    pub(crate) policy: FailurePolicy,
    /// Pool indices that already spent their one outlier re-measure.
    remeasured: HashSet<usize>,
    pub(crate) model_refits: usize,
    /// Refits answered from the fingerprint cache (observability only
    /// — deliberately absent from [`SessionDigest`], since a skip is
    /// behaviorally identical to the training it avoided).
    pub(crate) model_refit_skips: usize,
    /// Session-resident amortized trainer for the high-fidelity
    /// surrogate: keeps the binned dataset across rounds so each refit
    /// only bins the rows added since the last one.
    hifi_fit: IncrementalTrainer,
    pub(crate) asked_batches: usize,
    pub(crate) told_batches: usize,
    pub(crate) diag: Diagnostics,
}

impl<'a> SessionCore<'a> {
    pub(crate) fn new(
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        rng: &mut Pcg32,
    ) -> SessionCore<'a> {
        SessionCore {
            prob,
            pool,
            scorer,
            sel_rng: rng.derive_str("select"),
            measured: Vec::new(),
            measured_set: HashSet::new(),
            workflow_runs: 0,
            component_runs: 0,
            workflow_cost: 0.0,
            component_cost: 0.0,
            failed_workflow_cost: 0.0,
            failed_component_cost: 0.0,
            failed_runs: 0,
            policy: FailurePolicy::default(),
            remeasured: HashSet::new(),
            model_refits: 0,
            model_refit_skips: 0,
            hifi_fit: IncrementalTrainer::new(),
            asked_batches: 0,
            told_batches: 0,
            diag: Diagnostics::default(),
        }
    }

    /// Build a workflow request for pool index `i`.
    pub(crate) fn workflow_request(&self, i: usize) -> MeasurementRequest {
        MeasurementRequest::Workflow {
            pool_idx: i,
            config: self.pool.configs[i].clone(),
        }
    }

    /// Requests for a slate of pool picks, marking each as measured
    /// (every emitted request *will* be measured, so marking at emit
    /// time is equivalent to the monolithic insert-after-measure).
    pub(crate) fn take_workflow_picks(&mut self, picks: &[usize]) -> Vec<MeasurementRequest> {
        for &i in picks {
            self.measured_set.insert(i);
        }
        picks.iter().map(|&i| self.workflow_request(i)).collect()
    }

    /// Account one told workflow measurement.
    pub(crate) fn record_workflow(&mut self, i: usize, y: f64) {
        self.measured.push((i, y));
        self.workflow_runs += 1;
        self.workflow_cost += y;
    }

    /// Account one told component measurement.
    pub(crate) fn record_component(&mut self, y: f64) {
        self.component_runs += 1;
        self.component_cost += y;
    }

    /// Replace pool index `i`'s recorded reading with a fresh
    /// re-measure (the outlier gate's second opinion).  The re-measure
    /// is a real run: it counts and costs like any other, but the
    /// surrogate only ever sees the newer reading.
    pub(crate) fn replace_workflow(&mut self, i: usize, y: f64) {
        self.workflow_runs += 1;
        self.workflow_cost += y;
        if let Some(slot) = self.measured.iter_mut().rev().find(|(j, _)| *j == i) {
            slot.1 = y;
        }
    }

    /// Charge one failed workflow attempt at pool index `i` against
    /// the budget (the run burned wall-clock before dying; the
    /// expected cost is the pool's ground-truth objective value).
    pub(crate) fn charge_failed_workflow(&mut self, i: usize, attempt: usize) {
        let charge = self.policy.failure_charge(self.pool.truth_of(i), attempt);
        self.failed_workflow_cost += charge;
        self.failed_runs += 1;
    }

    /// Charge one failed isolated-component attempt.  The expected
    /// cost is the mean observed component cost, falling back to the
    /// pool's failure-cost floor (eager: pool-best value, as before;
    /// lazy: one fixed member's truth) when nothing has been observed
    /// yet — always positive, so budget-gated phases terminate even
    /// under a 100% failure rate.
    pub(crate) fn charge_failed_component(&mut self, attempt: usize) {
        let expected = if self.component_runs > 0 {
            self.component_cost / self.component_runs as f64
        } else {
            self.pool.failure_cost_floor()
        };
        self.failed_component_cost += self.policy.failure_charge(expected, attempt);
        self.failed_runs += 1;
    }

    /// Component-side spend including failure charges — what
    /// budget-gated component phases compare against their allowance.
    pub(crate) fn component_spend(&self) -> f64 {
        self.component_cost + self.failed_component_cost
    }

    pub(crate) fn total_cost(&self) -> f64 {
        self.workflow_cost + self.component_cost + self.failed_workflow_cost
            + self.failed_component_cost
    }

    pub(crate) fn refit(&mut self) {
        self.model_refits += 1;
    }

    /// Train (or fetch) the high-fidelity workflow surrogate on
    /// `measured` rows through the session's amortized trainer.
    /// Bitwise identical to [`super::common::train_hifi`] on the same
    /// rows; repeated calls with unchanged rows return the cached
    /// model, counted in `model_refit_skips` (the refit itself is
    /// still accounted by the caller's [`refit`](Self::refit), keeping
    /// digests identical to the from-scratch path).
    pub(crate) fn fit_hifi(&mut self, measured: &[(usize, f64)]) -> Ensemble {
        let xs: Vec<[f32; F_MAX]> = measured
            .iter()
            .map(|&(i, _)| self.pool.feats.workflow[i])
            .collect();
        let y: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
        let params = crate::gbt::GbtParams::small_data();
        let skips_before = self.hifi_fit.skips();
        let model =
            self.hifi_fit.train_log(&xs, &y, self.prob.n_workflow_features(), &params);
        self.model_refit_skips += (self.hifi_fit.skips() - skips_before) as usize;
        model
    }

    /// Bump the skip counter for a fingerprint-gated reuse that
    /// happened outside [`fit_hifi`](Self::fit_hifi) (e.g. ALpH's
    /// combiner trainer).
    pub(crate) fn note_refit_skips(&mut self, n: u64) {
        self.model_refit_skips += n as usize;
    }

    /// Build the crash-checkpoint digest from a progress snapshot plus
    /// the selection stream's raw position (see [`SessionDigest`]).
    pub(crate) fn digest(&self, s: &SessionState) -> SessionDigest {
        SessionDigest {
            phase: s.phase.to_string(),
            done: s.done,
            asked_batches: s.asked_batches,
            told_batches: s.told_batches,
            workflow_runs: s.workflow_runs,
            component_runs: s.component_runs,
            failed_runs: s.failed_runs,
            model_refits: s.model_refits,
            cost_bits: s.collection_cost.to_bits(),
            sel_rng: self.sel_rng.snapshot(),
            using_hifi: s.using_hifi,
        }
    }

    pub(crate) fn state(
        &self,
        phase: &'static str,
        done: bool,
        using_hifi: Option<bool>,
    ) -> SessionState {
        SessionState {
            phase,
            done,
            asked_batches: self.asked_batches,
            told_batches: self.told_batches,
            workflow_runs: self.workflow_runs,
            component_runs: self.component_runs,
            collection_cost: self.total_cost(),
            failed_runs: self.failed_runs,
            model_refits: self.model_refits,
            model_refit_skips: self.model_refit_skips,
            using_hifi,
        }
    }

    /// The measured rows a surrogate fit (or the final selection)
    /// should see.  With the outlier gate off this is the raw record;
    /// with it on, readings outside the median/MAD band on ln(y) are
    /// winsorized to the band edge — the "down-weight" step that caps
    /// a corrupted reading's influence without discarding the row.
    pub(crate) fn train_measured(&self) -> Vec<(usize, f64)> {
        if !self.policy.outlier_gate {
            return self.measured.clone();
        }
        winsorize(&self.measured, self.policy.outlier_k).0
    }

    /// Pool indices whose delivered reading the gate currently flags
    /// and which still have their one deterministic re-measure
    /// available.  Marks the returned picks as spent, so every pool
    /// index is re-measured at most once per session (bounding the
    /// gate's extra runs).
    pub(crate) fn outlier_remeasure_picks(&mut self) -> Vec<usize> {
        if !self.policy.outlier_gate {
            return Vec::new();
        }
        let (_, flagged) = winsorize(&self.measured, self.policy.outlier_k);
        let picks: Vec<usize> = flagged
            .into_iter()
            .filter(|i| !self.remeasured.contains(i))
            .collect();
        self.remeasured.extend(picks.iter().copied());
        picks
    }

    /// Finish into the tuner output (searcher already ran → `best_idx`).
    pub(crate) fn into_output(self, model: Ensemble, best_idx: usize) -> TunerOutput {
        TunerOutput {
            model,
            measured: self.measured,
            best_idx,
            collection_cost: self.workflow_cost + self.component_cost
                + self.failed_workflow_cost
                + self.failed_component_cost,
            workflow_runs: self.workflow_runs,
            failed_runs: self.failed_runs,
        }
    }
}

/// Median/MAD outlier gate on ln(y): readings more than `k` robust
/// z-units from the median are clamped to the band edge.  Returns the
/// winsorized rows and the flagged pool indices.  Needs at least four
/// rows and a positive MAD to act (a degenerate spread means there is
/// nothing robust to gate against).
fn winsorize(measured: &[(usize, f64)], k: f64) -> (Vec<(usize, f64)>, Vec<usize>) {
    if measured.len() < 4 {
        return (measured.to_vec(), Vec::new());
    }
    let lns: Vec<f64> = measured
        .iter()
        .map(|&(_, y)| y.max(f64::MIN_POSITIVE).ln())
        .collect();
    let med = stats::median(&lns);
    let devs: Vec<f64> = lns.iter().map(|l| (l - med).abs()).collect();
    let mad = stats::median(&devs);
    if mad <= 0.0 {
        return (measured.to_vec(), Vec::new());
    }
    // 1.4826 makes MAD a consistent σ estimate under normality
    let band = k * 1.4826 * mad;
    let mut rows = measured.to_vec();
    let mut flagged = Vec::new();
    for (row, &ln_y) in rows.iter_mut().zip(&lns) {
        if (ln_y - med).abs() > band {
            flagged.push(row.0);
            row.1 = (med + band * (ln_y - med).signum()).exp();
        }
    }
    (rows, flagged)
}

/// Split one told batch into successes and retries.  `pending` pairs
/// each request's session-side meta with its attempt counter (0 on
/// first issue).  Every non-ok outcome invokes `charge` (failed
/// attempts always cost wall-clock); entries with attempt budget left
/// come back in the retry list with the counter advanced, exhausted
/// ones are dropped.  Successes keep told order, which on the
/// fault-free path is exactly batch order.
pub(crate) fn triage_results<M>(
    pending: Vec<(M, usize)>,
    results: &[MeasurementResult],
    max_retries: usize,
    mut charge: impl FnMut(&M, usize),
) -> (Vec<(M, f64)>, Vec<(M, usize)>) {
    assert_eq!(
        results.len(),
        pending.len(),
        "tell must answer the asked batch"
    );
    let mut ok = Vec::new();
    let mut retry = Vec::new();
    for ((meta, attempt), r) in pending.into_iter().zip(results) {
        match r.value() {
            Some(v) => ok.push((meta, v)),
            None => {
                charge(&meta, attempt);
                if attempt < max_retries {
                    retry.push((meta, attempt + 1));
                }
            }
        }
    }
    (ok, retry)
}

/// Phase-1 component sampling shared by the CEAL-family sessions
/// (Alg. 1 lines 1-6): reset `samples` to the historical data (or
/// empties), pre-draw every component's isolated configurations from
/// the selection stream — legal because the selection and measurement
/// streams are independent, so both draw orders match the monolithic
/// interleaving — and return the measurement requests; `slots` records
/// each request's (configurable slot, encoded features) for `tell`.
/// An infeasible component space degrades to a warning on the
/// session's diagnostics sink and skips only that component (it trains
/// on whatever it has; empty → constant model).
pub(crate) fn sample_component_requests(
    core: &mut SessionCore<'_>,
    historical: Option<&std::sync::Arc<Vec<ComponentSamples>>>,
    m_r: usize,
    samples: &mut Vec<ComponentSamples>,
    slots: &mut Vec<(usize, [f32; F_MAX])>,
) -> Vec<MeasurementRequest> {
    let spec = &core.prob.sim.spec;
    let configurable = spec.configurable();
    *samples = match historical {
        Some(h) => {
            assert_eq!(h.len(), configurable.len(), "historical arity");
            h.iter().cloned().collect()
        }
        None => configurable
            .iter()
            .map(|_| ComponentSamples::default())
            .collect(),
    };
    slots.clear();
    let mut reqs = Vec::new();
    for (slot, &comp) in configurable.iter().enumerate() {
        let cs = &spec.components[comp];
        for _ in 0..m_r {
            // feasible on the same <=32-node allocations as the pool
            match core.prob.sim.sample_component_feasible(comp, &mut core.sel_rng) {
                Ok(cfg) => {
                    slots.push((slot, cs.encode(&cfg)));
                    reqs.push(MeasurementRequest::Component { comp, config: cfg });
                }
                Err(e) => {
                    // an over-tight component space: train on what we
                    // have instead of aborting the campaign
                    core.diag.warn(format!("{e}; skipping its isolated runs"));
                    break;
                }
            }
        }
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn batch_constructors() {
        let b = MeasurementBatch::empty();
        assert!(b.is_empty());
        assert_eq!(b.mode, BatchMode::Sequential);
        let r = MeasurementRequest::Component {
            comp: 0,
            config: vec![1, 2],
        };
        let f = MeasurementBatch::fan_out(vec![]);
        assert_eq!(f.mode, BatchMode::FanOut);
        let s = MeasurementBatch::sequential(vec![r.clone()]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.requests[0], r);
    }

    #[test]
    fn diagnostics_sinks() {
        let mut d = Diagnostics::default();
        d.set_sink(DiagSink::Silent);
        d.warn("dropped".into());
        assert!(d.captured().is_empty());
        d.set_sink(DiagSink::Capture);
        d.warn("kept".into());
        assert_eq!(d.captured(), ["kept"]);
    }

    #[test]
    fn diagnostics_file_sink_appends() {
        let path = std::env::temp_dir().join(format!(
            "ceal-diag-sink-{}-{:?}.log",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut d = Diagnostics::default();
        d.set_sink(DiagSink::File(path.clone()));
        d.warn("first".into());
        d.warn("second".into());
        let text = std::fs::read_to_string(&path).expect("diag file written");
        assert_eq!(text, "warning: first\nwarning: second\n");
        assert!(d.captured().is_empty(), "file sink does not capture");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failure_charge_backs_off_and_caps() {
        let p = FailurePolicy::default();
        assert_eq!(p.failure_charge(100.0, 0), 25.0);
        assert_eq!(p.failure_charge(100.0, 1), 50.0);
        assert_eq!(p.failure_charge(100.0, 2), 100.0);
        // growth 2^3 = 8 capped at 4
        assert_eq!(p.failure_charge(100.0, 3), 100.0);
    }

    #[test]
    fn triage_splits_ok_retry_and_exhausted() {
        let pending = vec![("a", 0), ("b", 0), ("c", 2)];
        let results = [
            MeasurementResult::ok(5.0),
            MeasurementResult::failed(FailureKind::Crash),
            MeasurementResult::timed_out(),
        ];
        let mut charged = Vec::new();
        let (ok, retry) = triage_results(pending, &results, 2, |m, att| charged.push((*m, att)));
        assert_eq!(ok, vec![("a", 5.0)]);
        // "b" has budget left; "c" exhausted its two retries
        assert_eq!(retry, vec![("b", 1)]);
        assert_eq!(charged, vec![("b", 0), ("c", 2)]);
    }

    #[test]
    fn winsorize_flags_and_clamps_outliers() {
        let mut rows: Vec<(usize, f64)> = (0..12).map(|i| (i, 10.0 + (i % 3) as f64)).collect();
        rows.push((12, 10.0 * 1e6)); // corrupted straggler
        let (gated, flagged) = winsorize(&rows, 6.0);
        assert_eq!(flagged, vec![12]);
        assert!(gated[12].1 < 1e6, "clamped, got {}", gated[12].1);
        assert!(gated[12].1 > 10.0, "clamps to the band edge, not the median");
        // inliers untouched bitwise
        for i in 0..12 {
            assert_eq!(gated[i], rows[i]);
        }

        // degenerate spread (MAD 0) and tiny samples gate nothing
        let flat: Vec<(usize, f64)> = (0..8).map(|i| (i, 3.0)).collect();
        assert!(winsorize(&flat, 6.0).1.is_empty());
        assert!(winsorize(&rows[..3], 6.0).1.is_empty());
    }

    /// The collector evaluator must consume its RNG exactly like the
    /// direct measure / measure_pool_batch calls it replaces.
    #[test]
    fn collector_evaluator_matches_direct_calls() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 20, 3);
        let seed_rng = Pcg32::new(11, 0);

        // sequential: workflow + component requests
        let mut direct = Collector::new(&prob, seed_rng.clone());
        let d0 = direct.measure(&pool.configs[2]);
        let d1 = direct.measure_component(0, prob.sim.spec.component_slice(&pool.configs[2], 0));
        let mut via = Collector::new(&prob, seed_rng.clone());
        let batch = MeasurementBatch::sequential(vec![
            MeasurementRequest::Workflow {
                pool_idx: 2,
                config: pool.configs[2].clone(),
            },
            MeasurementRequest::Component {
                comp: 0,
                config: prob.sim.spec.component_slice(&pool.configs[2], 0).to_vec(),
            },
        ]);
        let res = via.evaluate(&batch);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].value(), Some(d0));
        assert_eq!(res[1].value(), Some(d1));
        assert_eq!(via.total_cost(), direct.total_cost());

        // fan-out: must match measure_pool_batch draw-for-draw
        let idxs = [4usize, 7, 9];
        let mut direct = Collector::new(&prob, seed_rng.clone());
        let want = direct.measure_pool_batch(&pool, &idxs);
        let mut via = Collector::new(&prob, seed_rng.clone());
        let batch = MeasurementBatch::fan_out(
            idxs.iter()
                .map(|&i| MeasurementRequest::Workflow {
                    pool_idx: i,
                    config: pool.configs[i].clone(),
                })
                .collect(),
        );
        let res = via.evaluate(&batch);
        for (r, (_, y)) in res.iter().zip(&want) {
            assert_eq!(r.value(), Some(*y));
        }
        assert_eq!(via.workflow_runs, direct.workflow_runs);
        assert_eq!(via.total_cost(), direct.total_cost());
    }
}
