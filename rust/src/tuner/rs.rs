//! RS — random-sampling baseline (§7.3): spend the whole budget on
//! uniformly random pool configurations, train once, search.
//!
//! Session shape: one sequential batch of `m` random picks, then done.
//! Under failures the session retries each failed pick up to the
//! policy's attempt budget, then substitutes fresh random picks for
//! permanently lost ones (bounded rounds), and finally — with the
//! outlier gate armed — re-measures flagged readings once before
//! training.

use super::common::{
    random_unmeasured, searcher_best, Pool, Problem, Tuner, TunerOutput,
};
use super::session::{
    triage_results, FailurePolicy, MeasurementBatch, MeasurementResult, SessionCore,
    SessionDigest, SessionState, TunerSession,
};
use crate::gbt::Ensemble;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct RandomSampling;

impl Tuner for RandomSampling {
    fn name(&self) -> &'static str {
        "RS"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        Box::new(RsSession {
            core: SessionCore::new(prob, pool, scorer, rng),
            m: m.min(pool.len()),
            pending: Vec::new(),
            retry: Vec::new(),
            in_gate: false,
            issued_main: false,
            sub_rounds: 0,
            got: 0,
            done: false,
        })
    }
}

struct RsSession<'a> {
    core: SessionCore<'a>,
    m: usize,
    /// In-flight (pool index, attempt) pairs (empty when none).
    pending: Vec<(usize, usize)>,
    /// Failed picks with attempt budget left, re-asked next batch.
    retry: Vec<(usize, usize)>,
    /// True while the in-flight batch re-measures gate-flagged points.
    in_gate: bool,
    issued_main: bool,
    /// Substitute-sampling rounds spent replacing lost picks.
    sub_rounds: usize,
    /// Successfully recorded samples (gate re-measures not counted).
    got: usize,
    done: bool,
}

impl RsSession<'_> {
    fn issue(&mut self, picks: Vec<(usize, usize)>) -> MeasurementBatch {
        self.core.asked_batches += 1;
        let reqs = picks
            .iter()
            .map(|&(i, _)| self.core.workflow_request(i))
            .collect();
        self.pending = picks;
        MeasurementBatch::sequential(reqs)
    }
}

impl TunerSession for RsSession<'_> {
    fn name(&self) -> &'static str {
        "RS"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(self.pending.is_empty(), "ask() with results outstanding");
        if self.done {
            return MeasurementBatch::empty();
        }
        if !self.issued_main {
            self.issued_main = true;
            let picks = random_unmeasured(
                self.core.pool,
                &self.core.measured_set,
                self.m,
                &mut self.core.sel_rng,
            );
            for &i in &picks {
                self.core.measured_set.insert(i);
            }
            return self.issue(picks.into_iter().map(|i| (i, 0)).collect());
        }
        if !self.retry.is_empty() {
            let retry = std::mem::take(&mut self.retry);
            return self.issue(retry);
        }
        // main batch and retries resolved: top up permanently lost
        // picks with fresh random draws (bounded rounds)
        let deficit = self.m.saturating_sub(self.got);
        let avail = self.core.pool.len() - self.core.measured_set.len();
        if !self.in_gate
            && deficit > 0
            && avail > 0
            && self.sub_rounds < self.core.policy.substitute_rounds
        {
            self.sub_rounds += 1;
            let k = deficit.min(avail);
            let picks = random_unmeasured(
                self.core.pool,
                &self.core.measured_set,
                k,
                &mut self.core.sel_rng,
            );
            for &i in &picks {
                self.core.measured_set.insert(i);
            }
            return self.issue(picks.into_iter().map(|i| (i, 0)).collect());
        }
        // sampling settled: give flagged readings their re-measure
        let flagged = self.core.outlier_remeasure_picks();
        if !flagged.is_empty() {
            self.in_gate = true;
            return self.issue(flagged.into_iter().map(|i| (i, 0)).collect());
        }
        self.done = true;
        MeasurementBatch::empty()
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        let pending = std::mem::take(&mut self.pending);
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        let core = &mut self.core;
        let (ok, retry) = triage_results(pending, results, max_retries, |&i, att| {
            core.charge_failed_workflow(i, att)
        });
        for (i, y) in ok {
            if self.in_gate {
                self.core.replace_workflow(i, y);
            } else {
                self.core.record_workflow(i, y);
                self.got += 1;
            }
        }
        self.retry = retry;
        // fault-free fast path: a fully answered main batch completes
        // the session right here, as the pre-failure-aware code did
        if !self.in_gate
            && self.retry.is_empty()
            && self.got >= self.m
            && !self.core.policy.outlier_gate
        {
            self.done = true;
        }
    }

    fn state(&self) -> SessionState {
        let phase = if self.done { "done" } else { "sample" };
        self.core.state(phase, self.done, None)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        assert!(self.done, "finish() before the session completed");
        let mut core = self.core;
        let rows = core.train_measured();
        let model = if rows.is_empty() {
            // every measurement attempt failed: no data, constant model
            Ensemble::constant(1, 0.0)
        } else {
            core.fit_hifi(&rows)
        };
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;
    use crate::tuner::session::FailureKind;

    #[test]
    fn uses_exact_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 1);
        let mut rng = Pcg32::new(2, 2);
        let out = RandomSampling.run(&prob, &pool, &Scorer::Native, 25, &mut rng);
        assert_eq!(out.workflow_runs, 25);
        assert_eq!(out.measured.len(), 25);
        assert!(out.collection_cost > 0.0);
        assert!(out.best_idx < pool.len());
        // distinct samples
        let set: std::collections::HashSet<usize> =
            out.measured.iter().map(|&(i, _)| i).collect();
        assert_eq!(set.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::new(WorkflowId::HS, Objective::CompTime);
        let pool = Pool::generate(&prob, 80, 3);
        let run = |seed: u64| {
            let mut rng = Pcg32::new(seed, 0);
            RandomSampling
                .run(&prob, &pool, &Scorer::Native, 20, &mut rng)
                .best_idx
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn session_state_reports_progress() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 40, 4);
        let mut rng = Pcg32::new(6, 6);
        let mut session = RandomSampling.session(&prob, &pool, &Scorer::Native, 10, &mut rng);
        assert_eq!(session.state().phase, "sample");
        assert!(!session.state().done);
        let batch = session.ask();
        assert_eq!(batch.len(), 10);
        let results: Vec<MeasurementResult> = (0..10)
            .map(|k| MeasurementResult::ok(1.0 + k as f64))
            .collect();
        session.tell(&results);
        let st = session.state();
        assert!(st.done);
        assert_eq!(st.workflow_runs, 10);
        assert!((st.collection_cost - (10.0 + 45.0)).abs() < 1e-12);
        assert!(session.ask().is_empty());
        let out = session.finish();
        assert_eq!(out.workflow_runs, 10);
    }

    #[test]
    fn retries_then_substitutes_lost_picks() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 60, 9);
        let mut rng = Pcg32::new(3, 3);
        let mut session = RandomSampling.session(&prob, &pool, &Scorer::Native, 6, &mut rng);
        session.set_failure_policy(FailurePolicy {
            max_retries: 1,
            ..FailurePolicy::default()
        });

        // main batch: fail the last two picks
        let batch = session.ask();
        assert_eq!(batch.len(), 6);
        let mut results: Vec<MeasurementResult> = (0..4).map(|_| MeasurementResult::ok(2.0)).collect();
        results.push(MeasurementResult::failed(FailureKind::Crash));
        results.push(MeasurementResult::timed_out());
        session.tell(&results);
        assert_eq!(session.state().failed_runs, 2);
        assert!(!session.state().done);

        // retry batch re-asks exactly the two failures; fail one again
        let retry = session.ask();
        assert_eq!(retry.len(), 2);
        session.tell(&[
            MeasurementResult::ok(2.5),
            MeasurementResult::failed(FailureKind::Transport),
        ]);

        // the exhausted pick is substituted with a fresh random one
        let sub = session.ask();
        assert_eq!(sub.len(), 1);
        session.tell(&[MeasurementResult::ok(3.0)]);

        assert!(session.ask().is_empty());
        let st = session.state();
        assert!(st.done);
        assert_eq!(st.workflow_runs, 6);
        assert_eq!(st.failed_runs, 3);
        // failure charges landed in the budget accounting
        assert!(st.collection_cost > 6.0 * 2.0);
        let out = session.finish();
        assert_eq!(out.measured.len(), 6);
        assert_eq!(out.failed_runs, 3);
    }
}
