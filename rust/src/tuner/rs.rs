//! RS — random-sampling baseline (§7.3): spend the whole budget on
//! uniformly random pool configurations, train once, search.

use std::collections::HashSet;

use super::common::{
    random_unmeasured, searcher_best, train_hifi, Collector, Pool, Problem, Tuner, TunerOutput,
};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct RandomSampling;

impl Tuner for RandomSampling {
    fn name(&self) -> &'static str {
        "RS"
    }

    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");
        let measured_set = HashSet::new();
        let picks = random_unmeasured(pool, &measured_set, m.min(pool.len()), &mut sel_rng);
        let measured: Vec<(usize, f64)> = picks
            .into_iter()
            .map(|i| (i, col.measure(&pool.configs[i])))
            .collect();
        let model = train_hifi(prob, pool, &measured);
        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn uses_exact_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 1);
        let mut rng = Pcg32::new(2, 2);
        let out = RandomSampling.run(&prob, &pool, &Scorer::Native, 25, &mut rng);
        assert_eq!(out.workflow_runs, 25);
        assert_eq!(out.measured.len(), 25);
        assert!(out.collection_cost > 0.0);
        assert!(out.best_idx < pool.len());
        // distinct samples
        let set: std::collections::HashSet<usize> =
            out.measured.iter().map(|&(i, _)| i).collect();
        assert_eq!(set.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::new(WorkflowId::HS, Objective::CompTime);
        let pool = Pool::generate(&prob, 80, 3);
        let run = |seed: u64| {
            let mut rng = Pcg32::new(seed, 0);
            RandomSampling
                .run(&prob, &pool, &Scorer::Native, 20, &mut rng)
                .best_idx
        };
        assert_eq!(run(5), run(5));
    }
}
