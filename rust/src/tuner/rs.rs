//! RS — random-sampling baseline (§7.3): spend the whole budget on
//! uniformly random pool configurations, train once, search.
//!
//! Session shape: one sequential batch of `m` random picks, then done.

use super::common::{
    random_unmeasured, searcher_best, train_hifi, Pool, Problem, Tuner, TunerOutput,
};
use super::session::{
    MeasurementBatch, MeasurementResult, SessionCore, SessionState, TunerSession,
};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct RandomSampling;

impl Tuner for RandomSampling {
    fn name(&self) -> &'static str {
        "RS"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        Box::new(RsSession {
            core: SessionCore::new(prob, pool, scorer, rng),
            m: m.min(pool.len()),
            pending: Vec::new(),
            done: false,
        })
    }
}

struct RsSession<'a> {
    core: SessionCore<'a>,
    m: usize,
    /// Pool indices of the in-flight batch (empty when none).
    pending: Vec<usize>,
    done: bool,
}

impl TunerSession for RsSession<'_> {
    fn name(&self) -> &'static str {
        "RS"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(self.pending.is_empty(), "ask() with results outstanding");
        if self.done {
            return MeasurementBatch::empty();
        }
        self.core.asked_batches += 1;
        let picks = random_unmeasured(
            self.core.pool,
            &self.core.measured_set,
            self.m,
            &mut self.core.sel_rng,
        );
        let reqs = self.core.take_workflow_picks(&picks);
        self.pending = picks;
        MeasurementBatch::sequential(reqs)
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        let picks = std::mem::take(&mut self.pending);
        assert_eq!(results.len(), picks.len(), "tell() arity mismatch");
        self.core.told_batches += 1;
        for (&i, r) in picks.iter().zip(results) {
            self.core.record_workflow(i, r.value);
        }
        self.done = true;
    }

    fn state(&self) -> SessionState {
        let phase = if self.done { "done" } else { "sample" };
        self.core.state(phase, self.done, None)
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        assert!(self.done, "finish() before the session completed");
        let core = self.core;
        let model = train_hifi(core.prob, core.pool, &core.measured);
        let best_idx = searcher_best(&model, core.pool, core.scorer, &core.measured);
        core.into_output(model, best_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn uses_exact_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 1);
        let mut rng = Pcg32::new(2, 2);
        let out = RandomSampling.run(&prob, &pool, &Scorer::Native, 25, &mut rng);
        assert_eq!(out.workflow_runs, 25);
        assert_eq!(out.measured.len(), 25);
        assert!(out.collection_cost > 0.0);
        assert!(out.best_idx < pool.len());
        // distinct samples
        let set: std::collections::HashSet<usize> =
            out.measured.iter().map(|&(i, _)| i).collect();
        assert_eq!(set.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::new(WorkflowId::HS, Objective::CompTime);
        let pool = Pool::generate(&prob, 80, 3);
        let run = |seed: u64| {
            let mut rng = Pcg32::new(seed, 0);
            RandomSampling
                .run(&prob, &pool, &Scorer::Native, 20, &mut rng)
                .best_idx
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn session_state_reports_progress() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 40, 4);
        let mut rng = Pcg32::new(6, 6);
        let mut session = RandomSampling.session(&prob, &pool, &Scorer::Native, 10, &mut rng);
        assert_eq!(session.state().phase, "sample");
        assert!(!session.state().done);
        let batch = session.ask();
        assert_eq!(batch.len(), 10);
        let results: Vec<MeasurementResult> = (0..10)
            .map(|k| MeasurementResult { value: 1.0 + k as f64 })
            .collect();
        session.tell(&results);
        let st = session.state();
        assert!(st.done);
        assert_eq!(st.workflow_runs, 10);
        assert!((st.collection_cost - (10.0 + 45.0)).abs() < 1e-12);
        assert!(session.ask().is_empty());
        let out = session.finish();
        assert_eq!(out.workflow_runs, 10);
    }
}
