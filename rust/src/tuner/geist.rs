//! GEIST — parameter-graph semi-supervised active learning (§7.3,
//! ref [26]): build a neighbor graph over the configuration pool,
//! propagate "likely top-5%" labels from measured configurations, and
//! spend each iteration's batch on the unmeasured nodes most likely to
//! be optimal (plus an exploration remainder).

use std::collections::HashSet;

use super::common::{
    random_unmeasured, searcher_best, train_hifi, Collector, Pool, Problem, Tuner, TunerOutput,
};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;
use crate::util::stats;

pub struct Geist {
    pub m0_frac: f64,
    pub iterations: usize,
    /// k of the k-NN parameter graph.
    pub knn: usize,
    /// Label-propagation damping (weight on neighborhood average).
    pub alpha: f64,
    /// Propagation sweeps per iteration.
    pub sweeps: usize,
    /// "Optimal" = within this top fraction of measured samples.
    pub top_frac: f64,
    /// Fraction of each batch spent on random exploration.
    pub explore_frac: f64,
}

impl Default for Geist {
    fn default() -> Self {
        Geist {
            m0_frac: 0.25,
            iterations: 6,
            knn: 10,
            alpha: 0.85,
            sweeps: 12,
            top_frac: 0.05,
            explore_frac: 0.2,
        }
    }
}

impl Geist {
    /// One label-propagation pass: measured nodes are clamped to their
    /// labels, unmeasured nodes relax toward their neighborhood mean.
    fn propagate(
        &self,
        pool: &Pool,
        labels: &[(usize, f64)], // (pool idx, 0/1 label)
    ) -> Vec<f64> {
        let graph = pool.knn_graph(self.knn);
        let n = pool.len();
        let mut clamped = vec![None; n];
        for &(i, l) in labels {
            clamped[i] = Some(l);
        }
        let prior = 0.0;
        let mut score: Vec<f64> = (0..n).map(|i| clamped[i].unwrap_or(prior)).collect();
        for _ in 0..self.sweeps {
            let mut next = score.clone();
            for i in 0..n {
                if let Some(l) = clamped[i] {
                    next[i] = l;
                    continue;
                }
                let nbrs = &graph[i];
                let avg = nbrs.iter().map(|&j| score[j]).sum::<f64>() / nbrs.len() as f64;
                next[i] = self.alpha * avg + (1.0 - self.alpha) * prior;
            }
            score = next;
        }
        score
    }
}

impl Tuner for Geist {
    fn name(&self) -> &'static str {
        "GEIST"
    }

    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");
        let m = m.min(pool.len());
        let m0 = ((m as f64 * self.m0_frac).round() as usize).clamp(1, m);
        let remaining = m - m0;
        let iters = self.iterations.min(remaining.max(1));
        let batch = if iters == 0 { 0 } else { remaining / iters };

        let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
        for i in random_unmeasured(pool, &measured_set, m0, &mut sel_rng) {
            measured.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }

        for _ in 0..iters {
            if batch == 0 {
                break;
            }
            // label measured configs: 1 if within the top fraction
            let ys: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
            let k_top = ((ys.len() as f64 * self.top_frac).ceil() as usize).max(1);
            let top_idx: HashSet<usize> = stats::bottom_k_indices(&ys, k_top)
                .into_iter()
                .map(|r| measured[r].0)
                .collect();
            let labels: Vec<(usize, f64)> = measured
                .iter()
                .map(|&(i, _)| (i, if top_idx.contains(&i) { 1.0 } else { 0.0 }))
                .collect();
            let prob_optimal = self.propagate(pool, &labels);

            let n_explore = ((batch as f64 * self.explore_frac).round() as usize).min(batch);
            let n_exploit = batch - n_explore;
            // highest probability-of-optimal first (maximize)
            let neg: Vec<f64> = prob_optimal.iter().map(|&s| -s).collect();
            for i in super::common::top_unmeasured(&neg, &measured_set, n_exploit) {
                measured.push((i, col.measure(&pool.configs[i])));
                measured_set.insert(i);
            }
            if n_explore > 0 {
                for i in random_unmeasured(pool, &measured_set, n_explore, &mut sel_rng) {
                    measured.push((i, col.measure(&pool.configs[i])));
                    measured_set.insert(i);
                }
            }
        }

        let model = train_hifi(prob, pool, &measured);
        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn propagation_spreads_from_labels() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 21);
        let g = Geist::default();
        // label the true best as 1, a bad one as 0
        let worst = stats::argmax(&pool.truth).unwrap();
        let labels = vec![(pool.best_idx, 1.0), (worst, 0.0)];
        let scores = g.propagate(&pool, &labels);
        assert_eq!(scores[pool.best_idx], 1.0);
        // neighbors of the best should score higher than neighbors of the worst
        let graph = pool.knn_graph(g.knn);
        let gb = &graph[pool.best_idx];
        let gw = &graph[worst];
        let avg_b: f64 = gb.iter().map(|&i| scores[i]).sum::<f64>() / gb.len() as f64;
        let avg_w: f64 = gw.iter().map(|&i| scores[i]).sum::<f64>() / gw.len() as f64;
        assert!(avg_b > avg_w, "{avg_b} vs {avg_w}");
    }

    #[test]
    fn runs_within_budget() {
        let prob = Problem::new(WorkflowId::HS, Objective::ExecTime);
        let pool = Pool::generate(&prob, 150, 22);
        let mut rng = Pcg32::new(6, 6);
        let out = Geist::default().run(&prob, &pool, &Scorer::Native, 30, &mut rng);
        assert!(out.workflow_runs <= 30);
        assert!(out.workflow_runs >= 24);
        let set: HashSet<usize> = out.measured.iter().map(|&(i, _)| i).collect();
        assert_eq!(set.len(), out.measured.len(), "no duplicate measurements");
    }
}
