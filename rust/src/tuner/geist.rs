//! GEIST — parameter-graph semi-supervised active learning (§7.3,
//! ref [26]): build a neighbor graph over the configuration pool,
//! propagate "likely top-5%" labels from measured configurations, and
//! spend each iteration's batch on the unmeasured nodes most likely to
//! be optimal (plus an exploration remainder).
//!
//! Session shape: one sequential bootstrap batch, then one sequential
//! batch per iteration combining the exploit picks (label propagation)
//! and the exploration remainder; the surrogate trains once at
//! `finish`, exactly like the monolithic loop did.

use std::collections::HashSet;

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, Pool, Problem, Tuner,
    TunerOutput,
};
use super::session::{
    triage_results, FailurePolicy, MeasurementBatch, MeasurementResult, SessionCore,
    SessionDigest, SessionState, TunerSession,
};
use crate::gbt::Ensemble;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;
use crate::util::stats;

pub struct Geist {
    pub m0_frac: f64,
    pub iterations: usize,
    /// k of the k-NN parameter graph.
    pub knn: usize,
    /// Label-propagation damping (weight on neighborhood average).
    pub alpha: f64,
    /// Propagation sweeps per iteration.
    pub sweeps: usize,
    /// "Optimal" = within this top fraction of measured samples.
    pub top_frac: f64,
    /// Fraction of each batch spent on random exploration.
    pub explore_frac: f64,
}

impl Default for Geist {
    fn default() -> Self {
        Geist {
            m0_frac: 0.25,
            iterations: 6,
            knn: 10,
            alpha: 0.85,
            sweeps: 12,
            top_frac: 0.05,
            explore_frac: 0.2,
        }
    }
}

impl Geist {
    /// One label-propagation pass: measured nodes are clamped to their
    /// labels, unmeasured nodes relax toward their neighborhood mean.
    /// (Crate-visible so the frozen [`super::legacy`] reference path
    /// shares the exact propagation arithmetic.)
    pub(crate) fn propagate(
        &self,
        pool: &Pool,
        labels: &[(usize, f64)], // (pool idx, 0/1 label)
    ) -> Vec<f64> {
        let graph = pool.knn_graph(self.knn);
        let n = pool.len();
        let mut clamped = vec![None; n];
        for &(i, l) in labels {
            clamped[i] = Some(l);
        }
        let prior = 0.0;
        let mut score: Vec<f64> = (0..n).map(|i| clamped[i].unwrap_or(prior)).collect();
        for _ in 0..self.sweeps {
            let mut next = score.clone();
            for i in 0..n {
                if let Some(l) = clamped[i] {
                    next[i] = l;
                    continue;
                }
                let nbrs = &graph[i];
                let avg = nbrs.iter().map(|&j| score[j]).sum::<f64>() / nbrs.len() as f64;
                next[i] = self.alpha * avg + (1.0 - self.alpha) * prior;
            }
            score = next;
        }
        score
    }
}

impl Tuner for Geist {
    fn name(&self) -> &'static str {
        "GEIST"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        let m = m.min(pool.len());
        let m0 = ((m as f64 * self.m0_frac).round() as usize).clamp(1, m);
        let remaining = m - m0;
        let iters = self.iterations.min(remaining.max(1));
        let batch = if iters == 0 { 0 } else { remaining / iters };
        Box::new(GeistSession {
            tuner: self,
            core: SessionCore::new(prob, pool, scorer, rng),
            m0,
            iters,
            batch,
            iter: 0,
            bootstrapped: false,
            pending: Vec::new(),
            retry: Vec::new(),
            in_gate: false,
            forced_done: false,
        })
    }
}

struct GeistSession<'a> {
    tuner: &'a Geist,
    core: SessionCore<'a>,
    m0: usize,
    iters: usize,
    batch: usize,
    iter: usize,
    bootstrapped: bool,
    /// In-flight (pool index, attempt) pairs.
    pending: Vec<(usize, usize)>,
    /// Failed picks with attempt budget left, re-asked next batch.
    retry: Vec<(usize, usize)>,
    /// True while the in-flight batch re-measures gate-flagged points.
    in_gate: bool,
    /// Set when the pool runs dry before the iteration budget does.
    forced_done: bool,
}

impl GeistSession<'_> {
    fn done(&self) -> bool {
        self.forced_done || (self.bootstrapped && (self.batch == 0 || self.iter >= self.iters))
    }

    fn issue(&mut self, picks: Vec<(usize, usize)>) -> MeasurementBatch {
        self.core.asked_batches += 1;
        let reqs = picks
            .iter()
            .map(|&(i, _)| self.core.workflow_request(i))
            .collect();
        self.pending = picks;
        MeasurementBatch::sequential(reqs)
    }

    /// The logical batch is fully resolved: advance the iteration
    /// (GEIST trains only at `finish`, so there is nothing to refit).
    fn close_batch(&mut self) {
        if self.bootstrapped {
            self.iter += 1;
        } else {
            self.bootstrapped = true;
        }
    }

    /// One iteration's picks: exploit (label propagation over the k-NN
    /// graph) then explore (uniform over the unmeasured remainder) —
    /// the exploit picks join the measured set before the exploration
    /// draw, exactly as the monolithic loop interleaved them.
    fn iteration_picks(&mut self) -> Vec<usize> {
        let t = self.tuner;
        let pool = self.core.pool;
        // label measured configs: 1 if within the top fraction
        let ys: Vec<f64> = self.core.measured.iter().map(|&(_, y)| y).collect();
        let k_top = ((ys.len() as f64 * t.top_frac).ceil() as usize).max(1);
        let top_idx: HashSet<usize> = stats::bottom_k_indices(&ys, k_top)
            .into_iter()
            .map(|r| self.core.measured[r].0)
            .collect();
        let labels: Vec<(usize, f64)> = self
            .core
            .measured
            .iter()
            .map(|&(i, _)| (i, if top_idx.contains(&i) { 1.0 } else { 0.0 }))
            .collect();
        let prob_optimal = t.propagate(pool, &labels);

        let n_explore = ((self.batch as f64 * t.explore_frac).round() as usize).min(self.batch);
        let n_exploit = self.batch - n_explore;
        // highest probability-of-optimal first (maximize)
        let neg: Vec<f64> = prob_optimal.iter().map(|&s| -s).collect();
        let mut picks = top_unmeasured(&neg, &self.core.measured_set, n_exploit);
        for &i in &picks {
            self.core.measured_set.insert(i);
        }
        if n_explore > 0 {
            let avail = pool.len() - self.core.measured_set.len();
            picks.extend(random_unmeasured(
                pool,
                &self.core.measured_set,
                n_explore.min(avail),
                &mut self.core.sel_rng,
            ));
        }
        picks
    }
}

impl TunerSession for GeistSession<'_> {
    fn name(&self) -> &'static str {
        "GEIST"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(self.pending.is_empty(), "ask() with results outstanding");
        if !self.retry.is_empty() {
            let retry = std::mem::take(&mut self.retry);
            return self.issue(retry);
        }
        if self.done() {
            return MeasurementBatch::empty();
        }
        self.in_gate = false;
        let picks = if !self.bootstrapped {
            let avail = self.core.pool.len() - self.core.measured_set.len();
            random_unmeasured(
                self.core.pool,
                &self.core.measured_set,
                self.m0.min(avail),
                &mut self.core.sel_rng,
            )
        } else {
            self.iteration_picks()
        };
        if picks.is_empty() {
            self.forced_done = true;
            return MeasurementBatch::empty();
        }
        for &i in &picks {
            self.core.measured_set.insert(i);
        }
        self.issue(picks.into_iter().map(|i| (i, 0)).collect())
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        let pending = std::mem::take(&mut self.pending);
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        let in_gate = self.in_gate;
        let core = &mut self.core;
        let (ok, retry) = triage_results(pending, results, max_retries, |&i, att| {
            core.charge_failed_workflow(i, att)
        });
        for (i, y) in ok {
            if in_gate {
                self.core.replace_workflow(i, y);
            } else {
                self.core.record_workflow(i, y);
            }
        }
        self.retry = retry;
        if !self.retry.is_empty() {
            return; // batch unresolved: re-ask the failures first
        }
        let flagged = self.core.outlier_remeasure_picks();
        if !flagged.is_empty() {
            // re-measure flagged readings before closing the iteration
            self.in_gate = true;
            self.retry = flagged.into_iter().map(|i| (i, 0)).collect();
            return;
        }
        if self.in_gate {
            self.in_gate = false;
        }
        self.close_batch();
    }

    fn state(&self) -> SessionState {
        let phase = if self.done() {
            "done"
        } else if !self.bootstrapped {
            "bootstrap"
        } else {
            "propagate"
        };
        self.core.state(phase, self.done(), None)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        assert!(self.done(), "finish() before the session completed");
        let mut core = self.core;
        let rows = core.train_measured();
        let model = if rows.is_empty() {
            // every measurement attempt failed: no data, constant model
            Ensemble::constant(1, 0.0)
        } else {
            core.fit_hifi(&rows)
        };
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn propagation_spreads_from_labels() {
        let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 21);
        let g = Geist::default();
        // label the true best as 1, a bad one as 0
        let worst = stats::argmax(pool.truth()).unwrap();
        let labels = vec![(pool.best_idx(), 1.0), (worst, 0.0)];
        let scores = g.propagate(&pool, &labels);
        assert_eq!(scores[pool.best_idx()], 1.0);
        // neighbors of the best should score higher than neighbors of the worst
        let graph = pool.knn_graph(g.knn);
        let gb = &graph[pool.best_idx()];
        let gw = &graph[worst];
        let avg_b: f64 = gb.iter().map(|&i| scores[i]).sum::<f64>() / gb.len() as f64;
        let avg_w: f64 = gw.iter().map(|&i| scores[i]).sum::<f64>() / gw.len() as f64;
        assert!(avg_b > avg_w, "{avg_b} vs {avg_w}");
    }

    #[test]
    fn runs_within_budget() {
        let prob = Problem::new(WorkflowId::HS, Objective::ExecTime);
        let pool = Pool::generate(&prob, 150, 22);
        let mut rng = Pcg32::new(6, 6);
        let out = Geist::default().run(&prob, &pool, &Scorer::Native, 30, &mut rng);
        assert!(out.workflow_runs <= 30);
        assert!(out.workflow_runs >= 24);
        let set: HashSet<usize> = out.measured.iter().map(|&(i, _)| i).collect();
        assert_eq!(set.len(), out.measured.len(), "no duplicate measurements");
    }
}
