//! CEAL — Component-based Ensemble Active Learning (paper Alg. 1).
//!
//! Phase 1 (lines 1-7): train per-component models on isolated
//! component runs (or free historical measurements) and combine them
//! with the objective's structure function (max/sum) into the
//! low-fidelity workflow model M_L.
//!
//! Phase 2 (lines 8-26): seed with m_0 random workflow runs, then
//! iterate: measure the batch, check whether the evolving high-fidelity
//! model M_H has overtaken M_L at ranking (top-1..3 recall sums on the
//! fresh batch — lines 16-21), train M_H on everything measured, and
//! pick the next batch as the best-scoring unmeasured pool configs
//! under whichever model currently wins.
//!
//! Session shape: one sequential batch of isolated component runs
//! (phase 1; absent with historical data), then one *fan-out* batch
//! per ensemble-active-learning iteration — the `C_meas` fan-out of
//! Alg. 1 line 15 survives the ask/tell split as a
//! [`BatchMode::FanOut`](super::session::BatchMode::FanOut) batch, so
//! evaluators can run the whole batch concurrently.

use std::sync::Arc;

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, top_unmeasured_model, Pool,
    Problem, Tuner, TunerOutput,
};
use super::session::{
    sample_component_requests, triage_results, DiagSink, FailurePolicy, MeasurementBatch,
    MeasurementRequest, MeasurementResult, SessionCore, SessionDigest, SessionState, TunerSession,
};
use crate::config::F_MAX;
use crate::gbt::{Ensemble, GbtParams};
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::{ComponentSamples, LowFiModel};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

/// CEAL hyper-parameters (paper §6 recommendations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CealParams {
    /// Ensemble-active-learning iterations I.
    pub iterations: usize,
    /// m_0 = m0_frac · m random bootstrap workflow runs.
    pub m0_frac: f64,
    /// m_R = mr_frac · m component-run budget (0 with history).
    pub mr_frac: f64,
}

impl CealParams {
    /// Without historical measurements: m_0 ≈ 10% m, m_R ≈ 35% m
    /// (inside the paper's stable 20-65% m_R plateau — §7.6 — and the
    /// best global compromise in our own Fig. 13-style sweeps).
    pub fn no_hist() -> CealParams {
        CealParams {
            iterations: 6,
            m0_frac: 0.10,
            mr_frac: 0.35,
        }
    }

    /// With historical measurements: m_R = 0, m_0 ≈ 25% m.
    pub fn with_hist() -> CealParams {
        CealParams {
            iterations: 6,
            m0_frac: 0.25,
            mr_frac: 0.0,
        }
    }
}

/// The CEAL tuner. `historical` carries pre-existing component
/// measurements D_hist (Alg. 1 line 4); when present they are free
/// (not charged against the budget or the collection cost).
pub struct Ceal {
    pub params: CealParams,
    pub historical: Option<Arc<Vec<ComponentSamples>>>,
    /// Component models trained purely from historical data are
    /// identical across repetitions — cache them per tuner instance
    /// (campaigns reuse one instance across reps). §Perf: this removes
    /// ~150 ms of redundant GBT training per repetition.
    cached_hist_models: std::sync::OnceLock<Vec<Ensemble>>,
}

impl Ceal {
    pub fn new(params: CealParams) -> Ceal {
        Ceal {
            params,
            historical: None,
            cached_hist_models: std::sync::OnceLock::new(),
        }
    }

    pub fn with_historical(params: CealParams, hist: Arc<Vec<ComponentSamples>>) -> Ceal {
        Ceal {
            params,
            historical: Some(hist),
            cached_hist_models: std::sync::OnceLock::new(),
        }
    }
}

/// Pick GBT hyper-parameters by training-set size.
pub fn gbt_params_for(n: usize) -> GbtParams {
    if n >= 200 {
        GbtParams::default()
    } else {
        GbtParams::small_data()
    }
}

impl Tuner for Ceal {
    fn name(&self) -> &'static str {
        "CEAL"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        let p = self.params;
        let m = m.min(pool.len());
        // budget split (line 9): m_R charged only when collecting fresh
        // component data
        let m_r = if self.historical.is_some() {
            0
        } else {
            (m as f64 * p.mr_frac).round() as usize
        };
        let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
        let remaining = m.saturating_sub(m0 + m_r);
        let iters = p.iterations.clamp(1, remaining.max(1));
        let m_b = (remaining / iters).max(1);
        Box::new(CealSession {
            tuner: self,
            core: SessionCore::new(prob, pool, scorer, rng),
            m_r,
            m0,
            iters,
            m_b,
            samples: Vec::new(),
            lowfi_scores: Vec::new(),
            using_hifi: false,
            hifi: None,
            actual: Vec::new(),
            xs_meas: Vec::new(),
            pred_l: Vec::new(),
            c_meas: Vec::new(),
            iter: 0,
            phase: Phase::Components,
            pending: Pending::None,
            comps_sampled: false,
            comp_retry: Vec::new(),
            batch_retry: Vec::new(),
            gate_q: Vec::new(),
            round_ok: Vec::new(),
        })
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Isolated component runs (Alg. 1 lines 1-6); skipped when m_R=0.
    Components,
    /// Ensemble active learning (lines 8-26).
    Workflow,
    Done,
}

/// An in-flight isolated component run: where its reading lands
/// (`slot`, `x`), the request itself (kept so a retry re-issues it
/// verbatim), and the attempt counter.
struct CompAttempt {
    slot: usize,
    x: [f32; F_MAX],
    req: MeasurementRequest,
}

enum Pending {
    None,
    Components(Vec<(CompAttempt, usize)>),
    /// (pool index, attempt) of the in-flight `C_meas` fan-out.
    Batch(Vec<(usize, usize)>),
    /// Outlier-gate re-measures (sequential).
    Gate(Vec<(usize, usize)>),
}

struct CealSession<'a> {
    tuner: &'a Ceal,
    core: SessionCore<'a>,
    m_r: usize,
    m0: usize,
    iters: usize,
    m_b: usize,
    /// Fresh component samples (merged with history at initialization).
    samples: Vec<ComponentSamples>,
    /// M_L's pool scores; empty until phase 1 closes.
    lowfi_scores: Vec<f64>,
    using_hifi: bool,
    hifi: Option<Ensemble>,
    /// Switch-detection state, extended incrementally with each fresh
    /// batch instead of re-gathered over all measured rows every
    /// iteration (M_L's scores are fixed; only M_H's predictions must
    /// be recomputed — the model retrains).
    actual: Vec<f64>,
    xs_meas: Vec<[f32; F_MAX]>,
    pred_l: Vec<f64>,
    c_meas: Vec<usize>,
    iter: usize,
    phase: Phase,
    pending: Pending,
    /// Phase-1 requests were drawn (they are drawn once; retries must
    /// not re-sample the component spaces).
    comps_sampled: bool,
    comp_retry: Vec<(CompAttempt, usize)>,
    batch_retry: Vec<(usize, usize)>,
    /// Outlier re-measures queued for the next sequential batch.
    gate_q: Vec<(usize, usize)>,
    /// Delivered readings of the in-flight round, in told order.
    round_ok: Vec<(usize, f64)>,
}

impl CealSession<'_> {
    /// Phase-1 sampling (lines 1-6): one sequential batch of isolated
    /// component runs via the shared
    /// [`sample_component_requests`] protocol.
    fn sample_components(&mut self) -> Vec<MeasurementRequest> {
        self.comps_sampled = true;
        let mut slots = Vec::new();
        let reqs = sample_component_requests(
            &mut self.core,
            self.tuner.historical.as_ref(),
            self.m_r,
            &mut self.samples,
            &mut slots,
        );
        self.pending = if reqs.is_empty() {
            Pending::None
        } else {
            Pending::Components(
                slots
                    .into_iter()
                    .zip(&reqs)
                    .map(|((slot, x), req)| (CompAttempt { slot, x, req: req.clone() }, 0))
                    .collect(),
            )
        };
        reqs
    }

    /// Close phase 1: fit the component models, combine into M_L,
    /// score the pool, and select the first `C_meas` (lines 7-11).
    fn open_workflow_phase(&mut self) {
        let prob = self.core.prob;
        let n_feats = prob.n_component_features();
        let fit = |samples: &[ComponentSamples]| {
            let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
            LowFiModel::fit(samples, &n_feats, prob.objective, &comp_params).comps
        };
        // Pure-history models are deterministic: train once per tuner.
        let comps = if self.m_r == 0 && self.tuner.historical.is_some() {
            self.tuner
                .cached_hist_models
                .get_or_init(|| fit(self.tuner.historical.as_ref().unwrap()))
                .clone()
        } else {
            fit(&self.samples)
        };
        let lowfi = LowFiModel {
            comps,
            objective: prob.objective,
        };
        self.lowfi_scores = lowfi.score(&self.core.pool.feats, self.core.scorer);
        self.core.refit();

        // line 8: m_0 random
        let mut c_meas = random_unmeasured(
            self.core.pool,
            &self.core.measured_set,
            self.m0,
            &mut self.core.sel_rng,
        );
        for &i in &c_meas {
            self.core.measured_set.insert(i);
        }
        // line 11: top m_B by M_L
        for i in top_unmeasured(&self.lowfi_scores, &self.core.measured_set, self.m_b) {
            c_meas.push(i);
            self.core.measured_set.insert(i);
        }
        self.c_meas = c_meas;
        self.phase = Phase::Workflow;
    }

    /// The `C_meas` round's deliveries are all in (line 15 happened,
    /// minus permanently lost picks): record them and run switch
    /// detection (lines 16-21).  Both models score everything measured
    /// so far *including* the fresh batch (which is out-of-sample for
    /// the current M_H) — a fresh m_B-sized batch alone is too small
    /// for stable top-1..3 recalls at the paper's budgets.
    fn record_round(&mut self) {
        let (pool, scorer) = (self.core.pool, self.core.scorer);
        let round = std::mem::take(&mut self.round_ok);
        for &(i, y) in &round {
            self.core.record_workflow(i, y);
        }
        if !self.using_hifi {
            for &(i, y) in &round {
                self.actual.push(y);
                self.xs_meas.push(pool.feats.workflow[i]);
                self.pred_l.push(self.lowfi_scores[i]);
            }
            if let Some(h) = &self.hifi {
                if !self.xs_meas.is_empty() {
                    let pred_h = scorer.score(h, &self.xs_meas);
                    let s_h = recall_sum_123(&pred_h, &self.actual);
                    let s_l = recall_sum_123(&self.pred_l, &self.actual);
                    if s_h >= s_l {
                        self.using_hifi = true;
                    }
                }
            }
        }
    }

    /// The round (and any outlier re-measures) is fully resolved:
    /// train M_H (line 22), advance the iteration, and select the next
    /// `C_meas` (lines 23-24).  M_L's pool scores are borrowed, not
    /// cloned, per iteration.
    fn close_round(&mut self) {
        let (pool, scorer) = (self.core.pool, self.core.scorer);
        let rows = self.core.train_measured();
        if !rows.is_empty() {
            self.hifi = Some(self.core.fit_hifi(&rows));
        }
        self.core.refit();
        self.iter += 1;
        if self.iter < self.iters {
            // Hifi selection fuses score-and-select (no O(pool) score
            // vector); the lowfi scores were materialized once at phase
            // open and are reused per iteration, as before.
            self.c_meas = match (self.using_hifi, self.hifi.as_ref()) {
                (true, Some(h)) => {
                    top_unmeasured_model(h, pool, scorer, &self.core.measured_set, self.m_b)
                }
                _ => top_unmeasured(&self.lowfi_scores, &self.core.measured_set, self.m_b),
            };
            for &i in &self.c_meas {
                self.core.measured_set.insert(i);
            }
        } else {
            self.phase = Phase::Done;
        }
    }

    /// Queue the outlier gate's re-measures if any reading is flagged;
    /// otherwise close the round.
    fn gate_or_close(&mut self) {
        let flagged = self.core.outlier_remeasure_picks();
        if flagged.is_empty() {
            self.close_round();
        } else {
            self.gate_q = flagged.into_iter().map(|i| (i, 0)).collect();
        }
    }
}

impl TunerSession for CealSession<'_> {
    fn name(&self) -> &'static str {
        "CEAL"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(
            matches!(self.pending, Pending::None),
            "ask() with results outstanding"
        );
        if self.phase == Phase::Components {
            if !self.comps_sampled {
                let reqs = self.sample_components();
                if reqs.is_empty() {
                    // m_R = 0 (or every component space infeasible): no
                    // isolated runs to charge — straight to phase 2.
                    self.open_workflow_phase();
                } else {
                    self.core.asked_batches += 1;
                    return MeasurementBatch::sequential(reqs);
                }
            } else if !self.comp_retry.is_empty() {
                // failed isolated runs with attempt budget left
                let retry = std::mem::take(&mut self.comp_retry);
                self.core.asked_batches += 1;
                let reqs = retry.iter().map(|(a, _)| a.req.clone()).collect();
                self.pending = Pending::Components(retry);
                return MeasurementBatch::sequential(reqs);
            } else {
                // defensive: tell() normally opens phase 2 itself
                self.open_workflow_phase();
            }
        }
        if !self.batch_retry.is_empty() {
            let retry = std::mem::take(&mut self.batch_retry);
            self.core.asked_batches += 1;
            let reqs = retry
                .iter()
                .map(|&(i, _)| self.core.workflow_request(i))
                .collect();
            self.pending = Pending::Batch(retry);
            return MeasurementBatch::fan_out(reqs);
        }
        if !self.gate_q.is_empty() {
            let gate = std::mem::take(&mut self.gate_q);
            self.core.asked_batches += 1;
            let reqs = gate
                .iter()
                .map(|&(i, _)| self.core.workflow_request(i))
                .collect();
            self.pending = Pending::Gate(gate);
            return MeasurementBatch::sequential(reqs);
        }
        if self.phase == Phase::Done || self.c_meas.is_empty() {
            // an exhausted pool leaves nothing to select: the
            // monolithic loop idled through its remaining iterations
            // with empty batches (same output; retraining on unchanged
            // data is a fixed point), the session just stops
            self.phase = Phase::Done;
            return MeasurementBatch::empty();
        }
        // line 15: the C_meas fan-out
        self.core.asked_batches += 1;
        let picks: Vec<(usize, usize)> = std::mem::take(&mut self.c_meas)
            .into_iter()
            .map(|i| (i, 0))
            .collect();
        let reqs: Vec<MeasurementRequest> = picks
            .iter()
            .map(|&(i, _)| self.core.workflow_request(i))
            .collect();
        self.pending = Pending::Batch(picks);
        MeasurementBatch::fan_out(reqs)
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => panic!("tell() without an outstanding batch"),
            Pending::Components(attempts) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(attempts, results, max_retries, |_, att| {
                    core.charge_failed_component(att)
                });
                for (a, y) in ok {
                    self.samples[a.slot].push(a.x, y);
                    self.core.record_component(y);
                }
                self.comp_retry = retry;
                if self.comp_retry.is_empty() {
                    // phase 1 resolved (permanently lost runs are
                    // skipped: the component models train on less)
                    self.open_workflow_phase();
                }
            }
            Pending::Batch(idxs) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(idxs, results, max_retries, |&i, att| {
                    core.charge_failed_workflow(i, att)
                });
                self.round_ok.extend(ok);
                self.batch_retry = retry;
                if !self.batch_retry.is_empty() {
                    return; // round unresolved: re-ask the failures first
                }
                self.record_round();
                self.gate_or_close();
            }
            Pending::Gate(picks) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(picks, results, max_retries, |&i, att| {
                    core.charge_failed_workflow(i, att)
                });
                for (i, y) in ok {
                    self.core.replace_workflow(i, y);
                }
                self.gate_q = retry;
                if self.gate_q.is_empty() {
                    self.gate_or_close();
                }
            }
        }
    }

    fn state(&self) -> SessionState {
        let (phase, done) = match self.phase {
            Phase::Components => ("components", false),
            Phase::Workflow => ("refine", false),
            Phase::Done => ("done", true),
        };
        let using = if self.lowfi_scores.is_empty() {
            None
        } else {
            Some(self.using_hifi)
        };
        self.core.state(phase, done, using)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        // a total measurement blackout leaves no model: fall back to a
        // constant so the session still yields a valid output
        let model = self
            .hifi
            .unwrap_or_else(|| Ensemble::constant(1, 0.0));
        let core = self.core;
        let rows = core.train_measured();
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_diag_sink(&mut self, sink: DiagSink) {
        self.core.diag.set_sink(sink);
    }

    fn diagnostics(&self) -> &[String] {
        self.core.diag.captured()
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;
    use crate::tuner::Collector;

    fn problem() -> Problem {
        Problem::new(WorkflowId::LV, Objective::CompTime)
    }

    #[test]
    fn budget_accounting_without_history() {
        let prob = problem();
        let pool = Pool::generate(&prob, 200, 31);
        let mut rng = Pcg32::new(7, 7);
        let ceal = Ceal::new(CealParams::no_hist());
        let m = 50;
        let out = ceal.run(&prob, &pool, &Scorer::Native, m, &mut rng);
        // workflow runs = m0 + I*mB <= m - mR
        let m_r = (m as f64 * 0.35).round() as usize;
        assert!(
            out.workflow_runs <= m - m_r,
            "workflow runs {} exceed {}",
            out.workflow_runs,
            m - m_r
        );
        assert!(out.workflow_runs >= (m - m_r) / 2);
        assert!(out.collection_cost > 0.0);
    }

    #[test]
    fn with_history_spends_full_budget_on_workflow() {
        let prob = problem();
        let pool = Pool::generate(&prob, 200, 32);
        // fake historical component data from isolated runs
        let mut rng = Pcg32::new(8, 8);
        let mut hist = vec![ComponentSamples::default(), ComponentSamples::default()];
        let mut col = Collector::new(&prob, rng.derive_str("hist"));
        for (slot, &comp) in prob.sim.spec.configurable().iter().enumerate() {
            for _ in 0..100 {
                let cfg = prob.sim.spec.components[comp].sample(&mut rng);
                let y = col.measure_component(comp, &cfg);
                hist[slot].push(prob.sim.spec.components[comp].encode(&cfg), y);
            }
        }
        let ceal = Ceal::with_historical(CealParams::with_hist(), Arc::new(hist));
        let mut rng2 = Pcg32::new(9, 9);
        let out = ceal.run(&prob, &pool, &Scorer::Native, 25, &mut rng2);
        assert!(out.workflow_runs >= 20 && out.workflow_runs <= 25,
            "runs {}", out.workflow_runs);
    }

    #[test]
    fn beats_random_sampling_on_average() {
        // The headline behaviour: with the same small budget CEAL's
        // tuned configuration should on average beat RS's.
        let prob = problem();
        let pool = Pool::generate(&prob, 400, 33);
        let scorer = Scorer::Native;
        let reps = 8;
        let mut ceal_sum = 0.0;
        let mut rs_sum = 0.0;
        for rep in 0..reps {
            let mut r1 = Pcg32::new(100 + rep, 1);
            let mut r2 = Pcg32::new(100 + rep, 2);
            let c = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &scorer, 25, &mut r1);
            let r = super::super::rs::RandomSampling.run(&prob, &pool, &scorer, 25, &mut r2);
            ceal_sum += pool.truth_of(c.best_idx);
            rs_sum += pool.truth_of(r.best_idx);
        }
        assert!(
            ceal_sum < rs_sum,
            "CEAL mean {} should beat RS mean {}",
            ceal_sum / reps as f64,
            rs_sum / reps as f64
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = problem();
        let pool = Pool::generate(&prob, 150, 34);
        let run = |seed| {
            let mut rng = Pcg32::new(seed, 0);
            Ceal::new(CealParams::no_hist())
                .run(&prob, &pool, &Scorer::Native, 25, &mut rng)
                .best_idx
        };
        assert_eq!(run(3), run(3));
    }

    /// The session exposes CEAL's structure: a sequential component
    /// batch first, then fan-out C_meas batches, with the switch state
    /// visible through `state()`.
    #[test]
    fn session_phases_and_fan_out() {
        use super::super::session::{BatchMode, Evaluator};
        let prob = problem();
        let pool = Pool::generate(&prob, 150, 35);
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rng = Pcg32::new(11, 11);
        let mut session = tuner.session(&prob, &pool, &Scorer::Native, 30, &mut rng);
        let mut col = Collector::new(&prob, Pcg32::new(12, 12));
        assert_eq!(session.state().phase, "components");
        let first = session.ask();
        assert_eq!(first.mode, BatchMode::Sequential);
        assert!(first
            .requests
            .iter()
            .all(|r| matches!(r, MeasurementRequest::Component { .. })));
        session.tell(&col.evaluate(&first));
        assert_eq!(session.state().phase, "refine");
        assert_eq!(session.state().using_hifi, Some(false));
        loop {
            let batch = session.ask();
            if batch.is_empty() {
                break;
            }
            assert_eq!(batch.mode, BatchMode::FanOut);
            session.tell(&col.evaluate(&batch));
        }
        let st = session.state();
        assert!(st.done);
        assert!(st.component_runs > 0);
        assert!(st.workflow_runs > 0);
        let out = session.finish();
        assert!(out.best_idx < pool.len());
    }

    /// A failed pick inside the `C_meas` fan-out is re-asked (as a
    /// fan-out sub-batch) before the iteration advances and the model
    /// refits; the round closes on the combined deliveries.
    #[test]
    fn fan_out_failures_retry_before_the_round_closes() {
        use super::super::session::{BatchMode, Evaluator, FailureKind};
        let prob = problem();
        let pool = Pool::generate(&prob, 150, 37);
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rng = Pcg32::new(14, 14);
        let mut session = tuner.session(&prob, &pool, &Scorer::Native, 30, &mut rng);
        let mut col = Collector::new(&prob, Pcg32::new(15, 15));

        let comps = session.ask();
        session.tell(&col.evaluate(&comps));
        let refits_before = session.state().model_refits;

        // first C_meas round: fail the first pick
        let round = session.ask();
        assert_eq!(round.mode, BatchMode::FanOut);
        let mut results = col.evaluate(&round);
        results[0] = MeasurementResult::failed(FailureKind::Crash);
        session.tell(&results);
        assert_eq!(session.state().failed_runs, 1);
        // round unresolved: no refit yet, retry batch is a fan-out
        assert_eq!(session.state().model_refits, refits_before);
        let retry = session.ask();
        assert_eq!(retry.mode, BatchMode::FanOut);
        assert_eq!(retry.len(), 1);
        session.tell(&col.evaluate(&retry));
        // now the round closed: the iteration refit happened
        assert_eq!(session.state().model_refits, refits_before + 1);

        loop {
            let batch = session.ask();
            if batch.is_empty() {
                break;
            }
            session.tell(&col.evaluate(&batch));
        }
        let out = session.finish();
        assert_eq!(out.failed_runs, 1);
        assert!(out.best_idx < pool.len());
    }

    #[test]
    #[should_panic(expected = "results outstanding")]
    fn ask_twice_panics() {
        let prob = problem();
        let pool = Pool::generate(&prob, 60, 36);
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rng = Pcg32::new(13, 13);
        let mut session = tuner.session(&prob, &pool, &Scorer::Native, 15, &mut rng);
        let _ = session.ask();
        let _ = session.ask();
    }
}
