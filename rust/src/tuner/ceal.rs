//! CEAL — Component-based Ensemble Active Learning (paper Alg. 1).
//!
//! Phase 1 (lines 1-7): train per-component models on isolated
//! component runs (or free historical measurements) and combine them
//! with the objective's structure function (max/sum) into the
//! low-fidelity workflow model M_L.
//!
//! Phase 2 (lines 8-26): seed with m_0 random workflow runs, then
//! iterate: measure the batch, check whether the evolving high-fidelity
//! model M_H has overtaken M_L at ranking (top-1..3 recall sums on the
//! fresh batch — lines 16-21), train M_H on everything measured, and
//! pick the next batch as the best-scoring unmeasured pool configs
//! under whichever model currently wins.

use std::collections::HashSet;
use std::sync::Arc;

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Collector, Pool, Problem,
    Tuner, TunerOutput,
};
use crate::gbt::GbtParams;
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::{ComponentSamples, LowFiModel};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

/// CEAL hyper-parameters (paper §6 recommendations).
#[derive(Clone, Copy, Debug)]
pub struct CealParams {
    /// Ensemble-active-learning iterations I.
    pub iterations: usize,
    /// m_0 = m0_frac · m random bootstrap workflow runs.
    pub m0_frac: f64,
    /// m_R = mr_frac · m component-run budget (0 with history).
    pub mr_frac: f64,
}

impl CealParams {
    /// Without historical measurements: m_0 ≈ 10% m, m_R ≈ 35% m
    /// (inside the paper's stable 20-65% m_R plateau — §7.6 — and the
    /// best global compromise in our own Fig. 13-style sweeps).
    pub fn no_hist() -> CealParams {
        CealParams {
            iterations: 6,
            m0_frac: 0.10,
            mr_frac: 0.35,
        }
    }

    /// With historical measurements: m_R = 0, m_0 ≈ 25% m.
    pub fn with_hist() -> CealParams {
        CealParams {
            iterations: 6,
            m0_frac: 0.25,
            mr_frac: 0.0,
        }
    }
}

/// The CEAL tuner. `historical` carries pre-existing component
/// measurements D_hist (Alg. 1 line 4); when present they are free
/// (not charged against the budget or the collection cost).
pub struct Ceal {
    pub params: CealParams,
    pub historical: Option<Arc<Vec<ComponentSamples>>>,
    /// Component models trained purely from historical data are
    /// identical across repetitions — cache them per tuner instance
    /// (campaigns reuse one instance across reps). §Perf: this removes
    /// ~150 ms of redundant GBT training per repetition.
    cached_hist_models: std::sync::OnceLock<Vec<crate::gbt::Ensemble>>,
}

impl Ceal {
    pub fn new(params: CealParams) -> Ceal {
        Ceal {
            params,
            historical: None,
            cached_hist_models: std::sync::OnceLock::new(),
        }
    }

    pub fn with_historical(params: CealParams, hist: Arc<Vec<ComponentSamples>>) -> Ceal {
        Ceal {
            params,
            historical: Some(hist),
            cached_hist_models: std::sync::OnceLock::new(),
        }
    }

    /// Collect component samples (lines 1-6): m_r isolated runs of each
    /// configurable component on random configurations, merged with any
    /// historical data.
    fn component_samples(
        &self,
        prob: &Problem,
        m_r: usize,
        col: &mut Collector,
        rng: &mut Pcg32,
    ) -> Vec<ComponentSamples> {
        let spec = &prob.sim.spec;
        let configurable = spec.configurable();
        let mut out: Vec<ComponentSamples> = match &self.historical {
            Some(h) => {
                assert_eq!(h.len(), configurable.len(), "historical arity");
                h.iter().cloned().collect()
            }
            None => configurable.iter().map(|_| ComponentSamples::default()).collect(),
        };
        for (slot, &comp) in configurable.iter().enumerate() {
            let cs = &spec.components[comp];
            for _ in 0..m_r {
                // feasible on the same <=32-node allocations as the pool
                match col.measure_component_sampled(comp, rng) {
                    Ok((cfg, y)) => out[slot].push(cs.encode(&cfg), y),
                    Err(e) => {
                        // an over-tight component space: train on what
                        // we have (empty -> constant model) instead of
                        // aborting the campaign
                        eprintln!("warning: {e}; skipping its isolated runs");
                        break;
                    }
                }
            }
        }
        out
    }
}

/// Pick GBT hyper-parameters by training-set size.
pub fn gbt_params_for(n: usize) -> GbtParams {
    if n >= 200 {
        GbtParams::default()
    } else {
        GbtParams::small_data()
    }
}

impl Tuner for Ceal {
    fn name(&self) -> &'static str {
        "CEAL"
    }

    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");
        let p = self.params;
        let m = m.min(pool.len());

        // budget split (line 9): m_R charged only when collecting fresh
        // component data
        let m_r = if self.historical.is_some() {
            0
        } else {
            (m as f64 * p.mr_frac).round() as usize
        };
        let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
        let remaining = m.saturating_sub(m0 + m_r);
        let iters = p.iterations.clamp(1, remaining.max(1));
        let m_b = (remaining / iters).max(1);

        // Phase 1: component models -> low-fidelity M_L (lines 1-7).
        // Pure-history models are deterministic: train once per tuner.
        let n_feats = prob.n_component_features();
        let fit = |samples: &[ComponentSamples]| {
            let comp_params =
                gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
            LowFiModel::fit(samples, &n_feats, prob.objective, &comp_params).comps
        };
        let comps = if m_r == 0 && self.historical.is_some() {
            self.cached_hist_models
                .get_or_init(|| fit(self.historical.as_ref().unwrap()))
                .clone()
        } else {
            let samples = self.component_samples(prob, m_r, &mut col, &mut sel_rng);
            fit(&samples)
        };
        let lowfi = LowFiModel {
            comps,
            objective: prob.objective,
        };
        let lowfi_scores = lowfi.score(&pool.feats, scorer);

        // Phase 2 (lines 8-26)
        let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
        // line 8: m_0 random
        let mut c_meas = random_unmeasured(pool, &measured_set, m0, &mut sel_rng);
        for &i in &c_meas {
            measured_set.insert(i);
        }
        // line 11: top m_B by M_L
        for i in top_unmeasured(&lowfi_scores, &measured_set, m_b) {
            c_meas.push(i);
            measured_set.insert(i);
        }

        let mut using_hifi = false; // M = M_L (line 12)
        let mut hifi: Option<crate::gbt::Ensemble> = None; // line 13

        // Switch-detection state, extended incrementally with each
        // fresh batch instead of re-gathered over all measured rows
        // every iteration (M_L's scores are fixed; only M_H's
        // predictions must be recomputed — the model retrains).
        let mut actual: Vec<f64> = Vec::with_capacity(m);
        let mut xs_meas: Vec<[f32; crate::config::F_MAX]> = Vec::with_capacity(m);
        let mut pred_l: Vec<f64> = Vec::with_capacity(m);

        for iter in 0..iters {
            // line 15: run workflow for C_meas, fanned across the
            // worker pool (bit-identical for any worker count)
            let batch = col.measure_pool_batch(pool, &c_meas);
            measured.extend_from_slice(&batch);
            // lines 16-21: model switch detection.  We score both models
            // on everything measured so far *including* the fresh batch
            // (which is out-of-sample for the current M_H) — a fresh
            // m_B-sized batch alone is too small for stable top-1..3
            // recalls at the paper's budgets.
            if !using_hifi {
                for &(i, y) in &batch {
                    actual.push(y);
                    xs_meas.push(pool.feats.workflow[i]);
                    pred_l.push(lowfi_scores[i]);
                }
                if let Some(h) = &hifi {
                    let pred_h = scorer.score(h, &xs_meas);
                    let s_h = recall_sum_123(&pred_h, &actual);
                    let s_l = recall_sum_123(&pred_l, &actual);
                    if s_h >= s_l {
                        using_hifi = true;
                    }
                }
            }
            // line 22: train/refine M_H on everything measured
            hifi = Some(train_hifi(prob, pool, &measured));
            // lines 23-24: score pool with M, select next batch.  M_L's
            // pool scores are borrowed, not cloned, per iteration.
            if iter + 1 < iters {
                let hifi_scores;
                let scores: &[f64] = if using_hifi {
                    hifi_scores = scorer.score(hifi.as_ref().unwrap(), &pool.feats.workflow);
                    &hifi_scores
                } else {
                    &lowfi_scores
                };
                c_meas = top_unmeasured(scores, &measured_set, m_b);
                for &i in &c_meas {
                    measured_set.insert(i);
                }
            }
        }

        let model = hifi.expect("at least one iteration ran");
        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    fn problem() -> Problem {
        Problem::new(WorkflowId::LV, Objective::CompTime)
    }

    #[test]
    fn budget_accounting_without_history() {
        let prob = problem();
        let pool = Pool::generate(&prob, 200, 31);
        let mut rng = Pcg32::new(7, 7);
        let ceal = Ceal::new(CealParams::no_hist());
        let m = 50;
        let out = ceal.run(&prob, &pool, &Scorer::Native, m, &mut rng);
        // workflow runs = m0 + I*mB <= m - mR
        let m_r = (m as f64 * 0.35).round() as usize;
        assert!(
            out.workflow_runs <= m - m_r,
            "workflow runs {} exceed {}",
            out.workflow_runs,
            m - m_r
        );
        assert!(out.workflow_runs >= (m - m_r) / 2);
        assert!(out.collection_cost > 0.0);
    }

    #[test]
    fn with_history_spends_full_budget_on_workflow() {
        let prob = problem();
        let pool = Pool::generate(&prob, 200, 32);
        // fake historical component data from isolated runs
        let mut rng = Pcg32::new(8, 8);
        let mut hist = vec![ComponentSamples::default(), ComponentSamples::default()];
        let mut col = Collector::new(&prob, rng.derive_str("hist"));
        for (slot, &comp) in prob.sim.spec.configurable().iter().enumerate() {
            for _ in 0..100 {
                let cfg = prob.sim.spec.components[comp].sample(&mut rng);
                let y = col.measure_component(comp, &cfg);
                hist[slot].push(prob.sim.spec.components[comp].encode(&cfg), y);
            }
        }
        let ceal = Ceal::with_historical(CealParams::with_hist(), Arc::new(hist));
        let mut rng2 = Pcg32::new(9, 9);
        let out = ceal.run(&prob, &pool, &Scorer::Native, 25, &mut rng2);
        assert!(out.workflow_runs >= 20 && out.workflow_runs <= 25,
            "runs {}", out.workflow_runs);
    }

    #[test]
    fn beats_random_sampling_on_average() {
        // The headline behaviour: with the same small budget CEAL's
        // tuned configuration should on average beat RS's.
        let prob = problem();
        let pool = Pool::generate(&prob, 400, 33);
        let scorer = Scorer::Native;
        let reps = 8;
        let mut ceal_sum = 0.0;
        let mut rs_sum = 0.0;
        for rep in 0..reps {
            let mut r1 = Pcg32::new(100 + rep, 1);
            let mut r2 = Pcg32::new(100 + rep, 2);
            let c = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &scorer, 25, &mut r1);
            let r = super::super::rs::RandomSampling.run(&prob, &pool, &scorer, 25, &mut r2);
            ceal_sum += pool.truth[c.best_idx];
            rs_sum += pool.truth[r.best_idx];
        }
        assert!(
            ceal_sum < rs_sum,
            "CEAL mean {} should beat RS mean {}",
            ceal_sum / reps as f64,
            rs_sum / reps as f64
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = problem();
        let pool = Pool::generate(&prob, 150, 34);
        let run = |seed| {
            let mut rng = Pcg32::new(seed, 0);
            Ceal::new(CealParams::no_hist())
                .run(&prob, &pool, &Scorer::Native, 25, &mut rng)
                .best_idx
        };
        assert_eq!(run(3), run(3));
    }
}
