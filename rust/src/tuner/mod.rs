//! Auto-tuning algorithms: the paper's CEAL (Alg. 1) and its
//! comparison targets RS, AL, GEIST (§7.3) and ALpH (§4).
//!
//! All tuners share the collector/modeler/searcher structure of §2.1:
//! the *collector* runs the workflow simulator, the *modeler* trains
//! boosted-tree surrogates on the collected samples, and the *searcher*
//! picks the pool configuration with the best predicted objective.

pub mod al;
pub mod alph;
pub mod budgeted;
pub mod ceal;
pub mod common;
pub mod geist;
pub mod rs;

pub use al::ActiveLearning;
pub use alph::Alph;
pub use budgeted::{BudgetedCeal, BudgetedCealParams};
pub use ceal::{Ceal, CealParams};
pub use common::{Collector, Pool, Problem, Tuner, TunerOutput};
pub use geist::Geist;
pub use rs::RandomSampling;
