//! Auto-tuning algorithms: the paper's CEAL (Alg. 1) and its
//! comparison targets RS, AL, GEIST (§7.3) and ALpH (§4), plus the
//! §6 cost-budgeted CEAL adaptation.
//!
//! All tuners share the collector/modeler/searcher structure of §2.1:
//! the *collector* performs measurements, the *modeler* trains
//! boosted-tree surrogates on the collected samples, and the *searcher*
//! picks the pool configuration with the best predicted objective.
//!
//! Since the ask/tell redesign the collector is *pluggable*: every
//! algorithm is implemented as a stepwise [`TunerSession`]
//! (ask for a [`MeasurementBatch`], tell the results back) behind the
//! [`Evaluator`] boundary, of which the simulator-backed [`Collector`]
//! is one implementation and the record/replay [`trace`] evaluators
//! are another.  [`Tuner::run`] survives as the thin generic driver
//! [`drive`]`(session, Collector)`; the pre-redesign monolithic loops
//! are frozen in [`legacy`] and pinned bit-for-bit by
//! `tests/session_equivalence.rs`.

pub mod al;
pub mod alph;
pub mod budgeted;
pub mod ceal;
pub mod common;
pub mod faults;
pub mod geist;
pub mod journal;
pub mod legacy;
pub mod rs;
pub mod session;
pub mod trace;

pub use al::ActiveLearning;
pub use alph::Alph;
pub use budgeted::{BudgetedCeal, BudgetedCealParams};
pub use ceal::{Ceal, CealParams};
pub use common::{
    top_unmeasured, top_unmeasured_model, Collector, Pool, Problem, TopK, Tuner, TunerOutput,
    LAZY_POOL_MIN, POOL_SIZE,
};
pub use faults::{FaultInjector, FaultPlan, FaultSpec};
pub use geist::Geist;
pub use journal::{
    drive_checkpointed, load_checkpoint, replay_into, DeadlineEvaluator, Exchange,
    LoadedCheckpoint, SessionJournal, JOURNAL_FILE, JOURNAL_VERSION, SNAPSHOT_FILE,
};
pub use rs::RandomSampling;
pub use session::{
    drive, BatchMode, DiagSink, Evaluator, EvaluatorState, FailureKind, FailurePolicy,
    MeasurementBatch, MeasurementOutcome, MeasurementRequest, MeasurementResult, SessionDigest,
    SessionState, TunerSession,
};
pub use trace::{TraceError, TraceHeader, TraceRecorder, TraceReplayer, TRACE_VERSION};
