//! The frozen pre-session monolithic tuning loops — the documented
//! reference path for the ask/tell redesign, kept the same way
//! `gbt::train_exact` and the simulator's `build_pipeline`/`simulate`
//! reference paths are kept: `tests/session_equivalence.rs` pins every
//! algorithm's session port bit-for-bit against these bodies, and
//! `benches/tuners.rs` runs one side-by-side row to show the driver
//! adds no measurable overhead.
//!
//! Nothing in the production path calls into this module.  The bodies
//! are verbatim copies of the pre-redesign `Tuner::run`
//! implementations (including their `eprintln!` warnings — the session
//! ports route the same messages through the
//! [`DiagSink`](super::session::DiagSink) instead).

use std::collections::HashSet;

use crate::config::F_MAX;
use crate::gbt::Ensemble;
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::{ComponentSamples, LowFiModel};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

use super::alph::{combiner_features, Alph};
use super::budgeted::BudgetedCeal;
use super::ceal::{gbt_params_for, Ceal};
use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Collector, Pool, Problem,
    TunerOutput,
};
use super::{ActiveLearning, Geist};

/// RS reference: spend the whole budget on random configurations,
/// train once, search.
pub fn run_rs(
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    m: usize,
    rng: &mut Pcg32,
) -> TunerOutput {
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");
    let measured_set = HashSet::new();
    let picks = random_unmeasured(pool, &measured_set, m.min(pool.len()), &mut sel_rng);
    let measured: Vec<(usize, f64)> = picks
        .into_iter()
        .map(|i| (i, col.measure(&pool.configs[i])))
        .collect();
    let model = train_hifi(prob, pool, &measured);
    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}

/// AL reference: random bootstrap, then iterative best-predicted
/// batches.
pub fn run_al(
    t: &ActiveLearning,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    m: usize,
    rng: &mut Pcg32,
) -> TunerOutput {
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");
    let m = m.min(pool.len());
    let m0 = ((m as f64 * t.m0_frac).round() as usize).clamp(1, m);
    let remaining = m - m0;
    let iters = t.iterations.min(remaining.max(1));
    let batch = if iters == 0 { 0 } else { remaining / iters };

    let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
    let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
    for i in random_unmeasured(pool, &measured_set, m0, &mut sel_rng) {
        measured.push((i, col.measure(&pool.configs[i])));
        measured_set.insert(i);
    }

    let mut model = train_hifi(prob, pool, &measured);
    for _ in 0..iters {
        if batch == 0 {
            break;
        }
        let preds = scorer.score(&model, &pool.feats.workflow);
        for i in top_unmeasured(&preds, &measured_set, batch) {
            measured.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }
        model = train_hifi(prob, pool, &measured);
    }

    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}

/// GEIST reference: label propagation over the pool's k-NN parameter
/// graph, exploit + explore batches.
pub fn run_geist(
    t: &Geist,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    m: usize,
    rng: &mut Pcg32,
) -> TunerOutput {
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");
    let m = m.min(pool.len());
    let m0 = ((m as f64 * t.m0_frac).round() as usize).clamp(1, m);
    let remaining = m - m0;
    let iters = t.iterations.min(remaining.max(1));
    let batch = if iters == 0 { 0 } else { remaining / iters };

    let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
    let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
    for i in random_unmeasured(pool, &measured_set, m0, &mut sel_rng) {
        measured.push((i, col.measure(&pool.configs[i])));
        measured_set.insert(i);
    }

    for _ in 0..iters {
        if batch == 0 {
            break;
        }
        // label measured configs: 1 if within the top fraction
        let ys: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
        let k_top = ((ys.len() as f64 * t.top_frac).ceil() as usize).max(1);
        let top_idx: HashSet<usize> = crate::util::stats::bottom_k_indices(&ys, k_top)
            .into_iter()
            .map(|r| measured[r].0)
            .collect();
        let labels: Vec<(usize, f64)> = measured
            .iter()
            .map(|&(i, _)| (i, if top_idx.contains(&i) { 1.0 } else { 0.0 }))
            .collect();
        let prob_optimal = t.propagate(pool, &labels);

        let n_explore = ((batch as f64 * t.explore_frac).round() as usize).min(batch);
        let n_exploit = batch - n_explore;
        // highest probability-of-optimal first (maximize)
        let neg: Vec<f64> = prob_optimal.iter().map(|&s| -s).collect();
        for i in top_unmeasured(&neg, &measured_set, n_exploit) {
            measured.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }
        if n_explore > 0 {
            for i in random_unmeasured(pool, &measured_set, n_explore, &mut sel_rng) {
                measured.push((i, col.measure(&pool.configs[i])));
                measured_set.insert(i);
            }
        }
    }

    let model = train_hifi(prob, pool, &measured);
    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}

/// CEAL's phase-1 component collection (Alg. 1 lines 1-6), verbatim.
fn ceal_component_samples(
    t: &Ceal,
    prob: &Problem,
    m_r: usize,
    col: &mut Collector,
    rng: &mut Pcg32,
) -> Vec<ComponentSamples> {
    let spec = &prob.sim.spec;
    let configurable = spec.configurable();
    let mut out: Vec<ComponentSamples> = match &t.historical {
        Some(h) => {
            assert_eq!(h.len(), configurable.len(), "historical arity");
            h.iter().cloned().collect()
        }
        None => configurable
            .iter()
            .map(|_| ComponentSamples::default())
            .collect(),
    };
    for (slot, &comp) in configurable.iter().enumerate() {
        let cs = &spec.components[comp];
        for _ in 0..m_r {
            // feasible on the same <=32-node allocations as the pool
            match col.measure_component_sampled(comp, rng) {
                Ok((cfg, y)) => out[slot].push(cs.encode(&cfg), y),
                Err(e) => {
                    // an over-tight component space: train on what
                    // we have (empty -> constant model) instead of
                    // aborting the campaign
                    eprintln!("warning: {e}; skipping its isolated runs");
                    break;
                }
            }
        }
    }
    out
}

/// CEAL reference (paper Alg. 1): component models -> low-fidelity
/// M_L, then ensemble active learning with switch detection.
pub fn run_ceal(
    t: &Ceal,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    m: usize,
    rng: &mut Pcg32,
) -> TunerOutput {
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");
    let p = t.params;
    let m = m.min(pool.len());

    // budget split (line 9): m_R charged only when collecting fresh
    // component data
    let m_r = if t.historical.is_some() {
        0
    } else {
        (m as f64 * p.mr_frac).round() as usize
    };
    let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
    let remaining = m.saturating_sub(m0 + m_r);
    let iters = p.iterations.clamp(1, remaining.max(1));
    let m_b = (remaining / iters).max(1);

    // Phase 1: component models -> low-fidelity M_L (lines 1-7).
    // (The instance-level historical-model cache is a per-tuner
    // memoization of exactly this fit; recomputing it here is
    // result-identical.)
    let n_feats = prob.n_component_features();
    let fit = |samples: &[ComponentSamples]| {
        let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
        LowFiModel::fit(samples, &n_feats, prob.objective, &comp_params).comps
    };
    let comps = if m_r == 0 && t.historical.is_some() {
        fit(t.historical.as_ref().unwrap())
    } else {
        let samples = ceal_component_samples(t, prob, m_r, &mut col, &mut sel_rng);
        fit(&samples)
    };
    let lowfi = LowFiModel {
        comps,
        objective: prob.objective,
    };
    let lowfi_scores = lowfi.score(&pool.feats, scorer);

    // Phase 2 (lines 8-26)
    let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
    let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
    // line 8: m_0 random
    let mut c_meas = random_unmeasured(pool, &measured_set, m0, &mut sel_rng);
    for &i in &c_meas {
        measured_set.insert(i);
    }
    // line 11: top m_B by M_L
    for i in top_unmeasured(&lowfi_scores, &measured_set, m_b) {
        c_meas.push(i);
        measured_set.insert(i);
    }

    let mut using_hifi = false; // M = M_L (line 12)
    let mut hifi: Option<Ensemble> = None; // line 13

    let mut actual: Vec<f64> = Vec::with_capacity(m);
    let mut xs_meas: Vec<[f32; F_MAX]> = Vec::with_capacity(m);
    let mut pred_l: Vec<f64> = Vec::with_capacity(m);

    for iter in 0..iters {
        // line 15: run workflow for C_meas
        let batch = col.measure_pool_batch(pool, &c_meas);
        measured.extend_from_slice(&batch);
        // lines 16-21: model switch detection
        if !using_hifi {
            for &(i, y) in &batch {
                actual.push(y);
                xs_meas.push(pool.feats.workflow[i]);
                pred_l.push(lowfi_scores[i]);
            }
            if let Some(h) = &hifi {
                let pred_h = scorer.score(h, &xs_meas);
                let s_h = recall_sum_123(&pred_h, &actual);
                let s_l = recall_sum_123(&pred_l, &actual);
                if s_h >= s_l {
                    using_hifi = true;
                }
            }
        }
        // line 22: train/refine M_H on everything measured
        hifi = Some(train_hifi(prob, pool, &measured));
        // lines 23-24: score pool with M, select next batch
        if iter + 1 < iters {
            let hifi_scores;
            let scores: &[f64] = if using_hifi {
                hifi_scores = scorer.score(hifi.as_ref().unwrap(), &pool.feats.workflow);
                &hifi_scores
            } else {
                &lowfi_scores
            };
            c_meas = top_unmeasured(scores, &measured_set, m_b);
            for &i in &c_meas {
                measured_set.insert(i);
            }
        }
    }

    let model = hifi.expect("at least one iteration ran");
    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}

/// ALpH reference (§4): component models feed a *trained* combiner
/// M_0 instead of the structure function.
pub fn run_alph(
    t: &Alph,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    m: usize,
    rng: &mut Pcg32,
) -> TunerOutput {
    use crate::gbt::train_log;

    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");
    let p = t.params;
    let m = m.min(pool.len());

    let m_r = if t.historical.is_some() {
        0
    } else {
        (m as f64 * p.mr_frac).round() as usize
    };
    let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
    let remaining = m.saturating_sub(m0 + m_r);
    let iters = p.iterations.clamp(1, remaining.max(1));
    let m_b = (remaining / iters).max(1);

    // component models (same phase-1 as CEAL)
    let spec = &prob.sim.spec;
    let configurable = spec.configurable();
    let mut samples: Vec<ComponentSamples> = match &t.historical {
        Some(h) => h.iter().cloned().collect(),
        None => configurable
            .iter()
            .map(|_| ComponentSamples::default())
            .collect(),
    };
    for (slot, &comp) in configurable.iter().enumerate() {
        for _ in 0..m_r {
            match col.measure_component_sampled(comp, &mut sel_rng) {
                Ok((cfg, y)) => samples[slot].push(spec.components[comp].encode(&cfg), y),
                Err(e) => {
                    eprintln!("warning: {e}; skipping its isolated runs");
                    break;
                }
            }
        }
    }
    let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
    let n_feats = prob.n_component_features();
    let comp_models: Vec<Ensemble> = samples
        .iter()
        .zip(&n_feats)
        .map(|(s, &nf)| {
            if s.is_empty() {
                Ensemble::constant(nf.max(1), 0.0)
            } else {
                train_log(&s.xs, &s.y, nf.max(1), &comp_params)
            }
        })
        .collect();
    // per-component time predictions over the whole pool (fixed);
    // component models are log-space -> exponentiate
    let per_comp_preds: Vec<Vec<f64>> = comp_models
        .iter()
        .zip(&pool.feats.per_component)
        .map(|(e, xs)| scorer.score(e, xs).into_iter().map(f64::exp).collect())
        .collect();
    let n_j = per_comp_preds.len();

    // bootstrap: m0 random workflow runs train the combiner M_0
    let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
    let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
    let mut c_meas = random_unmeasured(pool, &measured_set, m0, &mut sel_rng);
    for &i in &c_meas {
        measured_set.insert(i);
    }

    let train_combiner = |measured: &[(usize, f64)]| -> Ensemble {
        let xs: Vec<[f32; F_MAX]> = measured
            .iter()
            .map(|&(i, _)| combiner_features(&per_comp_preds, i))
            .collect();
        let y: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
        train_log(&xs, &y, n_j.max(1), &gbt_params_for(y.len()))
    };

    let mut using_hifi = false;
    let mut hifi: Option<Ensemble> = None;
    let mut combiner: Option<Ensemble> = None;

    for iter in 0..iters {
        let batch = col.measure_pool_batch(pool, &c_meas);
        // switch detection, mirroring CEAL (fresh batch only)
        if !using_hifi {
            if let (Some(h), Some(c0)) = (&hifi, &combiner) {
                let actual: Vec<f64> = batch.iter().map(|&(_, y)| y).collect();
                let xs: Vec<_> = batch.iter().map(|&(i, _)| pool.feats.workflow[i]).collect();
                let pred_h = scorer.score(h, &xs);
                let cx: Vec<[f32; F_MAX]> = batch
                    .iter()
                    .map(|&(i, _)| combiner_features(&per_comp_preds, i))
                    .collect();
                let pred_l = scorer.score(c0, &cx);
                if recall_sum_123(&pred_h, &actual) >= recall_sum_123(&pred_l, &actual) {
                    using_hifi = true;
                }
            }
        }
        measured.extend_from_slice(&batch);
        hifi = Some(train_hifi(prob, pool, &measured));
        combiner = Some(train_combiner(&measured));
        if iter + 1 < iters {
            let scores: Vec<f64> = if using_hifi {
                scorer.score(hifi.as_ref().unwrap(), &pool.feats.workflow)
            } else {
                let c0 = combiner.as_ref().unwrap();
                let cx: Vec<[f32; F_MAX]> = (0..pool.len())
                    .map(|i| combiner_features(&per_comp_preds, i))
                    .collect();
                scorer.score(c0, &cx)
            };
            c_meas = top_unmeasured(&scores, &measured_set, m_b);
            for &i in &c_meas {
                measured_set.insert(i);
            }
        }
    }

    let model = hifi.expect("at least one iteration");
    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}

/// Budgeted-CEAL reference (§6 adaptation): cost-budgeted phases with
/// per-sample stopping.
pub fn run_budgeted(
    t: &BudgetedCeal,
    prob: &Problem,
    pool: &Pool,
    scorer: &Scorer,
    cost_budget: f64,
    rng: &mut Pcg32,
) -> TunerOutput {
    assert!(cost_budget > 0.0);
    let p = t.params;
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut sel_rng = rng.derive_str("select");

    // Phase 1: component runs until the component allowance is spent.
    let comp_allowance = cost_budget * p.component_frac;
    let spec = &prob.sim.spec;
    let configurable = spec.configurable();
    let mut samples: Vec<ComponentSamples> = configurable
        .iter()
        .map(|_| ComponentSamples::default())
        .collect();
    let mut exhausted = vec![false; configurable.len()];
    'outer: loop {
        let mut progressed = false;
        for (slot, &comp) in configurable.iter().enumerate() {
            if exhausted[slot] {
                continue;
            }
            if col.component_cost >= comp_allowance {
                break 'outer;
            }
            match col.measure_component_sampled(comp, &mut sel_rng) {
                Ok((cfg, y)) => {
                    samples[slot].push(spec.components[comp].encode(&cfg), y);
                    progressed = true;
                }
                Err(e) => {
                    eprintln!("warning: {e}; skipping its isolated runs");
                    exhausted[slot] = true;
                }
            }
        }
        if !progressed {
            break;
        }
    }
    let n_feats = prob.n_component_features();
    let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
    let lowfi = LowFiModel::fit(&samples, &n_feats, prob.objective, &comp_params);
    let lowfi_scores = lowfi.score(&pool.feats, scorer);

    // Phase 2: bootstrap + guided batches under the remaining budget.
    let mut measured: Vec<(usize, f64)> = Vec::new();
    let mut measured_set: HashSet<usize> = HashSet::new();
    let boot_allowance = cost_budget * (p.component_frac + p.bootstrap_frac);
    while col.total_cost() < boot_allowance && measured_set.len() < pool.len() {
        let i = random_unmeasured(pool, &measured_set, 1, &mut sel_rng)[0];
        measured.push((i, col.measure(&pool.configs[i])));
        measured_set.insert(i);
    }

    let mut using_hifi = false;
    let mut hifi = if measured.len() >= 2 {
        Some(train_hifi(prob, pool, &measured))
    } else {
        None
    };
    while col.total_cost() < cost_budget && measured_set.len() < pool.len() {
        let hifi_scores;
        let scores: &[f64] = match (&hifi, using_hifi) {
            (Some(h), true) => {
                hifi_scores = scorer.score(h, &pool.feats.workflow);
                &hifi_scores
            }
            _ => &lowfi_scores,
        };
        let batch_idx = top_unmeasured(scores, &measured_set, p.batch.min(pool.len()));
        if batch_idx.is_empty() {
            break;
        }
        let mut batch: Vec<(usize, f64)> = Vec::new();
        for i in batch_idx {
            if col.total_cost() >= cost_budget {
                break;
            }
            batch.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }
        if batch.is_empty() {
            break;
        }
        measured.extend_from_slice(&batch);
        if let Some(h) = &hifi {
            if !using_hifi {
                let actual: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
                let xs: Vec<_> = measured
                    .iter()
                    .map(|&(i, _)| pool.feats.workflow[i])
                    .collect();
                let s_h = recall_sum_123(&scorer.score(h, &xs), &actual);
                let pred_l: Vec<f64> = measured.iter().map(|&(i, _)| lowfi_scores[i]).collect();
                if s_h >= recall_sum_123(&pred_l, &actual) {
                    using_hifi = true;
                }
            }
        }
        if measured.len() >= 2 {
            hifi = Some(train_hifi(prob, pool, &measured));
        }
    }

    let model = hifi.unwrap_or_else(|| Ensemble::constant(1, 0.0));
    let best_idx = searcher_best(&model, pool, scorer, &measured);
    TunerOutput {
        model,
        measured,
        best_idx,
        collection_cost: col.total_cost(),
        workflow_runs: col.workflow_runs,
        failed_runs: 0,
    }
}
