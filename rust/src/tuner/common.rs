//! Shared tuner infrastructure: the tuning problem, the sample pool
//! C_pool (§5), the collector (the canonical session
//! [`Evaluator`](super::session::Evaluator)), and the Tuner trait +
//! searcher.

use std::collections::{HashMap, HashSet};

use crate::config::{Config, WorkflowId, F_MAX};
use crate::gbt::Ensemble;
use crate::sim::{Objective, SimWorkspace, WorkflowSim};
use crate::surrogate::{PoolFeatures, Scorer};
use crate::util::rng::Pcg32;
use crate::util::stats;

/// A tuning problem: one workflow, one optimization objective.
pub struct Problem {
    pub sim: WorkflowSim,
    pub objective: Objective,
}

impl Problem {
    pub fn new(id: WorkflowId, objective: Objective) -> Problem {
        Problem {
            sim: WorkflowSim::new(id),
            objective,
        }
    }

    /// Number of (real, unpadded) features in the whole-workflow view.
    pub fn n_workflow_features(&self) -> usize {
        self.sim.spec.n_params()
    }

    /// Per configurable component: its own feature count.
    pub fn n_component_features(&self) -> Vec<usize> {
        self.sim
            .spec
            .configurable()
            .into_iter()
            .map(|j| self.sim.spec.components[j].params.len())
            .collect()
    }
}

/// The sample pool C_pool (paper §5): a feasible random subset of the
/// configuration space from which all training samples are drawn, plus
/// the noise-free ground truth used as the experiment test set (§7.1
/// measures all 2000 pool configurations).
///
/// Pools are immutable once generated and may be shared (`Arc<Pool>`)
/// across every algorithm and repetition of a campaign cell — see
/// [`crate::coordinator::PoolCache`].  Tuners must never mutate a pool.
///
/// The truth side comes in two physical forms behind one accessor
/// surface ([`truth_of`](Self::truth_of) and friends):
///
/// * **Eager** ([`generate_par`](Self::generate_par)) — every config's
///   noise-free objective is measured at generation time, exactly as
///   the paper's §7.1 test set.  This is the reference path; all
///   exhaustive metrics (recall, MdAPE, normalized best) require it.
/// * **Lazy** ([`generate_lazy`](Self::generate_lazy)) — candidates
///   are sampled from the *identical* seed stream but no simulator
///   runs happen up front; a config's truth is computed (and cached)
///   only when something asks for it — failure-cost charges, the final
///   best-config report.  This is what makes 10^5–10^6-config pools
///   affordable: memory and generation time are bounded by the feature
///   side.  `truth_of(i)` is bit-identical across the two forms (the
///   same deterministic `expected_with` measurement).
pub struct Pool {
    pub configs: Vec<Config>,
    pub feats: PoolFeatures,
    truth: TruthSide,
    /// Lazily built k-NN parameter graphs (GEIST), one per requested
    /// `k` — pools are shared across algorithms, so callers may
    /// legitimately disagree on `k`.  Per-k `OnceLock` slots keep the
    /// O(n²) build outside the map lock (same pattern as the pool
    /// cache), so readers of other `k`s never block on a build.
    knn: std::sync::Mutex<HashMap<usize, std::sync::Arc<KnnSlot>>>,
}

type KnnSlot = std::sync::OnceLock<std::sync::Arc<Vec<Vec<usize>>>>;

/// The two physical truth representations; see [`Pool`].
enum TruthSide {
    Eager {
        /// Noise-free objective value per config (the test set).
        truth: Vec<f64>,
        /// Index of the best configuration in the pool.
        best_idx: usize,
    },
    Lazy(LazyTruth),
}

/// On-demand truth: the owned simulator + objective recompute any
/// config's noise-free measurement exactly as eager generation would
/// have, caching each value the first time it is asked for.
struct LazyTruth {
    sim: WorkflowSim,
    objective: Objective,
    cache: std::sync::Mutex<HashMap<usize, f64>>,
}

impl LazyTruth {
    fn value_of(&self, cfg: &Config, i: usize) -> f64 {
        if let Some(&v) = self.cache.lock().unwrap().get(&i) {
            return v;
        }
        // Compute outside the lock: the value is deterministic, so a
        // concurrent duplicate computation is benign (same bits).
        let v = self
            .objective
            .value(&self.sim.expected_with(cfg, &mut SimWorkspace::new()));
        self.cache.lock().unwrap().insert(i, v);
        v
    }
}

/// Pool size used by the paper (§7.1).
pub const POOL_SIZE: usize = 2000;

/// Pool sizes at or above this generate lazily by default (see
/// [`Pool::try_generate_auto`]): eager ground truth at these scales
/// costs O(size) simulator runs and O(size) resident doubles for a
/// test set nothing exhaustively consumes.
pub const LAZY_POOL_MIN: usize = 16_384;

impl Pool {
    /// Generate a deduplicated feasible pool and measure its ground
    /// truth.  Deterministic in (problem, seed).
    pub fn generate(prob: &Problem, size: usize, seed: u64) -> Pool {
        Pool::generate_par(prob, size, seed, 1)
    }

    /// [`try_generate_par`](Self::try_generate_par), panicking when the
    /// workflow's space admits no feasible configurations (legacy
    /// convenience — the paper trio and built-in scenarios are
    /// known-good).
    pub fn generate_par(prob: &Problem, size: usize, seed: u64, threads: usize) -> Pool {
        Pool::try_generate_par(prob, size, seed, threads)
            .unwrap_or_else(|e| panic!("pool generation failed: {e}"))
    }

    /// [`generate`](Self::generate) with the ground-truth measurement
    /// (`size` noise-free simulator runs — the dominant cost) spread
    /// across `threads` workers.  The result is identical for every
    /// thread count: configuration sampling stays sequential, and each
    /// config's expected measurement is deterministic.  Errors (instead
    /// of panicking) when feasibility sampling exhausts its rejection
    /// budget — newly registered workflows can have arbitrarily tight
    /// feasibility.
    pub fn try_generate_par(
        prob: &Problem,
        size: usize,
        seed: u64,
        threads: usize,
    ) -> Result<Pool, crate::sim::InfeasibleSpace> {
        let (configs, feats) = sample_pool_configs(prob, size, seed)?;
        let truth = measure_truth(prob, &configs, threads);
        let best_idx = stats::argmin(&truth).expect("non-empty pool");
        Ok(Pool {
            configs,
            feats,
            truth: TruthSide::Eager { truth, best_idx },
            knn: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// [`try_generate_lazy`](Self::try_generate_lazy), panicking on an
    /// infeasible space (mirror of [`generate_par`](Self::generate_par)).
    pub fn generate_lazy(prob: &Problem, size: usize, seed: u64) -> Pool {
        Pool::try_generate_lazy(prob, size, seed)
            .unwrap_or_else(|e| panic!("pool generation failed: {e}"))
    }

    /// Generate a *lazy* pool: the candidate configs come off the exact
    /// seed stream of [`try_generate_par`](Self::try_generate_par)
    /// (bitwise-equal `configs`/`feats` for the same `(problem, size,
    /// seed)`), but no ground truth is measured up front — each
    /// config's noise-free objective is computed on first access via
    /// [`truth_of`](Self::truth_of).  Generation cost and resident
    /// memory are bounded by sampling + feature encoding alone.
    pub fn try_generate_lazy(
        prob: &Problem,
        size: usize,
        seed: u64,
    ) -> Result<Pool, crate::sim::InfeasibleSpace> {
        let (configs, feats) = sample_pool_configs(prob, size, seed)?;
        Ok(Pool {
            configs,
            feats,
            truth: TruthSide::Lazy(LazyTruth {
                sim: prob.sim.clone(),
                objective: prob.objective,
                cache: std::sync::Mutex::new(HashMap::new()),
            }),
            knn: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Generation policy: eager (the reference) below
    /// [`LAZY_POOL_MIN`], lazy at or above it.  What the pool cache
    /// and CLI use so `--pool 100000` never materializes a truth side.
    pub fn try_generate_auto(
        prob: &Problem,
        size: usize,
        seed: u64,
        threads: usize,
    ) -> Result<Pool, crate::sim::InfeasibleSpace> {
        if size >= LAZY_POOL_MIN {
            Pool::try_generate_lazy(prob, size, seed)
        } else {
            Pool::try_generate_par(prob, size, seed, threads)
        }
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// Is the truth side on-demand (no materialized test set)?
    pub fn is_lazy(&self) -> bool {
        matches!(self.truth, TruthSide::Lazy(_))
    }

    /// Ground truth of pool index `i`.  Eager pools index the test
    /// set; lazy pools run the deterministic noise-free measurement on
    /// first access and cache it — bit-identical to the eager value.
    pub fn truth_of(&self, i: usize) -> f64 {
        match &self.truth {
            TruthSide::Eager { truth, .. } => truth[i],
            TruthSide::Lazy(l) => l.value_of(&self.configs[i], i),
        }
    }

    /// The full materialized test set, or `None` on a lazy pool.
    /// Exhaustive metrics (recall, MdAPE, pool-normalized best) must
    /// gate on this instead of forcing O(pool) simulator runs.
    pub fn truth_eager(&self) -> Option<&[f64]> {
        match &self.truth {
            TruthSide::Eager { truth, .. } => Some(truth),
            TruthSide::Lazy(_) => None,
        }
    }

    /// The materialized test set (panics on a lazy pool — use
    /// [`truth_eager`](Self::truth_eager) or
    /// [`truth_of`](Self::truth_of) in lazy-capable paths).
    pub fn truth(&self) -> &[f64] {
        self.truth_eager()
            .expect("lazy pool has no materialized ground truth")
    }

    /// Index of the true-best configuration (requires eager truth).
    pub fn best_idx(&self) -> usize {
        match &self.truth {
            TruthSide::Eager { best_idx, .. } => *best_idx,
            TruthSide::Lazy(_) => panic!("lazy pool has no materialized best index"),
        }
    }

    pub fn best_value(&self) -> f64 {
        self.truth()[self.best_idx()]
    }

    /// Lazily computed truth cells so far (0 for eager pools) — the
    /// lazy path's memory/diagnostic counter.
    pub fn lazy_truth_count(&self) -> usize {
        match &self.truth {
            TruthSide::Eager { .. } => 0,
            TruthSide::Lazy(l) => l.cache.lock().unwrap().len(),
        }
    }

    /// A positive, deterministic stand-in for an expected run cost when
    /// nothing has been observed yet (component failure charges).
    /// Eager pools use the pool-best value as before; lazy pools
    /// measure config 0 once — any fixed pool member works, the charge
    /// only needs to be positive and reproducible.
    pub(crate) fn failure_cost_floor(&self) -> f64 {
        match &self.truth {
            TruthSide::Eager { .. } => self.best_value(),
            TruthSide::Lazy(_) => self.truth_of(0),
        }
    }

    /// Approximate resident bytes (configs + features + truth side) —
    /// what the pool cache's LRU cap accounts against.
    pub fn approx_bytes(&self) -> usize {
        let n = self.len();
        let per_cfg = std::mem::size_of::<Config>()
            + self.configs.first().map_or(0, |c| c.0.len()) * std::mem::size_of::<i64>();
        let row = std::mem::size_of::<[f32; F_MAX]>();
        let feat_rows = 1 + self.feats.per_component.len();
        let truth = match &self.truth {
            TruthSide::Eager { truth, .. } => truth.len() * std::mem::size_of::<f64>(),
            // HashMap cell ≈ key + value + bucket overhead
            TruthSide::Lazy(l) => l.cache.lock().unwrap().len() * 48,
        };
        n * per_cfg + n * row * feat_rows + truth
    }

    /// k-nearest-neighbor graph over normalized workflow features
    /// (GEIST's parameter graph; built once per pool and `k`, then
    /// shared — pools themselves are shared across algorithms).
    ///
    /// Distances accumulate only over the spec's real feature count —
    /// the padded lanes up to `F_MAX` are zero for every row, so the
    /// neighbor sets are unchanged — and each row uses
    /// `select_nth_unstable` partial selection (then sorts only the `k`
    /// kept) instead of fully sorting all `n` candidates.  Ties break by
    /// ascending index, matching the old stable full sort.
    pub fn knn_graph(&self, k: usize) -> std::sync::Arc<Vec<Vec<usize>>> {
        let slot = {
            let mut cache = self.knn.lock().unwrap();
            std::sync::Arc::clone(cache.entry(k).or_default())
        };
        std::sync::Arc::clone(slot.get_or_init(|| std::sync::Arc::new(self.build_knn(k))))
    }

    /// One O(n²) graph build; see [`knn_graph`](Self::knn_graph).
    /// Rows are independent, so the build fans fixed 32-row chunks
    /// across the worker pool (each chunk task reuses its worker's
    /// persistent distance scratch); neighbor lists are bit-identical
    /// for any worker count.
    fn build_knn(&self, k: usize) -> Vec<Vec<usize>> {
        const ROWS: usize = 32;
        /// Pool rows needed before the build dispatches to the pool.
        const KNN_PAR_MIN: usize = 256;
        std::thread_local! {
            static KNN_SCRATCH: std::cell::RefCell<Vec<(f64, usize)>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        let n = self.len();
        let nf = self.feats.n_workflow.min(F_MAX);
        let xs = &self.feats.workflow;
        // total_cmp: same order as partial_cmp for the finite
        // distances this sees, with no NaN panic path
        let by_dist_then_index =
            |a: &(f64, usize), b: &(f64, usize)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1));
        let width = crate::util::parallel::width_for(n, KNN_PAR_MIN);
        let mut graph: Vec<Vec<usize>> = vec![Vec::new(); n];
        crate::util::parallel::for_each_chunk_mut(width, ROWS, &mut graph, |ci, rows| {
            KNN_SCRATCH.with(|scratch| {
                let mut dists = scratch.borrow_mut();
                for (row_off, slot) in rows.iter_mut().enumerate() {
                    let i = ci * ROWS + row_off;
                    dists.clear();
                    for j in 0..n {
                        if j == i {
                            continue;
                        }
                        let mut d = 0.0f64;
                        for f in 0..nf {
                            let diff = (xs[i][f] - xs[j][f]) as f64;
                            d += diff * diff;
                        }
                        dists.push((d, j));
                    }
                    let keep = k.min(dists.len());
                    if keep > 0 && keep < dists.len() {
                        dists.select_nth_unstable_by(keep - 1, by_dist_then_index);
                    }
                    let kept = &mut dists[..keep];
                    kept.sort_unstable_by(by_dist_then_index);
                    *slot = kept.iter().map(|&(_, j)| j).collect();
                }
            });
        });
        graph
    }
}

/// Shared candidate sampling of eager and lazy generation: the
/// deduplicated feasible draw off the `(seed, 0x9001)` stream plus the
/// feature encoding.  Extracting this is what makes the lazy pool's
/// configs bitwise-equal to the eager reference.
fn sample_pool_configs(
    prob: &Problem,
    size: usize,
    seed: u64,
) -> Result<(Vec<Config>, PoolFeatures), crate::sim::InfeasibleSpace> {
    let mut rng = Pcg32::new(seed, 0x9001);
    let spec = &prob.sim.spec;
    let mut seen: HashSet<Config> = HashSet::with_capacity(size * 2);
    let mut configs = Vec::with_capacity(size);
    let feasible = |c: &Config| prob.sim.feasible(c);
    while configs.len() < size {
        let c = spec.try_sample_feasible(&mut rng, &feasible, 100_000)?;
        if seen.insert(c.clone()) {
            configs.push(c);
        }
    }
    let feats = PoolFeatures::encode(spec, &configs);
    Ok((configs, feats))
}

/// Noise-free ground truth for every config, fanned across the
/// process-wide worker pool in fixed 64-config chunks (boundaries
/// independent of the worker count).  Each chunk task owns one
/// reusable simulator workspace, so the sweep performs O(n/64)
/// allocations regardless of pool size, and every config's expected
/// measurement is deterministic — the result is bit-identical for any
/// `threads`.
fn measure_truth(prob: &Problem, configs: &[Config], threads: usize) -> Vec<f64> {
    const CHUNK: usize = 64;
    let threads = threads.clamp(1, configs.len().max(1));
    let mut truth = vec![0.0f64; configs.len()];
    crate::util::parallel::for_each_chunk_mut(threads, CHUNK, &mut truth, |ci, out| {
        let mut ws = SimWorkspace::new();
        for (k, o) in out.iter_mut().enumerate() {
            let c = &configs[ci * CHUNK + k];
            *o = prob.objective.value(&prob.sim.expected_with(c, &mut ws));
        }
    });
    truth
}

/// The collector (§2.1): runs the simulator and accounts for cost.
/// Owns one [`SimWorkspace`] reused across all of its runs, so the
/// per-sample measurement path allocates nothing after the first run.
pub struct Collector<'a> {
    prob: &'a Problem,
    rng: Pcg32,
    ws: SimWorkspace,
    /// Workflow runs performed.
    pub workflow_runs: usize,
    /// Component runs performed (isolated).
    pub component_runs: usize,
    /// Σ objective values over workflow training runs.
    pub workflow_cost: f64,
    /// Σ objective values over component training runs.
    pub component_cost: f64,
}

impl<'a> Collector<'a> {
    pub fn new(prob: &'a Problem, rng: Pcg32) -> Collector<'a> {
        Collector {
            prob,
            rng,
            ws: SimWorkspace::new(),
            workflow_runs: 0,
            component_runs: 0,
            workflow_cost: 0.0,
            component_cost: 0.0,
        }
    }

    /// Run the workflow at `cfg`, returning the measured objective.
    pub fn measure(&mut self, cfg: &Config) -> f64 {
        let m = self.prob.sim.run_with(cfg, &mut self.rng, &mut self.ws);
        let y = self.prob.objective.value(&m);
        self.workflow_runs += 1;
        self.workflow_cost += y;
        y
    }

    /// Run configurable component `comp` (index into the spec) alone.
    pub fn measure_component(&mut self, comp: usize, comp_cfg: &[i64]) -> f64 {
        let m = self.prob.sim.run_component(comp, comp_cfg, &mut self.rng);
        let y = self.prob.objective.value(&m);
        self.component_runs += 1;
        self.component_cost += y;
        y
    }

    /// Measure a batch of pool configurations (CEAL's Alg. 1 line-15
    /// `C_meas` batch); see [`measure_config_batch`](Self::measure_config_batch).
    pub fn measure_pool_batch(&mut self, pool: &Pool, idxs: &[usize]) -> Vec<(usize, f64)> {
        let cfgs: Vec<&Config> = idxs.iter().map(|&i| &pool.configs[i]).collect();
        idxs.iter()
            .copied()
            .zip(self.measure_config_batch(&cfgs))
            .collect()
    }

    /// Measure a batch of explicit configurations, fanning the noisy
    /// simulator runs across the process-wide worker pool — one task
    /// per configuration.  This is the [`BatchMode::FanOut`] leg of
    /// the session [`Evaluator`] contract.
    ///
    /// Determinism: every slot draws from its own child RNG derived
    /// from the collector stream's current state and the slot index,
    /// the main stream then advances exactly once, and cost accounting
    /// folds in slot order after the join — so the returned values (and
    /// all collector state) are bit-identical for every worker count,
    /// including one.  A batch of zero or one goes through
    /// [`measure`](Self::measure) directly (no dispatch setup).
    ///
    /// [`BatchMode::FanOut`]: super::session::BatchMode::FanOut
    /// [`Evaluator`]: super::session::Evaluator
    pub fn measure_config_batch(&mut self, cfgs: &[&Config]) -> Vec<f64> {
        if cfgs.len() <= 1 {
            return cfgs.iter().map(|c| self.measure(c)).collect();
        }
        let rngs: Vec<Pcg32> = (0..cfgs.len())
            .map(|t| self.rng.derive(t as u64))
            .collect();
        self.rng.next_u64();
        let prob = self.prob;
        let mut ys = vec![0.0f64; cfgs.len()];
        let width = crate::util::parallel::current_threads();
        crate::util::parallel::for_each_chunk_mut(width, 1, &mut ys, |slot, out| {
            let mut rng = rngs[slot].clone();
            // `sim.run` rides the simulator's per-thread scratch
            // workspace, so the fan-out allocates nothing once the
            // pool workers are warm.
            out[0] = prob.objective.value(&prob.sim.run(cfgs[slot], &mut rng));
        });
        for &y in &ys {
            self.workflow_runs += 1;
            self.workflow_cost += y;
        }
        ys
    }

    /// Sample a feasible configuration for component `comp` (drawing
    /// from `sel_rng`, keeping selection and measurement RNG streams
    /// separate) and run it in isolation.  A component whose slice of
    /// the space admits no runnable allocation surfaces as an error —
    /// not a panic — without consuming any measurement budget.
    pub fn measure_component_sampled(
        &mut self,
        comp: usize,
        sel_rng: &mut Pcg32,
    ) -> Result<(Vec<i64>, f64), crate::sim::InfeasibleSpace> {
        let cfg = self.prob.sim.sample_component_feasible(comp, sel_rng)?;
        let y = self.measure_component(comp, &cfg);
        Ok((cfg, y))
    }

    /// Total collection cost (workflow + component runs) — the `c` of
    /// the least-number-of-uses metric (§7.2.3).
    pub fn total_cost(&self) -> f64 {
        self.workflow_cost + self.component_cost
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// What a tuner returns.
pub struct TunerOutput {
    /// Final high-fidelity surrogate model.
    pub model: Ensemble,
    /// Measured workflow samples: (pool index, measured objective).
    pub measured: Vec<(usize, f64)>,
    /// Searcher's pick: pool index with the best predicted objective.
    pub best_idx: usize,
    /// Total collection cost (incl. component runs unless historical,
    /// plus wall-clock charges for failed measurement attempts).
    pub collection_cost: f64,
    /// Workflow runs actually performed.
    pub workflow_runs: usize,
    /// Measurement attempts that failed or timed out.
    pub failed_runs: usize,
}

/// An auto-tuning algorithm.
///
/// The required surface is [`session`](Self::session): an algorithm is
/// a factory for ask/tell [`TunerSession`]s (its loop split at every
/// measurement).  [`run`](Self::run) is the provided synchronous
/// convenience — the thin generic driver [`drive`] over the
/// simulator-backed [`Collector`] — and is bit-identical to the
/// pre-session monolithic loops (pinned against [`super::legacy`] by
/// `tests/session_equivalence.rs`).
///
/// [`TunerSession`]: super::session::TunerSession
/// [`drive`]: super::session::drive
pub trait Tuner: Sync {
    fn name(&self) -> &'static str;

    /// Open one tuning session with a budget of `m` workflow-run
    /// equivalents.  `rng` seeds the session's selection stream (it is
    /// not advanced; children are derived from its current state, so
    /// the caller's stream stays aligned with the monolithic API).
    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn super::session::TunerSession + 'a>;

    /// Run one tuning campaign to completion against the simulator:
    /// `drive(session, Collector)`.
    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        super::session::drive(self.session(prob, pool, scorer, m, rng), &mut col)
    }
}

/// The searcher (§2.1): best configuration over the pool.  Model
/// predictions (log-space, exponentiated to times) are used for
/// unmeasured configurations; where a configuration was actually
/// measured, the observation replaces the model output — a tuner never
/// trusts a surrogate over data it already has.
///
/// Streaming: scores are consumed chunk-by-chunk as
/// [`Scorer::score_fold`] produces them — no O(pool) score vector.
/// Each fixed chunk keeps its first strict minimum, chunks merge in
/// chunk order, so the pick (first minimum, `partial_cmp` NaN panic
/// included) is identical to the old materialize-then-`argmin` pass at
/// any pool size and worker count.
pub fn searcher_best(
    model: &Ensemble,
    pool: &Pool,
    scorer: &Scorer,
    measured: &[(usize, f64)],
) -> usize {
    let overrides: HashMap<usize, f64> = measured.iter().copied().collect();
    let mins = scorer.score_fold_view(
        model,
        pool.feats.workflow_view(),
        || None::<(f64, usize)>,
        |best, base, preds| {
            for (j, p) in preds.iter().enumerate() {
                let i = base + j;
                let s = match overrides.get(&i) {
                    Some(&y) => y,
                    None => p.exp(),
                };
                let better = match best {
                    // strict `<` keeps the earliest minimum, like
                    // `min_by`; NaN panics, like `stats::argmin`
                    Some((b, _)) => {
                        s.partial_cmp(b).expect("NaN in argmin") == std::cmp::Ordering::Less
                    }
                    None => true,
                };
                if better {
                    *best = Some((s, i));
                }
            }
        },
    );
    let mut best: Option<(f64, usize)> = None;
    for m in mins.into_iter().flatten() {
        let better = match &best {
            Some((b, _)) => m.0.partial_cmp(b).expect("NaN in argmin") == std::cmp::Ordering::Less,
            None => true,
        };
        if better {
            best = Some(m);
        }
    }
    best.expect("non-empty pool").1
}

/// Train the workflow (high-fidelity) surrogate on measured samples.
/// Log-space: the returned ensemble predicts ln(objective); use
/// [`predict_times`] for real-scale estimates.
pub fn train_hifi(prob: &Problem, pool: &Pool, measured: &[(usize, f64)]) -> Ensemble {
    let xs: Vec<[f32; F_MAX]> = measured
        .iter()
        .map(|&(i, _)| pool.feats.workflow[i])
        .collect();
    let y: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
    let params = crate::gbt::GbtParams::small_data();
    crate::gbt::train_log(&xs, &y, prob.n_workflow_features(), &params)
}

/// Real-scale time predictions of a log-space model over rows
/// (convenience alias for [`Scorer::score_times`]).
pub fn predict_times(
    model: &Ensemble,
    xs: &[[f32; F_MAX]],
    scorer: &crate::surrogate::Scorer,
) -> Vec<f64> {
    scorer.score_times(model, xs)
}

/// Select `k` distinct unmeasured pool indices uniformly at random.
///
/// Draws the same picks (same RNG consumption) as the old
/// "materialize the `available` vector, then `sample_indices`"
/// implementation, but without the pool-sized allocation:
/// [`Pcg32::sample_indices_sparse`] produces `k` distinct positions
/// over the *virtual* array of unmeasured indices with O(k)
/// bookkeeping, and a single scan of the index range maps each
/// position to the corresponding unmeasured index.  O(pool) time,
/// O(k) memory.
pub fn random_unmeasured(
    pool: &Pool,
    measured: &HashSet<usize>,
    k: usize,
    rng: &mut Pcg32,
) -> Vec<usize> {
    debug_assert!(measured.iter().all(|&i| i < pool.len()));
    let n_avail = pool.len() - measured.len();
    assert!(n_avail >= k, "pool exhausted");
    let positions = rng.sample_indices_sparse(n_avail, k);
    // Map virtual positions (ranks among unmeasured indices) to pool
    // indices in one pass, preserving draw order in the output.
    let mut order: Vec<(usize, usize)> = positions
        .iter()
        .enumerate()
        .map(|(slot, &p)| (p, slot))
        .collect();
    order.sort_unstable();
    let mut out = vec![0usize; k];
    let mut oi = 0;
    let mut rank = 0;
    for idx in 0..pool.len() {
        if oi == order.len() {
            break;
        }
        if measured.contains(&idx) {
            continue;
        }
        if order[oi].0 == rank {
            out[order[oi].1] = idx;
            oi += 1;
        }
        rank += 1;
    }
    debug_assert_eq!(oi, order.len(), "every sampled rank must resolve");
    out
}

/// A bounded selector of the `k` smallest `(score, index)` pairs under
/// `total_cmp`-then-index order — the streaming replacement for
/// "materialize every score, partial-sort the survivors".
///
/// The order is total and the pairs are distinct (distinct indices),
/// so the selected *set* is unique: offering candidates in any order —
/// including per-worker-shard with a final merge — yields the same
/// `k` picks, and [`into_indices`](Self::into_indices) returns them in
/// the same ascending order as the old full-sort selection.
pub struct TopK {
    k: usize,
    /// Max-heap on (score, index): the root is the worst kept pick.
    heap: std::collections::BinaryHeap<ScoredIdx>,
}

/// `(score, index)` with `total_cmp`-then-index ordering (NaN sorts
/// last, after every real score — a degenerate model must not panic).
#[derive(Clone, Copy)]
struct ScoredIdx(f64, usize);

impl PartialEq for ScoredIdx {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for ScoredIdx {}
impl PartialOrd for ScoredIdx {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ScoredIdx {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK {
            k,
            heap: std::collections::BinaryHeap::with_capacity(k + 1),
        }
    }

    /// Offer one candidate; keeps at most `k`, O(log k).
    #[inline]
    pub fn offer(&mut self, score: f64, idx: usize) {
        if self.k == 0 {
            return;
        }
        let cand = ScoredIdx(score, idx);
        if self.heap.len() < self.k {
            self.heap.push(cand);
        } else if let Some(worst) = self.heap.peek() {
            if cand < *worst {
                self.heap.pop();
                self.heap.push(cand);
            }
        }
    }

    /// Merge another shard's picks (worker-shard reduction).
    pub fn merge(&mut self, other: TopK) {
        for ScoredIdx(s, i) in other.heap {
            self.offer(s, i);
        }
    }

    /// The selected indices in ascending (score, index) order —
    /// exactly the old `select_nth` + sort output.
    pub fn into_indices(self) -> Vec<usize> {
        let mut picks = self.heap.into_vec();
        picks.sort_unstable();
        picks.into_iter().map(|ScoredIdx(_, i)| i).collect()
    }
}

/// Select the `k` best-scoring unmeasured pool indices (scores are
/// lower-is-better), in ascending score order with index tie-breaks.
///
/// One bounded-heap pass: O(pool · log k) time, O(k) extra memory —
/// no materialized index vector.  The (score, index) order is total,
/// so the selected set and its final order are deterministic and
/// identical to the old partial-selection implementation.
pub fn top_unmeasured(scores: &[f64], measured: &HashSet<usize>, k: usize) -> Vec<usize> {
    let mut top = TopK::new(k);
    for (i, &s) in scores.iter().enumerate() {
        if !measured.contains(&i) {
            top.offer(s, i);
        }
    }
    top.into_indices()
}

/// Fused score-and-select: the `k` best unmeasured pool indices under
/// `model`'s raw (log-space) pool scores, without materializing the
/// O(pool) score vector — each fixed [`Scorer::score_fold`] chunk
/// feeds a bounded [`TopK`] shard, shards merge in chunk order.
/// Equivalent to `top_unmeasured(&scorer.score(model,
/// &pool.feats.workflow), measured, k)` pick-for-pick (the per-row
/// scores are bitwise identical and the selection order is total).
pub fn top_unmeasured_model(
    model: &Ensemble,
    pool: &Pool,
    scorer: &Scorer,
    measured: &HashSet<usize>,
    k: usize,
) -> Vec<usize> {
    let shards = scorer.score_fold_view(
        model,
        pool.feats.workflow_view(),
        || TopK::new(k),
        |top, base, preds| {
            for (j, &p) in preds.iter().enumerate() {
                let i = base + j;
                if !measured.contains(&i) {
                    top.offer(p, i);
                }
            }
        },
    );
    let mut all = TopK::new(k);
    for shard in shards {
        all.merge(shard);
    }
    all.into_indices()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem() -> Problem {
        Problem::new(WorkflowId::LV, Objective::ExecTime)
    }

    #[test]
    fn pool_generation_is_feasible_and_deterministic() {
        let prob = toy_problem();
        let a = Pool::generate(&prob, 50, 7);
        let b = Pool::generate(&prob, 50, 7);
        assert_eq!(a.configs, b.configs);
        for c in &a.configs {
            assert!(prob.sim.feasible(c));
            assert!(prob.sim.spec.validate(c).is_ok());
        }
        // dedup
        let set: HashSet<&Config> = a.configs.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(a.best_value() <= stats::quantile(a.truth(), 0.1));
    }

    #[test]
    fn different_seeds_differ() {
        let prob = toy_problem();
        let a = Pool::generate(&prob, 30, 1);
        let b = Pool::generate(&prob, 30, 2);
        assert_ne!(a.configs, b.configs);
    }

    #[test]
    fn knn_graph_shape() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 40, 3);
        let g = pool.knn_graph(5);
        assert_eq!(g.len(), 40);
        for (i, nbrs) in g.iter().enumerate() {
            assert_eq!(nbrs.len(), 5);
            assert!(!nbrs.contains(&i));
        }
        // cached: same graph shared, per k
        let g2 = pool.knn_graph(5);
        assert!(std::sync::Arc::ptr_eq(&g, &g2));
        let g3 = pool.knn_graph(3);
        assert_eq!(g3[0].len(), 3, "different k builds its own graph");
        assert!(std::sync::Arc::ptr_eq(&g, &pool.knn_graph(5)));
    }

    /// The partial-selection kNN over real features must equal the old
    /// full sort over all F_MAX padded lanes, neighbor order included.
    #[test]
    fn knn_graph_equals_full_sort_reference() {
        for (wf, seed, k) in [
            (WorkflowId::LV, 13u64, 5usize),
            (WorkflowId::HS, 14, 10),
            (WorkflowId::GP, 15, 7),
        ] {
            let prob = Problem::new(wf, Objective::ExecTime);
            let pool = Pool::generate(&prob, 60, seed);
            let xs = &pool.feats.workflow;
            let reference: Vec<Vec<usize>> = (0..pool.len())
                .map(|i| {
                    let mut dists: Vec<(f64, usize)> = (0..pool.len())
                        .filter(|&j| j != i)
                        .map(|j| {
                            let mut d = 0.0f64;
                            for f in 0..F_MAX {
                                let diff = (xs[i][f] - xs[j][f]) as f64;
                                d += diff * diff;
                            }
                            (d, j)
                        })
                        .collect();
                    dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    dists.into_iter().take(k).map(|(_, j)| j).collect()
                })
                .collect();
            assert_eq!(&*pool.knn_graph(k), &reference, "{wf} k={k}");
        }
    }

    /// Parallel ground-truth measurement must be invisible: bit-identical
    /// pools for any worker count.
    #[test]
    fn generate_par_equals_serial() {
        let prob = toy_problem();
        let serial = Pool::generate(&prob, 60, 17);
        for threads in [2usize, 3, 7] {
            let par = Pool::generate_par(&prob, 60, 17, threads);
            assert_eq!(serial.configs, par.configs, "threads={threads}");
            assert_eq!(serial.truth(), par.truth(), "threads={threads}");
            assert_eq!(serial.best_idx(), par.best_idx(), "threads={threads}");
        }
    }

    #[test]
    fn collector_accounting() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 10, 4);
        let mut col = Collector::new(&prob, Pcg32::new(5, 5));
        let y = col.measure(&pool.configs[0]);
        assert!(y > 0.0);
        let yc = col.measure_component(0, prob.sim.spec.component_slice(&pool.configs[0], 0));
        assert!(yc > 0.0);
        assert_eq!(col.workflow_runs, 1);
        assert_eq!(col.component_runs, 1);
        assert!((col.total_cost() - y - yc).abs() < 1e-12);
    }

    #[test]
    fn selection_helpers() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 20, 6);
        let mut measured: HashSet<usize> = [0, 1, 2].into_iter().collect();
        let mut rng = Pcg32::new(8, 8);
        let r = random_unmeasured(&pool, &measured, 5, &mut rng);
        assert_eq!(r.len(), 5);
        assert!(r.iter().all(|i| !measured.contains(i)));

        let scores: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let t = top_unmeasured(&scores, &measured, 3);
        assert_eq!(t, vec![3, 4, 5]);
        measured.insert(4);
        let t2 = top_unmeasured(&scores, &measured, 3);
        assert_eq!(t2, vec![3, 5, 6]);
    }

    /// The sparse-Fisher-Yates `random_unmeasured` must keep the picks
    /// of the old materialize-then-`sample_indices` implementation for
    /// every seed — selection changes would silently reshuffle every
    /// downstream campaign.
    #[test]
    fn random_unmeasured_keeps_existing_picks() {
        fn reference(
            pool: &Pool,
            measured: &HashSet<usize>,
            k: usize,
            rng: &mut Pcg32,
        ) -> Vec<usize> {
            let available: Vec<usize> =
                (0..pool.len()).filter(|i| !measured.contains(i)).collect();
            assert!(available.len() >= k, "pool exhausted");
            rng.sample_indices(available.len(), k)
                .into_iter()
                .map(|i| available[i])
                .collect()
        }

        let prob = toy_problem();
        let pool = Pool::generate(&prob, 50, 18);
        crate::util::prop::check("random_unmeasured picks", 40, |rng| {
            let n_meas = rng.gen_range(30) as usize;
            let measured: HashSet<usize> = (0..n_meas)
                .map(|_| rng.gen_range(pool.len() as u64) as usize)
                .collect();
            let k = rng.gen_range((pool.len() - measured.len()) as u64 + 1) as usize;
            let mut r1 = rng.derive(1);
            let mut r2 = r1.clone();
            let new = random_unmeasured(&pool, &measured, k, &mut r1);
            let old = reference(&pool, &measured, k, &mut r2);
            crate::util::prop::assert_prop(
                new == old,
                format!("picks diverged: {new:?} vs {old:?}"),
            )?;
            // both must have consumed the same amount of randomness
            crate::util::prop::assert_prop(
                r1.next_u64() == r2.next_u64(),
                "RNG consumption diverged",
            )
        });
    }

    #[test]
    fn top_unmeasured_tie_break_and_bounds() {
        let measured: HashSet<usize> = HashSet::new();
        let scores = vec![1.0, 0.5, 0.5, 0.5, 2.0, 0.1];
        // ties broken by ascending index, deterministically
        assert_eq!(top_unmeasured(&scores, &measured, 3), vec![5, 1, 2]);
        assert_eq!(top_unmeasured(&scores, &measured, 0), Vec::<usize>::new());
        // k >= available returns everything, still fully sorted
        assert_eq!(top_unmeasured(&scores, &measured, 99), vec![5, 1, 2, 3, 0, 4]);
    }

    /// The bounded-heap `top_unmeasured` must reproduce the old
    /// materialize-and-partial-sort selection exactly — picks and order
    /// — for random scores with deliberate ties, any k, any measured
    /// set, NaNs included.
    #[test]
    fn top_unmeasured_equals_full_sort_reference() {
        fn reference(scores: &[f64], measured: &HashSet<usize>, k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> =
                (0..scores.len()).filter(|i| !measured.contains(i)).collect();
            if k == 0 {
                return Vec::new();
            }
            let by = |a: &usize, b: &usize| scores[*a].total_cmp(&scores[*b]).then(a.cmp(b));
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, by);
                idx.truncate(k);
            }
            idx.sort_unstable_by(by);
            idx
        }

        crate::util::prop::check("top_unmeasured streaming vs full sort", 60, |rng| {
            let n = 1 + rng.gen_range(200) as usize;
            let scores: Vec<f64> = (0..n)
                .map(|_| match rng.gen_range(5) {
                    0 => 0.5, // force ties
                    1 => f64::NAN,
                    _ => rng.f64(),
                })
                .collect();
            let measured: HashSet<usize> = (0..rng.gen_range(n as u64 / 2 + 1))
                .map(|_| rng.gen_range(n as u64) as usize)
                .collect();
            let k = rng.gen_range(n as u64 + 4) as usize;
            crate::util::prop::assert_prop(
                top_unmeasured(&scores, &measured, k) == reference(&scores, &measured, k),
                "streaming picks diverged from full-sort reference",
            )
        });
    }

    /// Fused score-and-select must equal materialize-then-select, and
    /// the streaming searcher must equal the materialized argmin — the
    /// exactness contracts the session tuners lean on.
    #[test]
    fn fused_selection_matches_materialized() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 150, 23);
        let measured_rows: Vec<(usize, f64)> = (0..25).map(|i| (i * 3, pool.truth_of(i * 3))).collect();
        let model = train_hifi(&prob, &pool, &measured_rows);
        let scorer = Scorer::Native;
        let measured: HashSet<usize> = measured_rows.iter().map(|&(i, _)| i).collect();

        let scores = scorer.score(&model, &pool.feats.workflow);
        for k in [0usize, 1, 5, 40, 150, 200] {
            assert_eq!(
                top_unmeasured_model(&model, &pool, &scorer, &measured, k),
                top_unmeasured(&scores, &measured, k),
                "k={k}"
            );
        }

        // searcher: reference = materialize, override, argmin
        let mut times = scorer.score_times(&model, &pool.feats.workflow);
        for &(i, y) in &measured_rows {
            times[i] = y;
        }
        let want = stats::argmin(&times).unwrap();
        assert_eq!(searcher_best(&model, &pool, &scorer, &measured_rows), want);
    }

    /// Lazy pools draw the identical candidate stream as the eager
    /// reference and produce bit-identical truth on demand.
    #[test]
    fn lazy_pool_matches_eager_reference() {
        let prob = toy_problem();
        let eager = Pool::generate_par(&prob, 120, 19, 3);
        let lazy = Pool::generate_lazy(&prob, 120, 19);
        assert!(lazy.is_lazy() && !eager.is_lazy());
        assert_eq!(eager.configs, lazy.configs);
        assert_eq!(eager.feats.workflow, lazy.feats.workflow);
        assert_eq!(lazy.lazy_truth_count(), 0);
        for i in (0..120).step_by(7) {
            assert_eq!(eager.truth_of(i), lazy.truth_of(i), "truth diverged at {i}");
        }
        // cached: second read hits the cache, count stays put
        let n = lazy.lazy_truth_count();
        assert!(n > 0);
        let _ = lazy.truth_of(0);
        assert_eq!(lazy.lazy_truth_count(), n);
        assert!(eager.truth_eager().is_some() && lazy.truth_eager().is_none());
        // the failure-cost floor is positive and deterministic on both
        assert!(eager.failure_cost_floor() > 0.0);
        assert_eq!(lazy.failure_cost_floor(), lazy.truth_of(0));
        // auto policy: small stays eager
        let auto = Pool::try_generate_auto(&prob, 50, 19, 1).unwrap();
        assert!(!auto.is_lazy());
    }

    #[test]
    fn train_and_search() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 60, 9);
        // measure 30 configs with the truth (no noise) and check the
        // searcher lands in a decent region
        let measured: Vec<(usize, f64)> = (0..30).map(|i| (i, pool.truth_of(i))).collect();
        let model = train_hifi(&prob, &pool, &measured);
        let best = searcher_best(&model, &pool, &Scorer::Native, &measured);
        let rank = pool
            .truth()
            .iter()
            .filter(|&&v| v < pool.truth_of(best))
            .count();
        assert!(rank < 30, "searcher pick should rank near the top, got {rank}");
    }
}
