//! Shared tuner infrastructure: the tuning problem, the sample pool
//! C_pool (§5), the collector, and the Tuner trait + searcher.

use std::collections::HashSet;

use crate::config::{Config, WorkflowId, F_MAX};
use crate::gbt::Ensemble;
use crate::sim::{Objective, WorkflowSim};
use crate::surrogate::{PoolFeatures, Scorer};
use crate::util::rng::Pcg32;
use crate::util::stats;

/// A tuning problem: one workflow, one optimization objective.
pub struct Problem {
    pub sim: WorkflowSim,
    pub objective: Objective,
}

impl Problem {
    pub fn new(id: WorkflowId, objective: Objective) -> Problem {
        Problem {
            sim: WorkflowSim::new(id),
            objective,
        }
    }

    /// Number of (real, unpadded) features in the whole-workflow view.
    pub fn n_workflow_features(&self) -> usize {
        self.sim.spec.n_params()
    }

    /// Per configurable component: its own feature count.
    pub fn n_component_features(&self) -> Vec<usize> {
        self.sim
            .spec
            .configurable()
            .into_iter()
            .map(|j| self.sim.spec.components[j].params.len())
            .collect()
    }
}

/// The sample pool C_pool (paper §5): a feasible random subset of the
/// configuration space from which all training samples are drawn, plus
/// the noise-free ground truth used as the experiment test set (§7.1
/// measures all 2000 pool configurations).
pub struct Pool {
    pub configs: Vec<Config>,
    pub feats: PoolFeatures,
    /// Noise-free objective value per config (the test-set measurement).
    pub truth: Vec<f64>,
    /// Index of the best configuration in the pool.
    pub best_idx: usize,
    /// Lazily built k-NN parameter graph (GEIST).
    knn: std::sync::OnceLock<Vec<Vec<usize>>>,
}

/// Pool size used by the paper (§7.1).
pub const POOL_SIZE: usize = 2000;

impl Pool {
    /// Generate a deduplicated feasible pool and measure its ground
    /// truth.  Deterministic in (problem, seed).
    pub fn generate(prob: &Problem, size: usize, seed: u64) -> Pool {
        let mut rng = Pcg32::new(seed, 0x9001);
        let spec = &prob.sim.spec;
        let mut seen: HashSet<Config> = HashSet::with_capacity(size * 2);
        let mut configs = Vec::with_capacity(size);
        let feasible = |c: &Config| prob.sim.feasible(c);
        while configs.len() < size {
            let c = spec.sample_feasible(&mut rng, &feasible, 100_000);
            if seen.insert(c.clone()) {
                configs.push(c);
            }
        }
        let feats = PoolFeatures::encode(spec, &configs);
        let truth: Vec<f64> = configs
            .iter()
            .map(|c| prob.objective.value(&prob.sim.expected(c)))
            .collect();
        let best_idx = stats::argmin(&truth).expect("non-empty pool");
        Pool {
            configs,
            feats,
            truth,
            best_idx,
            knn: std::sync::OnceLock::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    pub fn best_value(&self) -> f64 {
        self.truth[self.best_idx]
    }

    /// k-nearest-neighbor graph over normalized workflow features
    /// (GEIST's parameter graph; built once per pool).
    pub fn knn_graph(&self, k: usize) -> &Vec<Vec<usize>> {
        self.knn.get_or_init(|| {
            let n = self.len();
            let xs = &self.feats.workflow;
            let mut graph = Vec::with_capacity(n);
            for i in 0..n {
                let mut dists: Vec<(f64, usize)> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| {
                        let mut d = 0.0f64;
                        for f in 0..F_MAX {
                            let diff = (xs[i][f] - xs[j][f]) as f64;
                            d += diff * diff;
                        }
                        (d, j)
                    })
                    .collect();
                dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                graph.push(dists.into_iter().take(k).map(|(_, j)| j).collect());
            }
            graph
        })
    }
}

/// The collector (§2.1): runs the simulator and accounts for cost.
pub struct Collector<'a> {
    prob: &'a Problem,
    rng: Pcg32,
    /// Workflow runs performed.
    pub workflow_runs: usize,
    /// Component runs performed (isolated).
    pub component_runs: usize,
    /// Σ objective values over workflow training runs.
    pub workflow_cost: f64,
    /// Σ objective values over component training runs.
    pub component_cost: f64,
}

impl<'a> Collector<'a> {
    pub fn new(prob: &'a Problem, rng: Pcg32) -> Collector<'a> {
        Collector {
            prob,
            rng,
            workflow_runs: 0,
            component_runs: 0,
            workflow_cost: 0.0,
            component_cost: 0.0,
        }
    }

    /// Run the workflow at `cfg`, returning the measured objective.
    pub fn measure(&mut self, cfg: &Config) -> f64 {
        let m = self.prob.sim.run(cfg, &mut self.rng);
        let y = self.prob.objective.value(&m);
        self.workflow_runs += 1;
        self.workflow_cost += y;
        y
    }

    /// Run configurable component `comp` (index into the spec) alone.
    pub fn measure_component(&mut self, comp: usize, comp_cfg: &[i64]) -> f64 {
        let m = self.prob.sim.run_component(comp, comp_cfg, &mut self.rng);
        let y = self.prob.objective.value(&m);
        self.component_runs += 1;
        self.component_cost += y;
        y
    }

    /// Total collection cost (workflow + component runs) — the `c` of
    /// the least-number-of-uses metric (§7.2.3).
    pub fn total_cost(&self) -> f64 {
        self.workflow_cost + self.component_cost
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// What a tuner returns.
pub struct TunerOutput {
    /// Final high-fidelity surrogate model.
    pub model: Ensemble,
    /// Measured workflow samples: (pool index, measured objective).
    pub measured: Vec<(usize, f64)>,
    /// Searcher's pick: pool index with the best predicted objective.
    pub best_idx: usize,
    /// Total collection cost (incl. component runs unless historical).
    pub collection_cost: f64,
    /// Workflow runs actually performed.
    pub workflow_runs: usize,
}

/// An auto-tuning algorithm.
pub trait Tuner: Sync {
    fn name(&self) -> &'static str;

    /// Run one tuning campaign with a budget of `m` workflow-run
    /// equivalents, drawing randomness from `rng`.
    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput;
}

/// The searcher (§2.1): best configuration over the pool.  Model
/// predictions (log-space, exponentiated to times) are used for
/// unmeasured configurations; where a configuration was actually
/// measured, the observation replaces the model output — a tuner never
/// trusts a surrogate over data it already has.
pub fn searcher_best(
    model: &Ensemble,
    pool: &Pool,
    scorer: &Scorer,
    measured: &[(usize, f64)],
) -> usize {
    let mut scores: Vec<f64> = scorer
        .score(model, &pool.feats.workflow)
        .into_iter()
        .map(f64::exp)
        .collect();
    for &(i, y) in measured {
        scores[i] = y;
    }
    stats::argmin(&scores).expect("non-empty pool")
}

/// Train the workflow (high-fidelity) surrogate on measured samples.
/// Log-space: the returned ensemble predicts ln(objective); use
/// [`predict_times`] for real-scale estimates.
pub fn train_hifi(prob: &Problem, pool: &Pool, measured: &[(usize, f64)]) -> Ensemble {
    let xs: Vec<[f32; F_MAX]> = measured
        .iter()
        .map(|&(i, _)| pool.feats.workflow[i])
        .collect();
    let y: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
    let params = crate::gbt::GbtParams::small_data();
    crate::gbt::train_log(&xs, &y, prob.n_workflow_features(), &params)
}

/// Real-scale time predictions of a log-space model over rows.
pub fn predict_times(
    model: &Ensemble,
    xs: &[[f32; F_MAX]],
    scorer: &crate::surrogate::Scorer,
) -> Vec<f64> {
    scorer.score(model, xs).into_iter().map(f64::exp).collect()
}

/// Select `k` distinct unmeasured pool indices uniformly at random.
pub fn random_unmeasured(
    pool: &Pool,
    measured: &HashSet<usize>,
    k: usize,
    rng: &mut Pcg32,
) -> Vec<usize> {
    let available: Vec<usize> = (0..pool.len()).filter(|i| !measured.contains(i)).collect();
    assert!(available.len() >= k, "pool exhausted");
    rng.sample_indices(available.len(), k)
        .into_iter()
        .map(|i| available[i])
        .collect()
}

/// Select the `k` best-scoring unmeasured pool indices (scores are
/// lower-is-better), in ascending score order with index tie-breaks.
///
/// Partial selection: `select_nth_unstable_by` partitions the k best
/// candidates in O(pool), then only those k are sorted — the typical
/// call has k (a batch of a few samples) ≪ pool (2000 configs), where
/// a full sort wastes an O(pool·log pool) pass per iteration.  The
/// (score, index) comparator is total, so the selected set and its
/// final order are deterministic regardless of partition internals.
pub fn top_unmeasured(
    scores: &[f64],
    measured: &HashSet<usize>,
    k: usize,
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).filter(|i| !measured.contains(i)).collect();
    if k == 0 {
        idx.clear();
        return idx;
    }
    let by_score_then_index =
        |a: &usize, b: &usize| scores[*a].partial_cmp(&scores[*b]).unwrap().then(a.cmp(b));
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, by_score_then_index);
        idx.truncate(k);
    }
    idx.sort_unstable_by(by_score_then_index);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem() -> Problem {
        Problem::new(WorkflowId::Lv, Objective::ExecTime)
    }

    #[test]
    fn pool_generation_is_feasible_and_deterministic() {
        let prob = toy_problem();
        let a = Pool::generate(&prob, 50, 7);
        let b = Pool::generate(&prob, 50, 7);
        assert_eq!(a.configs, b.configs);
        for c in &a.configs {
            assert!(prob.sim.feasible(c));
            assert!(prob.sim.spec.validate(c).is_ok());
        }
        // dedup
        let set: HashSet<&Config> = a.configs.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(a.best_value() <= stats::quantile(&a.truth, 0.1));
    }

    #[test]
    fn different_seeds_differ() {
        let prob = toy_problem();
        let a = Pool::generate(&prob, 30, 1);
        let b = Pool::generate(&prob, 30, 2);
        assert_ne!(a.configs, b.configs);
    }

    #[test]
    fn knn_graph_shape() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 40, 3);
        let g = pool.knn_graph(5);
        assert_eq!(g.len(), 40);
        for (i, nbrs) in g.iter().enumerate() {
            assert_eq!(nbrs.len(), 5);
            assert!(!nbrs.contains(&i));
        }
        // cached: same pointer
        let g2 = pool.knn_graph(5);
        assert!(std::ptr::eq(g, g2));
    }

    #[test]
    fn collector_accounting() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 10, 4);
        let mut col = Collector::new(&prob, Pcg32::new(5, 5));
        let y = col.measure(&pool.configs[0]);
        assert!(y > 0.0);
        let yc = col.measure_component(0, prob.sim.spec.component_slice(&pool.configs[0], 0));
        assert!(yc > 0.0);
        assert_eq!(col.workflow_runs, 1);
        assert_eq!(col.component_runs, 1);
        assert!((col.total_cost() - y - yc).abs() < 1e-12);
    }

    #[test]
    fn selection_helpers() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 20, 6);
        let mut measured: HashSet<usize> = [0, 1, 2].into_iter().collect();
        let mut rng = Pcg32::new(8, 8);
        let r = random_unmeasured(&pool, &measured, 5, &mut rng);
        assert_eq!(r.len(), 5);
        assert!(r.iter().all(|i| !measured.contains(i)));

        let scores: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let t = top_unmeasured(&scores, &measured, 3);
        assert_eq!(t, vec![3, 4, 5]);
        measured.insert(4);
        let t2 = top_unmeasured(&scores, &measured, 3);
        assert_eq!(t2, vec![3, 5, 6]);
    }

    #[test]
    fn top_unmeasured_tie_break_and_bounds() {
        let measured: HashSet<usize> = HashSet::new();
        let scores = vec![1.0, 0.5, 0.5, 0.5, 2.0, 0.1];
        // ties broken by ascending index, deterministically
        assert_eq!(top_unmeasured(&scores, &measured, 3), vec![5, 1, 2]);
        assert_eq!(top_unmeasured(&scores, &measured, 0), Vec::<usize>::new());
        // k >= available returns everything, still fully sorted
        assert_eq!(top_unmeasured(&scores, &measured, 99), vec![5, 1, 2, 3, 0, 4]);
    }

    #[test]
    fn train_and_search() {
        let prob = toy_problem();
        let pool = Pool::generate(&prob, 60, 9);
        // measure 30 configs with the truth (no noise) and check the
        // searcher lands in a decent region
        let measured: Vec<(usize, f64)> = (0..30).map(|i| (i, pool.truth[i])).collect();
        let model = train_hifi(&prob, &pool, &measured);
        let best = searcher_best(&model, &pool, &Scorer::Native, &measured);
        let rank = pool
            .truth
            .iter()
            .filter(|&&v| v < pool.truth[best])
            .count();
        assert!(rank < 30, "searcher pick should rank near the top, got {rank}");
    }
}
