//! Record/replay of session measurement streams — the
//! [`Evaluator`](super::session::Evaluator) pair that turns any tuning
//! session into a reproducible artifact with zero new dependencies.
//!
//! A trace is a versioned JSON-lines file: one header line, then one
//! line per measurement batch carrying the requests and the observed
//! values.  [`TraceRecorder`] wraps a live evaluator and logs every
//! batch it answers; [`TraceReplayer`] serves a recorded stream back,
//! *verifying* that the session re-issues exactly the recorded
//! requests — so a successful replay certifies the session's
//! determinism contract, pins its behaviour bit-for-bit without a
//! simulator, and doubles as the snapshot/resume substrate for
//! `ceal tune --record/--replay` (replaying a trace reconstructs the
//! session's full internal state from the measurement history alone).
//!
//! Format (version 1):
//!
//! ```text
//! {"algo":"CEAL","format":"ceal-session-trace","m":10,"objective":"comp_time","pool":150,"scorer":"native","seed":"52897","version":1,"workflow":"CH5"}
//! {"batch":0,"mode":"seq","reqs":[{"cfg":[430,8],"comp":0}],"ys":[12.5]}
//! {"batch":1,"mode":"fanout","reqs":[{"pool":3},{"pool":17}],"ys":[101.25,99.5]}
//! ```
//!
//! Numbers round-trip exactly (shortest-round-trip float formatting on
//! write, strtod on read); the seed is a string because u64 seeds can
//! exceed f64's integer range.  A trace whose `version` differs from
//! [`TRACE_VERSION`] is rejected up front with a clear error rather
//! than replayed into garbage.

use std::io::Write;
use std::path::Path;

use crate::util::json::{self, Json};

use super::ceal::CealParams;
use super::session::{BatchMode, Evaluator, MeasurementBatch, MeasurementRequest, MeasurementResult};

/// The trace format version this build writes and reads.
pub const TRACE_VERSION: u64 = 1;

const TRACE_FORMAT: &str = "ceal-session-trace";

/// Trace metadata: everything needed to reconstruct the recorded
/// session (the pool is regenerated deterministically from
/// (workflow, objective, pool, seed); the session RNG from
/// (seed, algo)).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceHeader {
    pub algo: String,
    pub workflow: String,
    pub objective: String,
    /// Training-sample budget m of the recorded session.
    pub m: usize,
    pub pool_size: usize,
    pub seed: u64,
    /// Scoring backend the session ran with ("native" | "pjrt") —
    /// replay must use the same backend or the searcher/selection
    /// passes could diverge from the recorded run.
    pub scorer: String,
    /// CEAL/ALpH hyper-parameter overrides active at record time
    /// (`--iters/--m0/--mr`); `None` means the algorithm defaults.
    pub ceal_params: Option<CealParams>,
}

impl TraceHeader {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("format", Json::Str(TRACE_FORMAT.into())),
            ("version", Json::Num(TRACE_VERSION as f64)),
            ("algo", Json::Str(self.algo.clone())),
            ("workflow", Json::Str(self.workflow.clone())),
            ("objective", Json::Str(self.objective.clone())),
            ("m", Json::Num(self.m as f64)),
            ("pool", Json::Num(self.pool_size as f64)),
            ("seed", Json::Str(self.seed.to_string())),
            ("scorer", Json::Str(self.scorer.clone())),
        ];
        if let Some(p) = self.ceal_params {
            pairs.push((
                "params",
                Json::obj(vec![
                    ("iterations", Json::Num(p.iterations as f64)),
                    ("m0_frac", Json::Num(p.m0_frac)),
                    ("mr_frac", Json::Num(p.mr_frac)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<TraceHeader, String> {
        let str_field = |k: &str| -> Result<String, String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("trace header missing string field '{k}'"))
        };
        let num_field = |k: &str| -> Result<usize, String> {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("trace header missing numeric field '{k}'"))
        };
        let seed: u64 = str_field("seed")?
            .parse()
            .map_err(|e| format!("bad trace seed: {e}"))?;
        let ceal_params = match v.get("params") {
            None => None,
            Some(p) => Some(CealParams {
                iterations: p
                    .get("iterations")
                    .and_then(Json::as_usize)
                    .ok_or("bad params.iterations")?,
                m0_frac: p.get("m0_frac").and_then(Json::as_f64).ok_or("bad params.m0_frac")?,
                mr_frac: p.get("mr_frac").and_then(Json::as_f64).ok_or("bad params.mr_frac")?,
            }),
        };
        Ok(TraceHeader {
            algo: str_field("algo")?,
            workflow: str_field("workflow")?,
            objective: str_field("objective")?,
            m: num_field("m")?,
            pool_size: num_field("pool")?,
            seed,
            scorer: str_field("scorer")?,
            ceal_params,
        })
    }
}

fn mode_name(mode: BatchMode) -> &'static str {
    match mode {
        BatchMode::Sequential => "seq",
        BatchMode::FanOut => "fanout",
    }
}

fn request_json(req: &MeasurementRequest) -> Json {
    match req {
        MeasurementRequest::Workflow { pool_idx, .. } => {
            Json::obj(vec![("pool", Json::Num(*pool_idx as f64))])
        }
        MeasurementRequest::Component { comp, config } => Json::obj(vec![
            ("comp", Json::Num(*comp as f64)),
            (
                "cfg",
                Json::Arr(config.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
        ]),
    }
}

/// An [`Evaluator`] decorator that answers batches through `inner` and
/// appends each (requests, results) pair to a JSON-lines sink.
///
/// IO errors do not interrupt the tuning run (the `Evaluator` contract
/// has no error channel); the first one is held and surfaced by
/// [`finish`](Self::finish), and writing stops after it.
pub struct TraceRecorder<'e, W: Write> {
    inner: &'e mut dyn Evaluator,
    out: W,
    batches: u64,
    error: Option<std::io::Error>,
}

impl<'e, W: Write> TraceRecorder<'e, W> {
    /// Wrap `inner`, writing the header line immediately.
    pub fn new(
        inner: &'e mut dyn Evaluator,
        mut out: W,
        header: &TraceHeader,
    ) -> std::io::Result<TraceRecorder<'e, W>> {
        let mut line = header.to_json().compact();
        line.push('\n');
        out.write_all(line.as_bytes())?;
        Ok(TraceRecorder {
            inner,
            out,
            batches: 0,
            error: None,
        })
    }

    /// Batches recorded so far.
    pub fn batches_written(&self) -> u64 {
        self.batches
    }

    /// Flush and return the sink, or the first write error.
    pub fn finish(mut self) -> std::io::Result<W> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        self.out.flush()?;
        Ok(self.out)
    }
}

impl<W: Write> Evaluator for TraceRecorder<'_, W> {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        let results = self.inner.evaluate(batch);
        if self.error.is_none() {
            let line = Json::obj(vec![
                ("batch", Json::Num(self.batches as f64)),
                ("mode", Json::Str(mode_name(batch.mode).into())),
                (
                    "reqs",
                    Json::Arr(batch.requests.iter().map(request_json).collect()),
                ),
                (
                    "ys",
                    Json::arr_f64(&results.iter().map(|r| r.value).collect::<Vec<_>>()),
                ),
            ]);
            let mut text = line.compact();
            text.push('\n');
            if let Err(e) = self.out.write_all(text.as_bytes()) {
                self.error = Some(e);
            }
        }
        self.batches += 1;
        results
    }
}

/// A request as recorded in a trace (workflow requests are identified
/// by pool index alone — the pool regenerates deterministically from
/// the header, so configurations are not duplicated into the file).
#[derive(Clone, Debug, PartialEq)]
pub enum RecordedRequest {
    Workflow { pool_idx: usize },
    Component { comp: usize, config: Vec<i64> },
}

impl RecordedRequest {
    /// Does a live request match this recorded one?
    fn matches(&self, req: &MeasurementRequest) -> bool {
        match (self, req) {
            (
                RecordedRequest::Workflow { pool_idx },
                MeasurementRequest::Workflow { pool_idx: live, .. },
            ) => pool_idx == live,
            (
                RecordedRequest::Component { comp, config },
                MeasurementRequest::Component {
                    comp: live_comp,
                    config: live_cfg,
                },
            ) => comp == live_comp && config == live_cfg,
            _ => false,
        }
    }
}

/// One recorded measurement batch.
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedBatch {
    pub mode: BatchMode,
    pub requests: Vec<RecordedRequest>,
    pub values: Vec<f64>,
}

/// Replays a recorded measurement stream as an [`Evaluator`],
/// verifying batch-by-batch that the session issues exactly the
/// recorded requests.  A divergence means the trace belongs to a
/// different (seed, algorithm, build) and panics with the offending
/// batch rather than silently answering the wrong question.
pub struct TraceReplayer {
    pub header: TraceHeader,
    batches: Vec<RecordedBatch>,
    pos: usize,
}

impl TraceReplayer {
    /// Parse a whole trace document.
    pub fn parse(text: &str) -> Result<TraceReplayer, String> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, first) = lines.next().ok_or("empty trace file")?;
        let head = json::parse(first).map_err(|e| format!("trace header: {e}"))?;
        match head.get("format").and_then(Json::as_str) {
            Some(TRACE_FORMAT) => {}
            _ => return Err(format!("not a {TRACE_FORMAT} file")),
        }
        let version = head
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("trace header missing 'version'")? as u64;
        if version != TRACE_VERSION {
            return Err(format!(
                "unsupported session-trace version {version} (this build reads version \
                 {TRACE_VERSION}); re-record the trace with this binary"
            ));
        }
        let header = TraceHeader::from_json(&head)?;
        let mut batches = Vec::new();
        for (lineno, line) in lines {
            let v = json::parse(line).map_err(|e| format!("trace line {}: {e}", lineno + 1))?;
            batches.push(Self::parse_batch(&v, lineno + 1)?);
        }
        Ok(TraceReplayer {
            header,
            batches,
            pos: 0,
        })
    }

    fn parse_batch(v: &Json, lineno: usize) -> Result<RecordedBatch, String> {
        let mode = match v.get("mode").and_then(Json::as_str) {
            Some("seq") => BatchMode::Sequential,
            Some("fanout") => BatchMode::FanOut,
            other => return Err(format!("trace line {lineno}: bad mode {other:?}")),
        };
        let reqs = v
            .get("reqs")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("trace line {lineno}: missing 'reqs'"))?;
        let mut requests = Vec::with_capacity(reqs.len());
        for r in reqs {
            if let Some(idx) = r.get("pool").and_then(Json::as_usize) {
                requests.push(RecordedRequest::Workflow { pool_idx: idx });
            } else if let Some(comp) = r.get("comp").and_then(Json::as_usize) {
                let cfg = r
                    .get("cfg")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("trace line {lineno}: component request missing 'cfg'"))?
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as i64))
                    .collect::<Option<Vec<i64>>>()
                    .ok_or_else(|| format!("trace line {lineno}: non-numeric 'cfg'"))?;
                requests.push(RecordedRequest::Component { comp, config: cfg });
            } else {
                return Err(format!("trace line {lineno}: unrecognized request {r:?}"));
            }
        }
        let values: Vec<f64> = v
            .get("ys")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("trace line {lineno}: missing 'ys'"))?
            .iter()
            .map(|x| x.as_f64())
            .collect::<Option<Vec<f64>>>()
            .ok_or_else(|| format!("trace line {lineno}: non-numeric 'ys'"))?;
        if values.len() != requests.len() {
            return Err(format!(
                "trace line {lineno}: {} requests but {} values",
                requests.len(),
                values.len()
            ));
        }
        Ok(RecordedBatch {
            mode,
            requests,
            values,
        })
    }

    /// Load a trace from disk.
    pub fn load(path: &Path) -> Result<TraceReplayer, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
        TraceReplayer::parse(&text)
    }

    /// The recorded batches (for inspection and format tests).
    pub fn batches(&self) -> &[RecordedBatch] {
        &self.batches
    }

    /// Batches not yet served.  A clean replay ends at zero; a
    /// remainder means the replayed session diverged from (or was
    /// shorter than) the recorded one.
    pub fn remaining(&self) -> usize {
        self.batches.len() - self.pos
    }
}

impl Evaluator for TraceReplayer {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        let rec = self.batches.get(self.pos).unwrap_or_else(|| {
            panic!(
                "trace exhausted: session asked batch {} but the trace holds {} \
                 (seed/algorithm/build mismatch?)",
                self.pos,
                self.batches.len()
            )
        });
        assert_eq!(
            rec.mode, batch.mode,
            "replay divergence at batch {}: batch mode changed",
            self.pos
        );
        assert_eq!(
            rec.requests.len(),
            batch.len(),
            "replay divergence at batch {}: batch size changed",
            self.pos
        );
        for (k, (recorded, live)) in rec.requests.iter().zip(&batch.requests).enumerate() {
            assert!(
                recorded.matches(live),
                "replay divergence at batch {} request {k}: recorded {recorded:?}, \
                 session asked {live:?}",
                self.pos
            );
        }
        self.pos += 1;
        rec.values
            .iter()
            .map(|&value| MeasurementResult { value })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> TraceHeader {
        TraceHeader {
            algo: "CEAL".into(),
            workflow: "LV".into(),
            objective: "comp_time".into(),
            m: 10,
            pool_size: 100,
            seed: 0xCEA1,
            scorer: "native".into(),
            ceal_params: None,
        }
    }

    struct Fixed(f64);
    impl Evaluator for Fixed {
        fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
            batch
                .requests
                .iter()
                .map(|_| MeasurementResult { value: self.0 })
                .collect()
        }
    }

    fn wf_req(i: usize) -> MeasurementRequest {
        MeasurementRequest::Workflow {
            pool_idx: i,
            config: crate::config::Config(vec![]),
        }
    }

    #[test]
    fn record_then_replay_roundtrips() {
        let mut inner = Fixed(2.25);
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        let b0 = MeasurementBatch::sequential(vec![MeasurementRequest::Component {
            comp: 1,
            config: vec![4, 8],
        }]);
        let b1 = MeasurementBatch::fan_out(vec![wf_req(3), wf_req(17)]);
        let r0 = rec.evaluate(&b0);
        let r1 = rec.evaluate(&b1);
        assert_eq!(rec.batches_written(), 2);
        rec.finish().unwrap();

        let text = String::from_utf8(buf).unwrap();
        let mut rep = TraceReplayer::parse(&text).unwrap();
        assert_eq!(rep.header, header());
        assert_eq!(rep.batches().len(), 2);
        assert_eq!(rep.evaluate(&b0), r0);
        assert_eq!(rep.evaluate(&b1), r1);
        assert_eq!(rep.remaining(), 0);
    }

    #[test]
    #[should_panic(expected = "replay divergence")]
    fn replay_rejects_diverging_requests() {
        let mut inner = Fixed(1.0);
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        rec.evaluate(&MeasurementBatch::fan_out(vec![wf_req(3)]));
        rec.finish().unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut rep = TraceReplayer::parse(&text).unwrap();
        rep.evaluate(&MeasurementBatch::fan_out(vec![wf_req(4)]));
    }

    #[test]
    fn header_with_params_roundtrips() {
        let mut h = header();
        h.ceal_params = Some(CealParams {
            iterations: 4,
            m0_frac: 0.125,
            mr_frac: 0.25,
        });
        let parsed = TraceHeader::from_json(&json::parse(&h.to_json().compact()).unwrap()).unwrap();
        assert_eq!(parsed, h);
    }

    #[test]
    fn wrong_format_and_version_are_rejected() {
        assert!(TraceReplayer::parse("{\"hello\": 1}")
            .unwrap_err()
            .contains("not a ceal-session-trace"));
        let mut h = header().to_json().compact();
        h = h.replace("\"version\":1", "\"version\":2");
        let err = TraceReplayer::parse(&h).unwrap_err();
        assert!(err.contains("version 2"), "{err}");
        assert!(err.contains("re-record"), "{err}");
    }
}
