//! Record/replay of session measurement streams — the
//! [`Evaluator`](super::session::Evaluator) pair that turns any tuning
//! session into a reproducible artifact with zero new dependencies.
//!
//! A trace is a versioned JSON-lines file: one header line, then one
//! line per measurement batch carrying the requests and the observed
//! outcomes.  [`TraceRecorder`] wraps a live evaluator and logs every
//! batch it answers; [`TraceReplayer`] serves a recorded stream back,
//! *verifying* that the session re-issues exactly the recorded
//! requests — so a successful replay certifies the session's
//! determinism contract, pins its behaviour bit-for-bit without a
//! simulator, and doubles as the snapshot/resume substrate for
//! `ceal tune --record/--replay` (replaying a trace reconstructs the
//! session's full internal state from the measurement history alone).
//!
//! Format (version 2):
//!
//! ```text
//! {"algo":"CEAL","format":"ceal-session-trace","m":10,"objective":"comp_time","pool":150,"scorer":"native","seed":"52897","version":2,"workflow":"CH5"}
//! {"batch":0,"mode":"seq","reqs":[{"cfg":[430,8],"comp":0}],"ys":[12.5]}
//! {"batch":1,"mode":"fanout","reqs":[{"pool":3},{"pool":17}],"ys":[101.25,"crash"]}
//! ```
//!
//! Version 2 extends version 1 with fault-tolerant measurement
//! outcomes: a `ys` entry is either a number (a delivered reading) or
//! one of the strings `"crash"`, `"transport"`, `"corrupt"`,
//! `"timeout"` (a failed attempt — see
//! [`MeasurementOutcome`]); and the header may carry a `faults` object
//! recording the [`FaultSpec`] the run was injected with, so `--replay`
//! re-arms the same failure-handling policy.  Version-1 traces (all
//! `ys` numeric, no `faults`) parse unchanged; this build *writes*
//! version 2.
//!
//! Numbers round-trip exactly (shortest-round-trip float formatting on
//! write, strtod on read); the seed is a string because u64 seeds can
//! exceed f64's integer range.  A trace whose `version` is newer than
//! [`TRACE_VERSION`] is rejected up front with a clear
//! [`TraceError::Version`] rather than replayed into garbage.  Replay
//! mismatches no longer panic: the replayer *latches* the first
//! [`TraceError`] (divergence, exhaustion), answers that batch — and
//! every later one — with transport failures so the session can wind
//! down through its normal failure handling, and surfaces the error
//! through [`TraceReplayer::error`] for the caller to report.

use std::io::Write;
use std::path::Path;

use crate::sim::{FailureKind, MeasurementOutcome};
use crate::util::json::{self, Json};

use super::ceal::CealParams;
use super::faults::{FaultPlan, FaultSpec};
use super::session::{BatchMode, Evaluator, MeasurementBatch, MeasurementRequest, MeasurementResult};

/// The trace format version this build writes.
pub const TRACE_VERSION: u64 = 2;

/// The oldest trace format version this build still reads.
pub const TRACE_MIN_VERSION: u64 = 1;

const TRACE_FORMAT: &str = "ceal-session-trace";

/// Everything that can go wrong loading or replaying a trace.  The
/// replayer's [`Evaluator`] impl cannot return errors (the trait has no
/// error channel), so replay-time variants are latched on the replayer
/// and surfaced after the run via [`TraceReplayer::error`].
#[derive(Clone, Debug, PartialEq)]
pub enum TraceError {
    /// The file could not be read.
    Io(String),
    /// The file is not a session trace at all.
    NotATrace(String),
    /// A trace from an incompatible (newer or pre-release) format.
    Version(u64),
    /// A structurally invalid header or batch line.
    Malformed(String),
    /// The session asked for more batches than the trace holds.
    Exhausted { asked: usize, have: usize },
    /// The session issued a different batch than was recorded.
    Divergence { batch: usize, detail: String },
    /// A CRC-sealed journal/snapshot record whose checksum does not
    /// match its content (bit rot or a torn write that still parses).
    Crc { context: String },
    /// A resumed session's rebuilt state digest differs from the
    /// checkpointed one — the checkpoint belongs to a different
    /// (seed, algorithm, build).
    StateMismatch { detail: String },
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => f.write_str(e),
            TraceError::NotATrace(e) => f.write_str(e),
            TraceError::Version(v) => write!(
                f,
                "unsupported session-trace version {v} (this build reads versions \
                 {TRACE_MIN_VERSION}-{TRACE_VERSION}); re-record the trace with this binary"
            ),
            TraceError::Malformed(e) => f.write_str(e),
            TraceError::Exhausted { asked, have } => write!(
                f,
                "trace exhausted: session asked batch {asked} but the trace holds {have} \
                 (seed/algorithm/build mismatch?)"
            ),
            TraceError::Divergence { batch, detail } => {
                write!(f, "replay divergence at batch {batch}: {detail}")
            }
            TraceError::Crc { context } => {
                write!(f, "CRC mismatch in {context}: record is corrupted")
            }
            TraceError::StateMismatch { detail } => write!(
                f,
                "resume state mismatch: {detail} (checkpoint from a different \
                 seed/algorithm/build?)"
            ),
        }
    }
}

impl std::error::Error for TraceError {}

/// Trace metadata: everything needed to reconstruct the recorded
/// session (the pool is regenerated deterministically from
/// (workflow, objective, pool, seed); the session RNG from
/// (seed, algo); the fault schedule from `faults`).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceHeader {
    pub algo: String,
    pub workflow: String,
    pub objective: String,
    /// Training-sample budget m of the recorded session.
    pub m: usize,
    pub pool_size: usize,
    pub seed: u64,
    /// Scoring backend the session ran with ("native" | "pjrt") —
    /// replay must use the same backend or the searcher/selection
    /// passes could diverge from the recorded run.
    pub scorer: String,
    /// CEAL/ALpH hyper-parameter overrides active at record time
    /// (`--iters/--m0/--mr`); `None` means the algorithm defaults.
    pub ceal_params: Option<CealParams>,
    /// Fault-injection provenance (`--faults`): recorded so a replayed
    /// session arms the same failure-handling policy that shaped the
    /// recorded request stream.  `None` for fault-free runs and all
    /// version-1 traces.
    pub faults: Option<FaultSpec>,
}

impl TraceHeader {
    pub(crate) fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("format", Json::Str(TRACE_FORMAT.into())),
            ("version", Json::Num(TRACE_VERSION as f64)),
            ("algo", Json::Str(self.algo.clone())),
            ("workflow", Json::Str(self.workflow.clone())),
            ("objective", Json::Str(self.objective.clone())),
            ("m", Json::Num(self.m as f64)),
            ("pool", Json::Num(self.pool_size as f64)),
            ("seed", Json::Str(self.seed.to_string())),
            ("scorer", Json::Str(self.scorer.clone())),
        ];
        if let Some(p) = self.ceal_params {
            pairs.push((
                "params",
                Json::obj(vec![
                    ("iterations", Json::Num(p.iterations as f64)),
                    ("m0_frac", Json::Num(p.m0_frac)),
                    ("mr_frac", Json::Num(p.mr_frac)),
                ]),
            ));
        }
        if let Some(spec) = &self.faults {
            let mut fp = vec![
                ("p_fail", Json::Num(spec.plan.p_fail)),
                ("p_timeout", Json::Num(spec.plan.p_timeout)),
                ("p_straggle", Json::Num(spec.plan.p_straggle)),
                ("straggler_mult", Json::Num(spec.plan.straggler_mult)),
                ("p_corrupt", Json::Num(spec.plan.p_corrupt)),
                ("corrupt_mult", Json::Num(spec.plan.corrupt_mult)),
                ("seed", Json::Str(spec.seed.to_string())),
            ];
            if let Some(t) = spec.plan.target_component {
                fp.push(("target", Json::Num(t as f64)));
            }
            pairs.push(("faults", Json::obj(fp)));
        }
        Json::obj(pairs)
    }

    pub(crate) fn from_json(v: &Json) -> Result<TraceHeader, TraceError> {
        let str_field = |k: &str| -> Result<String, TraceError> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| {
                    TraceError::Malformed(format!("trace header missing string field '{k}'"))
                })
        };
        let num_field = |k: &str| -> Result<usize, TraceError> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| {
                TraceError::Malformed(format!("trace header missing numeric field '{k}'"))
            })
        };
        let seed: u64 = str_field("seed")?
            .parse()
            .map_err(|e| TraceError::Malformed(format!("bad trace seed: {e}")))?;
        let bad = |k: &str| TraceError::Malformed(format!("bad params.{k}"));
        let ceal_params = match v.get("params") {
            None => None,
            Some(p) => Some(CealParams {
                iterations: p
                    .get("iterations")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| bad("iterations"))?,
                m0_frac: p
                    .get("m0_frac")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad("m0_frac"))?,
                mr_frac: p
                    .get("mr_frac")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad("mr_frac"))?,
            }),
        };
        let faults = match v.get("faults") {
            None => None,
            Some(fj) => {
                let fbad =
                    |k: &str| TraceError::Malformed(format!("bad faults.{k} in trace header"));
                let f64_field = |k: &str| -> Result<f64, TraceError> {
                    fj.get(k).and_then(Json::as_f64).ok_or_else(|| fbad(k))
                };
                let fseed: u64 = fj
                    .get("seed")
                    .and_then(Json::as_str)
                    .ok_or_else(|| fbad("seed"))?
                    .parse()
                    .map_err(|_| fbad("seed"))?;
                Some(FaultSpec {
                    plan: FaultPlan {
                        p_fail: f64_field("p_fail")?,
                        p_timeout: f64_field("p_timeout")?,
                        p_straggle: f64_field("p_straggle")?,
                        straggler_mult: f64_field("straggler_mult")?,
                        p_corrupt: f64_field("p_corrupt")?,
                        corrupt_mult: f64_field("corrupt_mult")?,
                        target_component: fj.get("target").and_then(Json::as_usize),
                    },
                    seed: fseed,
                })
            }
        };
        Ok(TraceHeader {
            algo: str_field("algo")?,
            workflow: str_field("workflow")?,
            objective: str_field("objective")?,
            m: num_field("m")?,
            pool_size: num_field("pool")?,
            seed,
            scorer: str_field("scorer")?,
            ceal_params,
            faults,
        })
    }
}

pub(crate) fn mode_name(mode: BatchMode) -> &'static str {
    match mode {
        BatchMode::Sequential => "seq",
        BatchMode::FanOut => "fanout",
    }
}

pub(crate) fn mode_from_name(name: Option<&str>) -> Result<BatchMode, String> {
    match name {
        Some("seq") => Ok(BatchMode::Sequential),
        Some("fanout") => Ok(BatchMode::FanOut),
        other => Err(format!("bad mode {other:?}")),
    }
}

pub(crate) fn request_json(req: &MeasurementRequest) -> Json {
    match req {
        MeasurementRequest::Workflow { pool_idx, .. } => {
            Json::obj(vec![("pool", Json::Num(*pool_idx as f64))])
        }
        MeasurementRequest::Component { comp, config } => Json::obj(vec![
            ("comp", Json::Num(*comp as f64)),
            (
                "cfg",
                Json::Arr(config.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
        ]),
    }
}

/// A `ys` entry: a number for a delivered reading, a stable fault name
/// string otherwise.
pub(crate) fn outcome_json(o: &MeasurementOutcome) -> Json {
    match o.value() {
        Some(v) => Json::Num(v),
        None => Json::Str(
            o.fault_name()
                .expect("non-ok outcomes have fault names")
                .into(),
        ),
    }
}

pub(crate) fn outcome_from_json(v: &Json) -> Option<MeasurementOutcome> {
    match v {
        Json::Num(y) => Some(MeasurementOutcome::Ok(*y)),
        Json::Str(name) => MeasurementOutcome::from_fault_name(name),
        _ => None,
    }
}

/// An [`Evaluator`] decorator that answers batches through `inner` and
/// appends each (requests, results) pair to a JSON-lines sink.
///
/// IO errors do not interrupt the tuning run (the `Evaluator` contract
/// has no error channel); the first one is held and surfaced by
/// [`finish`](Self::finish), and writing stops after it.
pub struct TraceRecorder<'e, W: Write> {
    inner: &'e mut dyn Evaluator,
    out: W,
    batches: u64,
    error: Option<std::io::Error>,
}

impl<'e, W: Write> TraceRecorder<'e, W> {
    /// Wrap `inner`, writing the header line immediately.
    pub fn new(
        inner: &'e mut dyn Evaluator,
        mut out: W,
        header: &TraceHeader,
    ) -> std::io::Result<TraceRecorder<'e, W>> {
        let mut line = header.to_json().compact();
        line.push('\n');
        out.write_all(line.as_bytes())?;
        Ok(TraceRecorder {
            inner,
            out,
            batches: 0,
            error: None,
        })
    }

    /// Batches recorded so far.
    pub fn batches_written(&self) -> u64 {
        self.batches
    }

    /// Flush and return the sink, or the first write error.
    pub fn finish(mut self) -> std::io::Result<W> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        self.out.flush()?;
        Ok(self.out)
    }
}

impl<W: Write> Evaluator for TraceRecorder<'_, W> {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        let results = self.inner.evaluate(batch);
        if self.error.is_none() {
            let line = Json::obj(vec![
                ("batch", Json::Num(self.batches as f64)),
                ("mode", Json::Str(mode_name(batch.mode).into())),
                (
                    "reqs",
                    Json::Arr(batch.requests.iter().map(request_json).collect()),
                ),
                (
                    "ys",
                    Json::Arr(results.iter().map(|r| outcome_json(&r.outcome)).collect()),
                ),
            ]);
            let mut text = line.compact();
            text.push('\n');
            if let Err(e) = self.out.write_all(text.as_bytes()) {
                self.error = Some(e);
            }
        }
        self.batches += 1;
        results
    }

    fn checkpoint_state(&mut self) -> Option<super::session::EvaluatorState> {
        self.inner.checkpoint_state()
    }

    fn restore_state(&mut self, state: &super::session::EvaluatorState) -> bool {
        self.inner.restore_state(state)
    }

    fn note_replayed(&mut self, req: &MeasurementRequest) {
        self.inner.note_replayed(req);
    }
}

/// A request as recorded in a trace (workflow requests are identified
/// by pool index alone — the pool regenerates deterministically from
/// the header, so configurations are not duplicated into the file).
#[derive(Clone, Debug, PartialEq)]
pub enum RecordedRequest {
    Workflow { pool_idx: usize },
    Component { comp: usize, config: Vec<i64> },
}

impl RecordedRequest {
    /// The recorded form of a live request (what the journal persists).
    pub(crate) fn of(req: &MeasurementRequest) -> RecordedRequest {
        match req {
            MeasurementRequest::Workflow { pool_idx, .. } => RecordedRequest::Workflow {
                pool_idx: *pool_idx,
            },
            MeasurementRequest::Component { comp, config } => RecordedRequest::Component {
                comp: *comp,
                config: config.clone(),
            },
        }
    }

    /// Does a live request match this recorded one?
    pub(crate) fn matches(&self, req: &MeasurementRequest) -> bool {
        match (self, req) {
            (
                RecordedRequest::Workflow { pool_idx },
                MeasurementRequest::Workflow { pool_idx: live, .. },
            ) => pool_idx == live,
            (
                RecordedRequest::Component { comp, config },
                MeasurementRequest::Component {
                    comp: live_comp,
                    config: live_cfg,
                },
            ) => comp == live_comp && config == live_cfg,
            _ => false,
        }
    }
}

/// One recorded measurement batch.
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedBatch {
    pub mode: BatchMode,
    pub requests: Vec<RecordedRequest>,
    pub outcomes: Vec<MeasurementOutcome>,
}

/// Parse a `reqs` array (shared by trace batch lines and journal
/// records); errors carry no line context — callers add it.
pub(crate) fn parse_recorded_requests(v: Option<&Json>) -> Result<Vec<RecordedRequest>, String> {
    let reqs = v.and_then(Json::as_arr).ok_or("missing 'reqs'")?;
    let mut requests = Vec::with_capacity(reqs.len());
    for r in reqs {
        if let Some(idx) = r.get("pool").and_then(Json::as_usize) {
            requests.push(RecordedRequest::Workflow { pool_idx: idx });
        } else if let Some(comp) = r.get("comp").and_then(Json::as_usize) {
            let cfg = r
                .get("cfg")
                .and_then(Json::as_arr)
                .ok_or("component request missing 'cfg'")?
                .iter()
                .map(|x| x.as_f64().map(|f| f as i64))
                .collect::<Option<Vec<i64>>>()
                .ok_or("non-numeric 'cfg'")?;
            requests.push(RecordedRequest::Component { comp, config: cfg });
        } else {
            return Err(format!("unrecognized request {r:?}"));
        }
    }
    Ok(requests)
}

/// Parse a `ys` array (shared by trace batch lines and journal
/// records).
pub(crate) fn parse_outcomes(v: Option<&Json>) -> Result<Vec<MeasurementOutcome>, String> {
    v.and_then(Json::as_arr)
        .ok_or("missing 'ys'")?
        .iter()
        .map(outcome_from_json)
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| "unrecognized 'ys' entry".to_string())
}

/// Replays a recorded measurement stream as an [`Evaluator`],
/// verifying batch-by-batch that the session issues exactly the
/// recorded requests.  A divergence means the trace belongs to a
/// different (seed, algorithm, build); instead of panicking, the
/// replayer latches a [`TraceError`], answers the offending batch (and
/// every later one) with transport failures so the session can wind
/// down through its normal failure handling, and reports through
/// [`error`](Self::error).
pub struct TraceReplayer {
    pub header: TraceHeader,
    batches: Vec<RecordedBatch>,
    pos: usize,
    error: Option<TraceError>,
}

impl TraceReplayer {
    /// Parse a whole trace document.
    pub fn parse(text: &str) -> Result<TraceReplayer, TraceError> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, first) = lines
            .next()
            .ok_or_else(|| TraceError::NotATrace("empty trace file".into()))?;
        let head = json::parse(first)
            .map_err(|e| TraceError::NotATrace(format!("trace header: {e}")))?;
        match head.get("format").and_then(Json::as_str) {
            Some(TRACE_FORMAT) => {}
            _ => return Err(TraceError::NotATrace(format!("not a {TRACE_FORMAT} file"))),
        }
        let version = head
            .get("version")
            .and_then(Json::as_f64)
            .ok_or_else(|| TraceError::Malformed("trace header missing 'version'".into()))?
            as u64;
        if !(TRACE_MIN_VERSION..=TRACE_VERSION).contains(&version) {
            return Err(TraceError::Version(version));
        }
        let header = TraceHeader::from_json(&head)?;
        let mut batches = Vec::new();
        for (lineno, line) in lines {
            let v = json::parse(line)
                .map_err(|e| TraceError::Malformed(format!("trace line {}: {e}", lineno + 1)))?;
            batches.push(Self::parse_batch(&v, lineno + 1)?);
        }
        Ok(TraceReplayer {
            header,
            batches,
            pos: 0,
            error: None,
        })
    }

    fn parse_batch(v: &Json, lineno: usize) -> Result<RecordedBatch, TraceError> {
        let bad = |msg: String| TraceError::Malformed(format!("trace line {lineno}: {msg}"));
        let mode = mode_from_name(v.get("mode").and_then(Json::as_str)).map_err(&bad)?;
        let requests = parse_recorded_requests(v.get("reqs")).map_err(&bad)?;
        let outcomes = parse_outcomes(v.get("ys")).map_err(&bad)?;
        if outcomes.len() != requests.len() {
            return Err(bad(format!(
                "{} requests but {} outcomes",
                requests.len(),
                outcomes.len()
            )));
        }
        Ok(RecordedBatch {
            mode,
            requests,
            outcomes,
        })
    }

    /// Load a trace from disk.
    pub fn load(path: &Path) -> Result<TraceReplayer, TraceError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| TraceError::Io(format!("cannot read trace {}: {e}", path.display())))?;
        TraceReplayer::parse(&text)
    }

    /// The recorded batches (for inspection and format tests).
    pub fn batches(&self) -> &[RecordedBatch] {
        &self.batches
    }

    /// Batches not yet served.  A clean replay ends at zero; a
    /// remainder means the replayed session diverged from (or was
    /// shorter than) the recorded one.
    pub fn remaining(&self) -> usize {
        self.batches.len() - self.pos
    }

    /// The first replay mismatch, if any.  Once set, every subsequent
    /// batch is answered with transport failures.
    pub fn error(&self) -> Option<&TraceError> {
        self.error.as_ref()
    }

    /// Check a live batch against the recorded one; `Ok` carries the
    /// recorded outcomes.
    fn check(&mut self, batch: &MeasurementBatch) -> Result<Vec<MeasurementOutcome>, TraceError> {
        if self.pos >= self.batches.len() {
            return Err(TraceError::Exhausted {
                asked: self.pos,
                have: self.batches.len(),
            });
        }
        let rec = &self.batches[self.pos];
        if rec.mode != batch.mode {
            return Err(TraceError::Divergence {
                batch: self.pos,
                detail: "batch mode changed".into(),
            });
        }
        if rec.requests.len() != batch.len() {
            return Err(TraceError::Divergence {
                batch: self.pos,
                detail: format!(
                    "batch size changed (recorded {}, session asked {})",
                    rec.requests.len(),
                    batch.len()
                ),
            });
        }
        for (k, (recorded, live)) in rec.requests.iter().zip(&batch.requests).enumerate() {
            if !recorded.matches(live) {
                return Err(TraceError::Divergence {
                    batch: self.pos,
                    detail: format!("request {k}: recorded {recorded:?}, session asked {live:?}"),
                });
            }
        }
        self.pos += 1;
        Ok(self.batches[self.pos - 1].outcomes.clone())
    }
}

impl Evaluator for TraceReplayer {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        if self.error.is_none() {
            match self.check(batch) {
                Ok(outcomes) => {
                    return outcomes
                        .into_iter()
                        .map(|outcome| MeasurementResult { outcome })
                        .collect()
                }
                Err(e) => self.error = Some(e),
            }
        }
        // latched error: starve the session with transport failures so
        // it winds down through its normal failure handling
        batch
            .requests
            .iter()
            .map(|_| MeasurementResult::failed(FailureKind::Transport))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> TraceHeader {
        TraceHeader {
            algo: "CEAL".into(),
            workflow: "LV".into(),
            objective: "comp_time".into(),
            m: 10,
            pool_size: 100,
            seed: 0xCEA1,
            scorer: "native".into(),
            ceal_params: None,
            faults: None,
        }
    }

    struct Fixed(f64);
    impl Evaluator for Fixed {
        fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
            batch
                .requests
                .iter()
                .map(|_| MeasurementResult::ok(self.0))
                .collect()
        }
    }

    fn wf_req(i: usize) -> MeasurementRequest {
        MeasurementRequest::Workflow {
            pool_idx: i,
            config: crate::config::Config(vec![]),
        }
    }

    #[test]
    fn record_then_replay_roundtrips() {
        let mut inner = Fixed(2.25);
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        let b0 = MeasurementBatch::sequential(vec![MeasurementRequest::Component {
            comp: 1,
            config: vec![4, 8],
        }]);
        let b1 = MeasurementBatch::fan_out(vec![wf_req(3), wf_req(17)]);
        let r0 = rec.evaluate(&b0);
        let r1 = rec.evaluate(&b1);
        assert_eq!(rec.batches_written(), 2);
        rec.finish().unwrap();

        let text = String::from_utf8(buf).unwrap();
        let mut rep = TraceReplayer::parse(&text).unwrap();
        assert_eq!(rep.header, header());
        assert_eq!(rep.batches().len(), 2);
        assert_eq!(rep.evaluate(&b0), r0);
        assert_eq!(rep.evaluate(&b1), r1);
        assert_eq!(rep.remaining(), 0);
        assert_eq!(rep.error(), None);
    }

    /// Failed outcomes survive the write→parse→replay round trip
    /// bit-exactly, as fault-name strings in `ys`.
    #[test]
    fn faulted_outcomes_roundtrip() {
        struct Flaky;
        impl Evaluator for Flaky {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                batch
                    .requests
                    .iter()
                    .enumerate()
                    .map(|(k, _)| match k % 4 {
                        0 => MeasurementResult::ok(1.0 + k as f64),
                        1 => MeasurementResult::failed(FailureKind::Crash),
                        2 => MeasurementResult::timed_out(),
                        _ => MeasurementResult::failed(FailureKind::CorruptedReading),
                    })
                    .collect()
            }
        }
        let mut inner = Flaky;
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        let b = MeasurementBatch::fan_out((0..5).map(wf_req).collect());
        let recorded = rec.evaluate(&b);
        rec.finish().unwrap();

        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("\"crash\""), "{text}");
        assert!(text.contains("\"timeout\""), "{text}");
        let mut rep = TraceReplayer::parse(&text).unwrap();
        assert_eq!(rep.evaluate(&b), recorded);
        assert_eq!(rep.error(), None);
    }

    /// A diverging session no longer panics: the replayer latches the
    /// error, answers with transport failures, and reports it.
    #[test]
    fn replay_latches_divergence_as_error() {
        let mut inner = Fixed(1.0);
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        rec.evaluate(&MeasurementBatch::fan_out(vec![wf_req(3)]));
        rec.finish().unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut rep = TraceReplayer::parse(&text).unwrap();
        let results = rep.evaluate(&MeasurementBatch::fan_out(vec![wf_req(4)]));
        assert_eq!(results.len(), 1);
        assert!(!results[0].is_ok());
        let err = rep.error().expect("divergence latched").to_string();
        assert!(err.contains("replay divergence at batch 0"), "{err}");
        // later batches keep failing instead of serving wrong answers
        let more = rep.evaluate(&MeasurementBatch::fan_out(vec![wf_req(3)]));
        assert!(!more[0].is_ok());
    }

    /// Over-reading a trace latches an exhaustion error instead of
    /// panicking.
    #[test]
    fn over_reading_latches_exhausted() {
        let mut inner = Fixed(1.0);
        let mut buf: Vec<u8> = Vec::new();
        let mut rec = TraceRecorder::new(&mut inner, &mut buf, &header()).unwrap();
        let b = MeasurementBatch::fan_out(vec![wf_req(3)]);
        rec.evaluate(&b);
        rec.finish().unwrap();
        let mut rep = TraceReplayer::parse(&String::from_utf8(buf).unwrap()).unwrap();
        rep.evaluate(&b);
        assert_eq!(rep.error(), None);
        let extra = rep.evaluate(&b);
        assert!(!extra[0].is_ok());
        assert_eq!(
            rep.error(),
            Some(&TraceError::Exhausted { asked: 1, have: 1 })
        );
    }

    #[test]
    fn header_with_params_and_faults_roundtrips() {
        let mut h = header();
        h.ceal_params = Some(CealParams {
            iterations: 4,
            m0_frac: 0.125,
            mr_frac: 0.25,
        });
        h.faults = Some(FaultSpec {
            plan: FaultPlan::transient(0.25, 0.0625),
            seed: u64::MAX - 1,
        });
        let parsed = TraceHeader::from_json(&json::parse(&h.to_json().compact()).unwrap()).unwrap();
        assert_eq!(parsed, h);
    }

    /// Version-1 traces (all-numeric `ys`, no `faults`) still parse.
    #[test]
    fn version_1_traces_still_parse() {
        let text = "\
{\"algo\":\"RS\",\"format\":\"ceal-session-trace\",\"m\":2,\"objective\":\"comp_time\",\
\"pool\":50,\"scorer\":\"native\",\"seed\":\"7\",\"version\":1,\"workflow\":\"LV\"}\n\
{\"batch\":0,\"mode\":\"seq\",\"reqs\":[{\"pool\":3},{\"pool\":9}],\"ys\":[12.5,101.25]}\n";
        let rep = TraceReplayer::parse(text).unwrap();
        assert_eq!(rep.header.faults, None);
        assert_eq!(
            rep.batches()[0].outcomes,
            vec![MeasurementOutcome::Ok(12.5), MeasurementOutcome::Ok(101.25)]
        );
    }

    #[test]
    fn wrong_format_and_version_are_rejected() {
        assert!(TraceReplayer::parse("{\"hello\": 1}")
            .unwrap_err()
            .to_string()
            .contains("not a ceal-session-trace"));
        let mut h = header().to_json().compact();
        h = h.replace("\"version\":2", "\"version\":3");
        let err = TraceReplayer::parse(&h).unwrap_err();
        assert_eq!(err, TraceError::Version(3));
        let msg = err.to_string();
        assert!(msg.contains("version 3"), "{msg}");
        assert!(msg.contains("re-record"), "{msg}");
    }
}
