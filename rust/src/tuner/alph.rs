//! ALpH — the learned-combiner variant of CEAL (paper §4): instead of
//! combining component predictions with the structure function
//! (max/sum), ALpH *trains* a combining model M_0 on tuples
//! ({P_j(c)}, p) where p is the measured workflow performance — so its
//! low-fidelity model costs workflow runs to build and retrain, which
//! is exactly the deficiency §7.5.2 quantifies.
//!
//! Session shape mirrors CEAL's: one sequential component batch (when
//! m_R > 0), then one fan-out `C_meas` batch per iteration; both the
//! high-fidelity model and the combiner retrain after every told
//! batch.

use std::sync::Arc;

use super::ceal::{gbt_params_for, CealParams};
use super::common::{
    random_unmeasured, searcher_best, top_unmeasured_model, Pool, Problem, TopK,
    Tuner, TunerOutput,
};
use super::session::{
    sample_component_requests, triage_results, DiagSink, FailurePolicy, MeasurementBatch,
    MeasurementRequest, MeasurementResult, SessionCore, SessionDigest, SessionState, TunerSession,
};
use crate::config::F_MAX;
use crate::gbt::{train_log, Ensemble, IncrementalTrainer};
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::ComponentSamples;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct Alph {
    pub params: CealParams,
    pub historical: Option<Arc<Vec<ComponentSamples>>>,
}

impl Alph {
    pub fn new(params: CealParams) -> Alph {
        Alph {
            params,
            historical: None,
        }
    }

    pub fn with_historical(params: CealParams, hist: Arc<Vec<ComponentSamples>>) -> Alph {
        Alph {
            params,
            historical: Some(hist),
        }
    }
}

/// Component-prediction features for the combiner: row i carries
/// P_1(c_i)..P_J(c_i), zero-padded to F_MAX.  (Crate-visible so the
/// frozen [`super::legacy`] reference path shares the encoding.)
pub(crate) fn combiner_features(per_comp_preds: &[Vec<f64>], idx: usize) -> [f32; F_MAX] {
    let mut x = [0f32; F_MAX];
    for (j, preds) in per_comp_preds.iter().enumerate() {
        x[j] = preds[idx] as f32;
    }
    x
}

impl Tuner for Alph {
    fn name(&self) -> &'static str {
        "ALpH"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        let p = self.params;
        let m = m.min(pool.len());
        let m_r = if self.historical.is_some() {
            0
        } else {
            (m as f64 * p.mr_frac).round() as usize
        };
        let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
        let remaining = m.saturating_sub(m0 + m_r);
        let iters = p.iterations.clamp(1, remaining.max(1));
        let m_b = (remaining / iters).max(1);
        Box::new(AlphSession {
            tuner: self,
            core: SessionCore::new(prob, pool, scorer, rng),
            m_r,
            m0,
            iters,
            m_b,
            samples: Vec::new(),
            per_comp_preds: Vec::new(),
            using_hifi: false,
            hifi: None,
            combiner: None,
            combiner_fit: IncrementalTrainer::new(),
            c_meas: Vec::new(),
            iter: 0,
            phase: Phase::Components,
            pending: Pending::None,
            comps_sampled: false,
            comp_retry: Vec::new(),
            batch_retry: Vec::new(),
            gate_q: Vec::new(),
            round_ok: Vec::new(),
        })
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    Components,
    Workflow,
    Done,
}

/// An in-flight isolated component run (see the CEAL counterpart).
struct CompAttempt {
    slot: usize,
    x: [f32; F_MAX],
    req: MeasurementRequest,
}

enum Pending {
    None,
    Components(Vec<(CompAttempt, usize)>),
    /// (pool index, attempt) of the in-flight `C_meas` fan-out.
    Batch(Vec<(usize, usize)>),
    /// Outlier-gate re-measures (sequential).
    Gate(Vec<(usize, usize)>),
}

struct AlphSession<'a> {
    tuner: &'a Alph,
    core: SessionCore<'a>,
    m_r: usize,
    m0: usize,
    iters: usize,
    m_b: usize,
    samples: Vec<ComponentSamples>,
    /// Per-component time predictions over the whole pool (fixed after
    /// phase 1; component models are log-space → exponentiated).
    per_comp_preds: Vec<Vec<f64>>,
    using_hifi: bool,
    hifi: Option<Ensemble>,
    combiner: Option<Ensemble>,
    /// Amortized trainer for the combiner M_0 (the hifi model rides
    /// the core's trainer); its skip counts flow into the session's
    /// `model_refit_skips`.
    combiner_fit: IncrementalTrainer,
    c_meas: Vec<usize>,
    iter: usize,
    phase: Phase,
    pending: Pending,
    comps_sampled: bool,
    comp_retry: Vec<(CompAttempt, usize)>,
    batch_retry: Vec<(usize, usize)>,
    /// Outlier re-measures queued for the next sequential batch.
    gate_q: Vec<(usize, usize)>,
    /// Delivered readings of the in-flight round, in told order.
    round_ok: Vec<(usize, f64)>,
}

impl AlphSession<'_> {
    /// Phase-1 sampling, identical to CEAL's — the shared
    /// [`sample_component_requests`] protocol.
    fn sample_components(&mut self) -> Vec<MeasurementRequest> {
        self.comps_sampled = true;
        let mut slots = Vec::new();
        let reqs = sample_component_requests(
            &mut self.core,
            self.tuner.historical.as_ref(),
            self.m_r,
            &mut self.samples,
            &mut slots,
        );
        self.pending = if reqs.is_empty() {
            Pending::None
        } else {
            Pending::Components(
                slots
                    .into_iter()
                    .zip(&reqs)
                    .map(|((slot, x), req)| (CompAttempt { slot, x, req: req.clone() }, 0))
                    .collect(),
            )
        };
        reqs
    }

    /// Close phase 1: fit component models, precompute the combiner's
    /// pool features, and draw the m_0 random bootstrap batch.
    fn open_workflow_phase(&mut self) {
        let (prob, pool, scorer) = (self.core.prob, self.core.pool, self.core.scorer);
        let comp_params = gbt_params_for(self.samples.iter().map(|s| s.len()).max().unwrap_or(0));
        let n_feats = prob.n_component_features();
        let comp_models: Vec<Ensemble> = self
            .samples
            .iter()
            .zip(&n_feats)
            .map(|(s, &nf)| {
                if s.is_empty() {
                    Ensemble::constant(nf.max(1), 0.0)
                } else {
                    train_log(&s.xs, &s.y, nf.max(1), &comp_params)
                }
            })
            .collect();
        // Component views score through their pool-resident code
        // caches — at pool scale this re-ranks each model's thresholds
        // instead of re-coding the O(pool·F) component features.
        self.per_comp_preds = comp_models
            .iter()
            .enumerate()
            .map(|(k, e)| {
                scorer
                    .score_view(e, pool.feats.component_view(k))
                    .into_iter()
                    .map(f64::exp)
                    .collect()
            })
            .collect();
        self.core.refit();

        // bootstrap: m0 random workflow runs train the combiner M_0
        let c_meas = random_unmeasured(
            pool,
            &self.core.measured_set,
            self.m0,
            &mut self.core.sel_rng,
        );
        for &i in &c_meas {
            self.core.measured_set.insert(i);
        }
        self.c_meas = c_meas;
        self.phase = Phase::Workflow;
    }

    fn train_combiner(&mut self, rows: &[(usize, f64)]) -> Ensemble {
        let n_j = self.per_comp_preds.len();
        let xs: Vec<[f32; F_MAX]> = rows
            .iter()
            .map(|&(i, _)| combiner_features(&self.per_comp_preds, i))
            .collect();
        let y: Vec<f64> = rows.iter().map(|&(_, y)| y).collect();
        let skips_before = self.combiner_fit.skips();
        let model = self.combiner_fit.train_log(&xs, &y, n_j.max(1), &gbt_params_for(y.len()));
        self.core.note_refit_skips(self.combiner_fit.skips() - skips_before);
        model
    }

    /// The round's deliveries are all in: run switch detection —
    /// mirroring CEAL but on the fresh round only, and *before* the
    /// fresh rows join the training set, exactly as the monolithic
    /// loop ordered it — then record.
    fn record_round(&mut self) {
        let (pool, scorer) = (self.core.pool, self.core.scorer);
        let round = std::mem::take(&mut self.round_ok);
        if !self.using_hifi && !round.is_empty() {
            if let (Some(h), Some(c0)) = (&self.hifi, &self.combiner) {
                let actual: Vec<f64> = round.iter().map(|&(_, y)| y).collect();
                let xs: Vec<_> = round.iter().map(|&(i, _)| pool.feats.workflow[i]).collect();
                let pred_h = scorer.score(h, &xs);
                let cx: Vec<[f32; F_MAX]> = round
                    .iter()
                    .map(|&(i, _)| combiner_features(&self.per_comp_preds, i))
                    .collect();
                let pred_l = scorer.score(c0, &cx);
                if recall_sum_123(&pred_h, &actual) >= recall_sum_123(&pred_l, &actual) {
                    self.using_hifi = true;
                }
            }
        }
        for &(i, y) in &round {
            self.core.record_workflow(i, y);
        }
    }

    /// The round (and any outlier re-measures) is fully resolved:
    /// retrain both models, advance the iteration, select the next
    /// `C_meas`.
    fn close_round(&mut self) {
        let (pool, scorer) = (self.core.pool, self.core.scorer);
        let rows = self.core.train_measured();
        if !rows.is_empty() {
            self.hifi = Some(self.core.fit_hifi(&rows));
            self.core.refit();
            self.combiner = Some(self.train_combiner(&rows));
            self.core.refit();
        }
        self.iter += 1;
        if self.iter < self.iters {
            let picks: Option<Vec<usize>> = if self.using_hifi {
                // fused score-and-select over the pool features
                self.hifi.as_ref().map(|h| {
                    top_unmeasured_model(h, pool, scorer, &self.core.measured_set, self.m_b)
                })
            } else {
                // Combiner selection streams in fixed SCORE_CHUNK-row
                // windows: encode P_1..P_J rows for one chunk, score
                // it, feed a bounded TopK — never the O(pool) combiner
                // feature matrix or score vector.  Per-row scores are
                // batch-size-invariant, so picks match the old
                // materialize-everything pass exactly.
                self.combiner.as_ref().map(|c0| {
                    const CHUNK: usize = crate::surrogate::SCORE_CHUNK;
                    let mut top = TopK::new(self.m_b);
                    let mut cx: Vec<[f32; F_MAX]> = Vec::with_capacity(CHUNK);
                    let mut lo = 0;
                    while lo < pool.len() {
                        let hi = (lo + CHUNK).min(pool.len());
                        cx.clear();
                        cx.extend((lo..hi).map(|i| combiner_features(&self.per_comp_preds, i)));
                        for (j, s) in scorer.score(c0, &cx).into_iter().enumerate() {
                            let i = lo + j;
                            if !self.core.measured_set.contains(&i) {
                                top.offer(s, i);
                            }
                        }
                        lo = hi;
                    }
                    top.into_indices()
                })
            };
            match picks {
                Some(p) => {
                    self.c_meas = p;
                    for &i in &self.c_meas {
                        self.core.measured_set.insert(i);
                    }
                }
                // no model at all (total blackout): nothing to rank
                None => self.phase = Phase::Done,
            }
        } else {
            self.phase = Phase::Done;
        }
    }

    /// Queue the outlier gate's re-measures if any reading is flagged;
    /// otherwise close the round.
    fn gate_or_close(&mut self) {
        let flagged = self.core.outlier_remeasure_picks();
        if flagged.is_empty() {
            self.close_round();
        } else {
            self.gate_q = flagged.into_iter().map(|i| (i, 0)).collect();
        }
    }
}

impl TunerSession for AlphSession<'_> {
    fn name(&self) -> &'static str {
        "ALpH"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(
            matches!(self.pending, Pending::None),
            "ask() with results outstanding"
        );
        if self.phase == Phase::Components {
            if !self.comps_sampled {
                let reqs = self.sample_components();
                if reqs.is_empty() {
                    self.open_workflow_phase();
                } else {
                    self.core.asked_batches += 1;
                    return MeasurementBatch::sequential(reqs);
                }
            } else if !self.comp_retry.is_empty() {
                // failed isolated runs with attempt budget left
                let retry = std::mem::take(&mut self.comp_retry);
                self.core.asked_batches += 1;
                let reqs = retry.iter().map(|(a, _)| a.req.clone()).collect();
                self.pending = Pending::Components(retry);
                return MeasurementBatch::sequential(reqs);
            } else {
                // defensive: tell() normally opens phase 2 itself
                self.open_workflow_phase();
            }
        }
        if !self.batch_retry.is_empty() {
            let retry = std::mem::take(&mut self.batch_retry);
            self.core.asked_batches += 1;
            let reqs = retry
                .iter()
                .map(|&(i, _)| self.core.workflow_request(i))
                .collect();
            self.pending = Pending::Batch(retry);
            return MeasurementBatch::fan_out(reqs);
        }
        if !self.gate_q.is_empty() {
            let gate = std::mem::take(&mut self.gate_q);
            self.core.asked_batches += 1;
            let reqs = gate
                .iter()
                .map(|&(i, _)| self.core.workflow_request(i))
                .collect();
            self.pending = Pending::Gate(gate);
            return MeasurementBatch::sequential(reqs);
        }
        if self.phase == Phase::Done || self.c_meas.is_empty() {
            self.phase = Phase::Done;
            return MeasurementBatch::empty();
        }
        self.core.asked_batches += 1;
        let picks: Vec<(usize, usize)> = std::mem::take(&mut self.c_meas)
            .into_iter()
            .map(|i| (i, 0))
            .collect();
        let reqs: Vec<MeasurementRequest> = picks
            .iter()
            .map(|&(i, _)| self.core.workflow_request(i))
            .collect();
        self.pending = Pending::Batch(picks);
        MeasurementBatch::fan_out(reqs)
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => panic!("tell() without an outstanding batch"),
            Pending::Components(attempts) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(attempts, results, max_retries, |_, att| {
                    core.charge_failed_component(att)
                });
                for (a, y) in ok {
                    self.samples[a.slot].push(a.x, y);
                    self.core.record_component(y);
                }
                self.comp_retry = retry;
                if self.comp_retry.is_empty() {
                    self.open_workflow_phase();
                }
            }
            Pending::Batch(idxs) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(idxs, results, max_retries, |&i, att| {
                    core.charge_failed_workflow(i, att)
                });
                self.round_ok.extend(ok);
                self.batch_retry = retry;
                if !self.batch_retry.is_empty() {
                    return; // round unresolved: re-ask the failures first
                }
                self.record_round();
                self.gate_or_close();
            }
            Pending::Gate(picks) => {
                let core = &mut self.core;
                let (ok, retry) = triage_results(picks, results, max_retries, |&i, att| {
                    core.charge_failed_workflow(i, att)
                });
                for (i, y) in ok {
                    self.core.replace_workflow(i, y);
                }
                self.gate_q = retry;
                if self.gate_q.is_empty() {
                    self.gate_or_close();
                }
            }
        }
    }

    fn state(&self) -> SessionState {
        let (phase, done) = match self.phase {
            Phase::Components => ("components", false),
            Phase::Workflow => ("refine", false),
            Phase::Done => ("done", true),
        };
        let using = if self.per_comp_preds.is_empty() {
            None
        } else {
            Some(self.using_hifi)
        };
        self.core.state(phase, done, using)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        // a total measurement blackout leaves no model: fall back to a
        // constant so the session still yields a valid output
        let model = self
            .hifi
            .unwrap_or_else(|| Ensemble::constant(1, 0.0));
        let core = self.core;
        let rows = core.train_measured();
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_diag_sink(&mut self, sink: DiagSink) {
        self.core.diag.set_sink(sink);
    }

    fn diagnostics(&self) -> &[String] {
        self.core.diag.captured()
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn runs_within_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 41);
        let mut rng = Pcg32::new(10, 10);
        let out = Alph::new(CealParams::no_hist()).run(&prob, &pool, &Scorer::Native, 50, &mut rng);
        let m_r = (50f64 * 0.35).round() as usize;
        assert!(out.workflow_runs <= 50 - m_r);
        assert!(out.best_idx < pool.len());
    }

    #[test]
    fn combiner_features_padded() {
        let preds = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let x = combiner_features(&preds, 1);
        assert_eq!(x[0], 2.0);
        assert_eq!(x[1], 4.0);
        assert_eq!(x[2], 0.0);
    }
}
