//! ALpH — the learned-combiner variant of CEAL (paper §4): instead of
//! combining component predictions with the structure function
//! (max/sum), ALpH *trains* a combining model M_0 on tuples
//! ({P_j(c)}, p) where p is the measured workflow performance — so its
//! low-fidelity model costs workflow runs to build and retrain, which
//! is exactly the deficiency §7.5.2 quantifies.

use std::collections::HashSet;
use std::sync::Arc;

use super::ceal::{gbt_params_for, CealParams};
use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Collector, Pool, Problem,
    Tuner, TunerOutput,
};
use crate::config::F_MAX;
use crate::gbt::{train_log, Ensemble};
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::ComponentSamples;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct Alph {
    pub params: CealParams,
    pub historical: Option<Arc<Vec<ComponentSamples>>>,
}

impl Alph {
    pub fn new(params: CealParams) -> Alph {
        Alph {
            params,
            historical: None,
        }
    }

    pub fn with_historical(params: CealParams, hist: Arc<Vec<ComponentSamples>>) -> Alph {
        Alph {
            params,
            historical: Some(hist),
        }
    }
}

/// Component-prediction features for the combiner: row i carries
/// P_1(c_i)..P_J(c_i), zero-padded to F_MAX.
fn combiner_features(per_comp_preds: &[Vec<f64>], idx: usize) -> [f32; F_MAX] {
    let mut x = [0f32; F_MAX];
    for (j, preds) in per_comp_preds.iter().enumerate() {
        x[j] = preds[idx] as f32;
    }
    x
}

impl Tuner for Alph {
    fn name(&self) -> &'static str {
        "ALpH"
    }

    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");
        let p = self.params;
        let m = m.min(pool.len());

        let m_r = if self.historical.is_some() {
            0
        } else {
            (m as f64 * p.mr_frac).round() as usize
        };
        let m0 = ((m as f64 * p.m0_frac).round() as usize).clamp(1, m.saturating_sub(m_r));
        let remaining = m.saturating_sub(m0 + m_r);
        let iters = p.iterations.clamp(1, remaining.max(1));
        let m_b = (remaining / iters).max(1);

        // component models (same phase-1 as CEAL)
        let spec = &prob.sim.spec;
        let configurable = spec.configurable();
        let mut samples: Vec<ComponentSamples> = match &self.historical {
            Some(h) => h.iter().cloned().collect(),
            None => configurable.iter().map(|_| ComponentSamples::default()).collect(),
        };
        for (slot, &comp) in configurable.iter().enumerate() {
            for _ in 0..m_r {
                match col.measure_component_sampled(comp, &mut sel_rng) {
                    Ok((cfg, y)) => samples[slot].push(spec.components[comp].encode(&cfg), y),
                    Err(e) => {
                        eprintln!("warning: {e}; skipping its isolated runs");
                        break;
                    }
                }
            }
        }
        let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
        let n_feats = prob.n_component_features();
        let comp_models: Vec<Ensemble> = samples
            .iter()
            .zip(&n_feats)
            .map(|(s, &nf)| {
                if s.is_empty() {
                    Ensemble::constant(nf.max(1), 0.0)
                } else {
                    train_log(&s.xs, &s.y, nf.max(1), &comp_params)
                }
            })
            .collect();
        // per-component time predictions over the whole pool (fixed);
        // component models are log-space -> exponentiate
        let per_comp_preds: Vec<Vec<f64>> = comp_models
            .iter()
            .zip(&pool.feats.per_component)
            .map(|(e, xs)| {
                scorer
                    .score(e, xs)
                    .into_iter()
                    .map(f64::exp)
                    .collect()
            })
            .collect();
        let n_j = per_comp_preds.len();

        // bootstrap: m0 random workflow runs train the combiner M_0
        let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
        let mut c_meas = random_unmeasured(pool, &measured_set, m0, &mut sel_rng);
        for &i in &c_meas {
            measured_set.insert(i);
        }

        let train_combiner = |measured: &[(usize, f64)]| -> Ensemble {
            let xs: Vec<[f32; F_MAX]> = measured
                .iter()
                .map(|&(i, _)| combiner_features(&per_comp_preds, i))
                .collect();
            let y: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
            train_log(&xs, &y, n_j.max(1), &gbt_params_for(y.len()))
        };

        let mut using_hifi = false;
        let mut hifi: Option<Ensemble> = None;
        let mut combiner: Option<Ensemble> = None;

        for iter in 0..iters {
            // batch measurement fans across the worker pool, same as
            // CEAL (bit-identical for any worker count)
            let batch = col.measure_pool_batch(pool, &c_meas);
            // switch detection, mirroring CEAL
            if !using_hifi {
                if let (Some(h), Some(c0)) = (&hifi, &combiner) {
                    let actual: Vec<f64> = batch.iter().map(|&(_, y)| y).collect();
                    let xs: Vec<_> = batch
                        .iter()
                        .map(|&(i, _)| pool.feats.workflow[i])
                        .collect();
                    let pred_h = scorer.score(h, &xs);
                    let cx: Vec<[f32; F_MAX]> = batch
                        .iter()
                        .map(|&(i, _)| combiner_features(&per_comp_preds, i))
                        .collect();
                    let pred_l = scorer.score(c0, &cx);
                    if recall_sum_123(&pred_h, &actual) >= recall_sum_123(&pred_l, &actual) {
                        using_hifi = true;
                    }
                }
            }
            measured.extend_from_slice(&batch);
            hifi = Some(train_hifi(prob, pool, &measured));
            combiner = Some(train_combiner(&measured));
            if iter + 1 < iters {
                let scores: Vec<f64> = if using_hifi {
                    scorer.score(hifi.as_ref().unwrap(), &pool.feats.workflow)
                } else {
                    let c0 = combiner.as_ref().unwrap();
                    let cx: Vec<[f32; F_MAX]> = (0..pool.len())
                        .map(|i| combiner_features(&per_comp_preds, i))
                        .collect();
                    scorer.score(c0, &cx)
                };
                c_meas = top_unmeasured(&scores, &measured_set, m_b);
                for &i in &c_meas {
                    measured_set.insert(i);
                }
            }
        }

        let model = hifi.expect("at least one iteration");
        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn runs_within_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 41);
        let mut rng = Pcg32::new(10, 10);
        let out = Alph::new(CealParams::no_hist()).run(&prob, &pool, &Scorer::Native, 50, &mut rng);
        let m_r = (50f64 * 0.35).round() as usize;
        assert!(out.workflow_runs <= 50 - m_r);
        assert!(out.best_idx < pool.len());
    }

    #[test]
    fn combiner_features_padded() {
        let preds = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let x = combiner_features(&preds, 1);
        assert_eq!(x[0], 2.0);
        assert_eq!(x[1], 4.0);
        assert_eq!(x[2], 0.0);
    }
}
