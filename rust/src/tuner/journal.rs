//! Crash-safe tuning sessions: a durable write-ahead journal plus
//! periodic snapshot compaction, and the loader that rebuilds a
//! mid-flight session from them.
//!
//! A checkpoint directory holds two files:
//!
//! * `journal.jsonl` — one unsealed header line (the [`TraceHeader`]
//!   with the journal format/version and the campaign rep), then one
//!   CRC-sealed record per session event: an `ask` record *before* a
//!   batch is issued to the evaluator, a `tell` record — carrying the
//!   outcomes and the evaluator's post-batch RNG state — *before* the
//!   results are applied to the session.  Every append is fsynced, so
//!   a crash loses at most the record being written.
//! * `snapshot.json` — a single CRC-sealed object produced by periodic
//!   compaction: the full exchange history so far plus the session's
//!   [`SessionDigest`] at that point.  The snapshot is written
//!   atomically *first*, then the journal is truncated back to its
//!   header; a crash between the two leaves a tail whose records are
//!   already in the snapshot, which the loader skips by sequence
//!   number.
//!
//! Recovery never re-measures what was already told: the session is
//! rebuilt from its construction arguments and the journaled exchanges
//! are replayed through the ordinary `ask`/`tell` path
//! ([`replay_into`]), which reconstructs the full internal state —
//! surrogates, budgets, RNG positions — because session behaviour is a
//! pure function of construction arguments and told values (the
//! determinism contract of [`super::session`]).  The rebuilt state is
//! verified against the snapshot's digest, the evaluator's noise
//! stream is restored from the last tell record, and fault-injection
//! attempt counters are fast-forwarded via
//! [`Evaluator::note_replayed`] — so kill-at-any-point + resume is
//! bit-identical to the uninterrupted run (pinned by
//! `tests/crash_resume.rs`).
//!
//! Torn-write semantics: a final journal line that fails to parse or
//! CRC-check is the expected crash artifact — the exchange it
//! described never completed, so the loader drops it (noting the
//! recovery) and the session simply redoes that step.  Corruption
//! anywhere *else* is bit rot, reported as a hard
//! [`TraceError::Crc`]/[`TraceError::Malformed`] — never a panic, and
//! never a silent resume from wrong state.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::sim::MeasurementOutcome;
use crate::util::fsio;
use crate::util::json::{self, Json};
use crate::util::rng::RngSnapshot;

use super::common::TunerOutput;
use super::session::{
    BatchMode, Evaluator, EvaluatorState, MeasurementBatch, MeasurementResult, SessionDigest,
    TunerSession,
};
use super::trace::{
    mode_from_name, mode_name, outcome_json, parse_outcomes, parse_recorded_requests,
    RecordedRequest, TraceError, TraceHeader,
};

/// File names inside a checkpoint directory.
pub const JOURNAL_FILE: &str = "journal.jsonl";
pub const SNAPSHOT_FILE: &str = "snapshot.json";

/// The journal/snapshot format version this build writes and the
/// newest it reads (version compatibility policy: a newer on-disk
/// version is rejected with [`TraceError::Version`] rather than
/// resumed into garbage; older versions remain readable).
pub const JOURNAL_VERSION: u64 = 1;

const JOURNAL_FORMAT: &str = "ceal-session-journal";
const SNAPSHOT_FORMAT: &str = "ceal-session-snapshot";

/// Compact the journal into a snapshot every this many completed
/// exchanges (tunable per journal for tests via
/// [`SessionJournal::set_snapshot_every`]).
pub const DEFAULT_SNAPSHOT_EVERY: usize = 8;

/// One completed ask/tell round as persisted: what was asked, what
/// came back, and the evaluator's stochastic state after the batch.
#[derive(Clone, Debug, PartialEq)]
pub struct Exchange {
    pub mode: BatchMode,
    pub requests: Vec<RecordedRequest>,
    pub outcomes: Vec<MeasurementOutcome>,
    /// Evaluator noise-stream position after this exchange (absent for
    /// evaluators with no internal randomness).
    pub eval: Option<EvaluatorState>,
}

/// Everything recovered from a checkpoint directory: the run identity,
/// the full exchange history (snapshot + journal tail merged), and the
/// crash residue.
#[derive(Clone, Debug)]
pub struct LoadedCheckpoint {
    pub header: TraceHeader,
    /// Campaign rep index the checkpoint belongs to (0 for single
    /// sessions).
    pub rep: usize,
    /// All completed exchanges, oldest first.
    pub exchanges: Vec<Exchange>,
    /// How many of `exchanges` came from the snapshot; the rebuilt
    /// session's digest is verified at this boundary.
    pub snapshot_told: usize,
    /// Session digest captured when the snapshot was compacted.
    pub snapshot_digest: Option<SessionDigest>,
    /// A batch that was journaled as asked but never told (the crash
    /// hit mid-measurement); the resumed session re-asks it and the
    /// evaluator re-measures it live.
    pub pending_ask: Option<(BatchMode, Vec<RecordedRequest>)>,
    /// Human-readable notes about crash artifacts dropped during
    /// recovery (torn final record).
    pub recovered: Vec<String>,
}

impl LoadedCheckpoint {
    /// The evaluator state to restore after replay: the noise-stream
    /// position recorded with the last completed exchange.
    pub fn eval(&self) -> Option<EvaluatorState> {
        self.exchanges.last().and_then(|e| e.eval)
    }
}

/// The write-ahead journal for one tuning session.  IO and divergence
/// errors are *latched* (the measurement loop has no error channel,
/// mirroring [`super::trace::TraceRecorder`]): journaling stops at the
/// first error, the tuning run itself continues, and the caller checks
/// [`error`](Self::error) afterwards.  Creation, loading and resume
/// return hard errors instead.
pub struct SessionJournal {
    dir: PathBuf,
    header: TraceHeader,
    rep: usize,
    file: fs::File,
    /// Completed exchanges (snapshot + tail), mirroring disk.
    history: Vec<Exchange>,
    /// How many of `history` the on-disk snapshot covers.
    snapshotted: usize,
    /// A journaled-but-untold ask inherited from a resume: the next
    /// `record_ask` must match it instead of appending a duplicate.
    pending: Option<(BatchMode, Vec<RecordedRequest>)>,
    /// The in-flight ask awaiting its tell.
    current: Option<(BatchMode, Vec<RecordedRequest>)>,
    last_digest: Option<SessionDigest>,
    snapshot_every: usize,
    error: Option<TraceError>,
}

impl SessionJournal {
    /// Start a fresh journal in `dir` (created if needed); any stale
    /// snapshot from a previous run is removed so the directory always
    /// describes exactly one session.
    pub fn create(dir: &Path, header: &TraceHeader, rep: usize) -> Result<SessionJournal, TraceError> {
        fs::create_dir_all(dir).map_err(|e| {
            TraceError::Io(format!("cannot create checkpoint dir {}: {e}", dir.display()))
        })?;
        let snap = dir.join(SNAPSHOT_FILE);
        if snap.exists() {
            fs::remove_file(&snap).map_err(|e| {
                TraceError::Io(format!("cannot clear stale snapshot {}: {e}", snap.display()))
            })?;
        }
        let mut line = header_json(header, rep).compact();
        line.push('\n');
        let path = dir.join(JOURNAL_FILE);
        fsio::atomic_write(&path, line.as_bytes()).map_err(|e| {
            TraceError::Io(format!("cannot write journal {}: {e}", path.display()))
        })?;
        let file = open_append(&path)?;
        Ok(SessionJournal {
            dir: dir.to_path_buf(),
            header: header.clone(),
            rep,
            file,
            history: Vec::new(),
            snapshotted: 0,
            pending: None,
            current: None,
            last_digest: None,
            snapshot_every: DEFAULT_SNAPSHOT_EVERY,
            error: None,
        })
    }

    /// Reopen a checkpoint directory after a crash: load and validate
    /// everything on disk, rewrite the journal tail cleanly (dropping
    /// any torn final record so future appends start on a record
    /// boundary), and return the journal plus what must be replayed.
    pub fn resume(dir: &Path) -> Result<(SessionJournal, LoadedCheckpoint), TraceError> {
        let loaded = load_checkpoint(dir)?;
        let mut text = header_json(&loaded.header, loaded.rep).compact();
        text.push('\n');
        for (seq, ex) in loaded.exchanges.iter().enumerate().skip(loaded.snapshot_told) {
            text.push_str(&ask_line(seq, ex.mode, &ex.requests));
            text.push('\n');
            text.push_str(&tell_line(seq, &ex.outcomes, ex.eval.as_ref()));
            text.push('\n');
        }
        if let Some((mode, reqs)) = &loaded.pending_ask {
            text.push_str(&ask_line(loaded.exchanges.len(), *mode, reqs));
            text.push('\n');
        }
        let path = dir.join(JOURNAL_FILE);
        fsio::atomic_write(&path, text.as_bytes()).map_err(|e| {
            TraceError::Io(format!("cannot rewrite journal {}: {e}", path.display()))
        })?;
        let file = open_append(&path)?;
        let journal = SessionJournal {
            dir: dir.to_path_buf(),
            header: loaded.header.clone(),
            rep: loaded.rep,
            file,
            history: loaded.exchanges.clone(),
            snapshotted: loaded.snapshot_told,
            pending: loaded.pending_ask.clone(),
            current: None,
            last_digest: loaded.snapshot_digest.clone(),
            snapshot_every: DEFAULT_SNAPSHOT_EVERY,
            error: None,
        };
        Ok((journal, loaded))
    }

    /// Durably record a batch *before* it is issued to the evaluator.
    pub fn record_ask(&mut self, batch: &MeasurementBatch) {
        if self.error.is_some() {
            return;
        }
        assert!(self.current.is_none(), "record_ask with a tell outstanding");
        let recorded: Vec<RecordedRequest> =
            batch.requests.iter().map(RecordedRequest::of).collect();
        if let Some((mode, reqs)) = self.pending.take() {
            // a resumed session re-asking its journaled in-flight
            // batch: verify instead of appending a duplicate record
            if mode != batch.mode || reqs != recorded {
                self.error = Some(TraceError::Divergence {
                    batch: self.history.len(),
                    detail: "resumed session re-asked a different batch than journaled".into(),
                });
                return;
            }
            self.current = Some((mode, reqs));
            return;
        }
        let line = ask_line(self.history.len(), batch.mode, &recorded);
        self.append(&line);
        self.current = Some((batch.mode, recorded));
    }

    /// Durably record a batch's results (and the evaluator's post-batch
    /// state) *before* they are applied to the session.
    pub fn record_tell(&mut self, results: &[MeasurementResult], eval: Option<EvaluatorState>) {
        if self.error.is_some() {
            return;
        }
        let (mode, requests) = match self.current.take() {
            Some(c) => c,
            None => {
                self.error = Some(TraceError::Malformed(
                    "record_tell without a recorded ask".into(),
                ));
                return;
            }
        };
        let outcomes: Vec<MeasurementOutcome> = results.iter().map(|r| r.outcome).collect();
        let line = tell_line(self.history.len(), &outcomes, eval.as_ref());
        self.append(&line);
        self.history.push(Exchange {
            mode,
            requests,
            outcomes,
            eval,
        });
    }

    /// Called after the results were applied to the session; captures
    /// the post-apply digest and compacts the journal into a snapshot
    /// when enough exchanges accumulated.
    pub fn after_apply(&mut self, digest: Option<SessionDigest>) {
        if self.error.is_some() {
            return;
        }
        self.last_digest = digest;
        if self.history.len() - self.snapshotted >= self.snapshot_every {
            self.compact();
        }
    }

    /// The first journaling error, if any (journaling stopped there;
    /// the checkpoint on disk is stale but uncorrupted).
    pub fn error(&self) -> Option<&TraceError> {
        self.error.as_ref()
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    pub fn rep(&self) -> usize {
        self.rep
    }

    /// Completed exchanges recorded so far.
    pub fn exchanges(&self) -> usize {
        self.history.len()
    }

    /// True while a journaled ask awaits its tell — either freshly
    /// recorded or inherited from a resumed checkpoint.  Multi-tenant
    /// drivers use this after rehydration to know the in-flight batch
    /// must be re-issued (and verified) before the next tell can
    /// apply.
    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }

    /// Override the compaction period (minimum 1).
    pub fn set_snapshot_every(&mut self, every: usize) {
        self.snapshot_every = every.max(1);
    }

    fn append(&mut self, line: &str) {
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        let res = self
            .file
            .write_all(&bytes)
            .and_then(|_| self.file.sync_data());
        if let Err(e) = res {
            self.error = Some(TraceError::Io(format!("journal append failed: {e}")));
        }
    }

    /// Fold the journal into `snapshot.json` and truncate the journal
    /// back to its header.  Ordering is what makes this crash-safe:
    /// the snapshot lands atomically first, so until the truncation
    /// the directory holds the new snapshot *and* the full tail —
    /// loadable either way (stale tail records are skipped by seq).
    fn compact(&mut self) {
        let snap = snapshot_text(&self.header, self.rep, &self.history, self.last_digest.as_ref());
        let snap_path = self.dir.join(SNAPSHOT_FILE);
        if let Err(e) = fsio::atomic_write(&snap_path, snap.as_bytes()) {
            self.error = Some(TraceError::Io(format!("snapshot write failed: {e}")));
            return;
        }
        let mut line = header_json(&self.header, self.rep).compact();
        line.push('\n');
        let path = self.dir.join(JOURNAL_FILE);
        match fsio::atomic_write(&path, line.as_bytes()).and_then(|_| {
            fs::OpenOptions::new().append(true).open(&path)
        }) {
            Ok(f) => {
                self.file = f;
                self.snapshotted = self.history.len();
            }
            Err(e) => {
                self.error = Some(TraceError::Io(format!("journal compaction failed: {e}")));
            }
        }
    }
}

fn open_append(path: &Path) -> Result<fs::File, TraceError> {
    fs::OpenOptions::new()
        .append(true)
        .open(path)
        .map_err(|e| TraceError::Io(format!("cannot open journal {}: {e}", path.display())))
}

// ---------------------------------------------------------------------
// record encoding

/// Seal a record: the CRC-32 of the record's canonical compact JSON
/// (sans the `crc` key itself) is stored alongside it, so any byte of
/// bit rot in a record is detected on load.
fn seal(mut m: BTreeMap<String, Json>) -> String {
    m.remove("crc");
    let body = Json::Obj(m.clone()).compact();
    m.insert(
        "crc".to_string(),
        Json::Str(format!("{:08x}", fsio::crc32(body.as_bytes()))),
    );
    Json::Obj(m).compact()
}

/// Parse and CRC-verify a sealed record, returning the body (without
/// the seal).
fn unseal(line: &str, context: &str) -> Result<Json, TraceError> {
    let v = json::parse(line).map_err(|e| TraceError::Malformed(format!("{context}: {e}")))?;
    let mut m = match v {
        Json::Obj(m) => m,
        _ => {
            return Err(TraceError::Malformed(format!(
                "{context}: not a JSON object"
            )))
        }
    };
    let crc = match m.remove("crc") {
        Some(Json::Str(s)) => u32::from_str_radix(&s, 16)
            .map_err(|_| TraceError::Malformed(format!("{context}: bad 'crc' seal")))?,
        _ => {
            return Err(TraceError::Malformed(format!(
                "{context}: missing 'crc' seal"
            )))
        }
    };
    let body = Json::Obj(m);
    if fsio::crc32(body.compact().as_bytes()) != crc {
        return Err(TraceError::Crc {
            context: context.to_string(),
        });
    }
    Ok(body)
}

/// The journal's (unsealed) header line: the trace header plus the
/// journal format/version and the campaign rep.
fn header_json(header: &TraceHeader, rep: usize) -> Json {
    let mut m = match header.to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("trace headers serialize to objects"),
    };
    m.insert("format".to_string(), Json::Str(JOURNAL_FORMAT.into()));
    m.insert("version".to_string(), Json::Num(JOURNAL_VERSION as f64));
    if rep != 0 {
        m.insert("rep".to_string(), Json::Num(rep as f64));
    }
    Json::Obj(m)
}

fn check_format(v: &Json, format: &str, max_version: u64) -> Result<(), TraceError> {
    match v.get("format").and_then(Json::as_str) {
        Some(f) if f == format => {}
        _ => return Err(TraceError::NotATrace(format!("not a {format} file"))),
    }
    let version = v
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| TraceError::Malformed(format!("{format} header missing 'version'")))?
        as u64;
    if version == 0 || version > max_version {
        return Err(TraceError::Version(version));
    }
    Ok(())
}

fn recorded_request_json(r: &RecordedRequest) -> Json {
    match r {
        RecordedRequest::Workflow { pool_idx } => {
            Json::obj(vec![("pool", Json::Num(*pool_idx as f64))])
        }
        RecordedRequest::Component { comp, config } => Json::obj(vec![
            ("comp", Json::Num(*comp as f64)),
            (
                "cfg",
                Json::Arr(config.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
        ]),
    }
}

/// RNG positions persist as decimal strings (u64 exceeds f64's exact
/// integer range); the Box-Muller spare persists as its raw bits.
fn rng_json(s: &RngSnapshot) -> Json {
    Json::obj(vec![
        ("inc", Json::Str(s.inc.to_string())),
        (
            "spare",
            match s.spare_normal {
                Some(v) => Json::Str(v.to_bits().to_string()),
                None => Json::Null,
            },
        ),
        ("state", Json::Str(s.state.to_string())),
    ])
}

fn rng_from_json(v: &Json, context: &str) -> Result<RngSnapshot, TraceError> {
    let bad = |k: &str| TraceError::Malformed(format!("{context}: bad rng field '{k}'"));
    let u64_field = |k: &str| -> Result<u64, TraceError> {
        v.get(k)
            .and_then(Json::as_str)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad(k))
    };
    let spare_normal = match v.get("spare") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(f64::from_bits(s.parse().map_err(|_| bad("spare"))?)),
        Some(_) => return Err(bad("spare")),
    };
    Ok(RngSnapshot {
        state: u64_field("state")?,
        inc: u64_field("inc")?,
        spare_normal,
    })
}

pub(crate) fn eval_json(e: &EvaluatorState) -> Json {
    Json::obj(vec![("rng", rng_json(&e.rng))])
}

pub(crate) fn eval_from_json(v: &Json, context: &str) -> Result<EvaluatorState, TraceError> {
    let rng = v
        .get("rng")
        .ok_or_else(|| TraceError::Malformed(format!("{context}: eval state missing 'rng'")))?;
    Ok(EvaluatorState {
        rng: rng_from_json(rng, context)?,
    })
}

fn digest_json(d: &SessionDigest) -> Json {
    let mut pairs = vec![
        ("asked", Json::Num(d.asked_batches as f64)),
        ("comp_runs", Json::Num(d.component_runs as f64)),
        ("cost_bits", Json::Str(d.cost_bits.to_string())),
        ("done", Json::Bool(d.done)),
        ("failed_runs", Json::Num(d.failed_runs as f64)),
        ("phase", Json::Str(d.phase.clone())),
        ("refits", Json::Num(d.model_refits as f64)),
        ("sel_rng", rng_json(&d.sel_rng)),
        ("told", Json::Num(d.told_batches as f64)),
        ("wf_runs", Json::Num(d.workflow_runs as f64)),
    ];
    if let Some(h) = d.using_hifi {
        pairs.push(("using_hifi", Json::Bool(h)));
    }
    Json::obj(pairs)
}

fn digest_from_json(v: &Json) -> Result<SessionDigest, TraceError> {
    let bad = |k: &str| TraceError::Malformed(format!("snapshot digest: bad field '{k}'"));
    let num = |k: &str| -> Result<usize, TraceError> {
        v.get(k).and_then(Json::as_usize).ok_or_else(|| bad(k))
    };
    let cost_bits: u64 = v
        .get("cost_bits")
        .and_then(Json::as_str)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("cost_bits"))?;
    let done = match v.get("done") {
        Some(Json::Bool(b)) => *b,
        _ => return Err(bad("done")),
    };
    let using_hifi = match v.get("using_hifi") {
        None => None,
        Some(Json::Bool(b)) => Some(*b),
        Some(_) => return Err(bad("using_hifi")),
    };
    Ok(SessionDigest {
        phase: v
            .get("phase")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("phase"))?
            .to_string(),
        done,
        asked_batches: num("asked")?,
        told_batches: num("told")?,
        workflow_runs: num("wf_runs")?,
        component_runs: num("comp_runs")?,
        failed_runs: num("failed_runs")?,
        model_refits: num("refits")?,
        cost_bits,
        sel_rng: rng_from_json(
            v.get("sel_rng").ok_or_else(|| bad("sel_rng"))?,
            "snapshot digest",
        )?,
        using_hifi,
    })
}

fn ask_line(seq: usize, mode: BatchMode, requests: &[RecordedRequest]) -> String {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Json::Str("ask".into()));
    m.insert("mode".to_string(), Json::Str(mode_name(mode).into()));
    m.insert(
        "reqs".to_string(),
        Json::Arr(requests.iter().map(recorded_request_json).collect()),
    );
    m.insert("seq".to_string(), Json::Num(seq as f64));
    seal(m)
}

fn tell_line(seq: usize, outcomes: &[MeasurementOutcome], eval: Option<&EvaluatorState>) -> String {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Json::Str("tell".into()));
    m.insert("seq".to_string(), Json::Num(seq as f64));
    m.insert(
        "ys".to_string(),
        Json::Arr(outcomes.iter().map(outcome_json).collect()),
    );
    if let Some(e) = eval {
        m.insert("eval".to_string(), eval_json(e));
    }
    seal(m)
}

fn exchange_json(e: &Exchange) -> Json {
    let mut pairs = vec![
        ("mode", Json::Str(mode_name(e.mode).into())),
        (
            "reqs",
            Json::Arr(e.requests.iter().map(recorded_request_json).collect()),
        ),
        (
            "ys",
            Json::Arr(e.outcomes.iter().map(outcome_json).collect()),
        ),
    ];
    if let Some(ev) = &e.eval {
        pairs.push(("eval", eval_json(ev)));
    }
    Json::obj(pairs)
}

fn snapshot_text(
    header: &TraceHeader,
    rep: usize,
    history: &[Exchange],
    digest: Option<&SessionDigest>,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("format".to_string(), Json::Str(SNAPSHOT_FORMAT.into()));
    m.insert("version".to_string(), Json::Num(JOURNAL_VERSION as f64));
    m.insert("header".to_string(), header.to_json());
    if rep != 0 {
        m.insert("rep".to_string(), Json::Num(rep as f64));
    }
    m.insert(
        "exchanges".to_string(),
        Json::Arr(history.iter().map(exchange_json).collect()),
    );
    if let Some(d) = digest {
        m.insert("digest".to_string(), digest_json(d));
    }
    let mut text = seal(m);
    text.push('\n');
    text
}

// ---------------------------------------------------------------------
// loading

struct Snapshot {
    header: TraceHeader,
    rep: usize,
    exchanges: Vec<Exchange>,
    digest: Option<SessionDigest>,
}

fn parse_exchange(v: &Json, k: usize) -> Result<Exchange, TraceError> {
    let context = format!("snapshot exchange {k}");
    let bad = |msg: String| TraceError::Malformed(format!("{context}: {msg}"));
    let mode = mode_from_name(v.get("mode").and_then(Json::as_str)).map_err(&bad)?;
    let requests = parse_recorded_requests(v.get("reqs")).map_err(&bad)?;
    let outcomes = parse_outcomes(v.get("ys")).map_err(&bad)?;
    if outcomes.len() != requests.len() {
        return Err(bad(format!(
            "{} requests but {} outcomes",
            requests.len(),
            outcomes.len()
        )));
    }
    let eval = match v.get("eval") {
        None => None,
        Some(e) => Some(eval_from_json(e, &context)?),
    };
    Ok(Exchange {
        mode,
        requests,
        outcomes,
        eval,
    })
}

fn parse_snapshot(text: &str) -> Result<Snapshot, TraceError> {
    let v = unseal(text.trim(), "snapshot")?;
    check_format(&v, SNAPSHOT_FORMAT, JOURNAL_VERSION)?;
    let header = TraceHeader::from_json(
        v.get("header")
            .ok_or_else(|| TraceError::Malformed("snapshot missing 'header'".into()))?,
    )?;
    let rep = v.get("rep").and_then(Json::as_usize).unwrap_or(0);
    let exchanges = v
        .get("exchanges")
        .and_then(Json::as_arr)
        .ok_or_else(|| TraceError::Malformed("snapshot missing 'exchanges'".into()))?
        .iter()
        .enumerate()
        .map(|(k, e)| parse_exchange(e, k))
        .collect::<Result<Vec<_>, _>>()?;
    let digest = match v.get("digest") {
        None => None,
        Some(d) => Some(digest_from_json(d)?),
    };
    Ok(Snapshot {
        header,
        rep,
        exchanges,
        digest,
    })
}

enum TailRecord {
    Ask {
        seq: usize,
        mode: BatchMode,
        requests: Vec<RecordedRequest>,
    },
    Tell {
        seq: usize,
        outcomes: Vec<MeasurementOutcome>,
        eval: Option<EvaluatorState>,
    },
}

fn parse_record(v: &Json, context: &str) -> Result<TailRecord, TraceError> {
    let bad = |msg: String| TraceError::Malformed(format!("{context}: {msg}"));
    let seq = v
        .get("seq")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("missing 'seq'".into()))?;
    match v.get("kind").and_then(Json::as_str) {
        Some("ask") => {
            let mode = mode_from_name(v.get("mode").and_then(Json::as_str)).map_err(&bad)?;
            let requests = parse_recorded_requests(v.get("reqs")).map_err(&bad)?;
            if requests.is_empty() {
                return Err(bad("empty ask batch".into()));
            }
            Ok(TailRecord::Ask {
                seq,
                mode,
                requests,
            })
        }
        Some("tell") => {
            let outcomes = parse_outcomes(v.get("ys")).map_err(&bad)?;
            let eval = match v.get("eval") {
                None => None,
                Some(e) => Some(eval_from_json(e, context)?),
            };
            Ok(TailRecord::Tell {
                seq,
                outcomes,
                eval,
            })
        }
        other => Err(bad(format!("unrecognized record kind {other:?}"))),
    }
}

fn parse_journal_header(line: &str) -> Result<(TraceHeader, usize), TraceError> {
    let v = json::parse(line)
        .map_err(|e| TraceError::NotATrace(format!("journal header: {e}")))?;
    check_format(&v, JOURNAL_FORMAT, JOURNAL_VERSION)?;
    let header = TraceHeader::from_json(&v)?;
    let rep = v.get("rep").and_then(Json::as_usize).unwrap_or(0);
    Ok((header, rep))
}

/// True when `dir` holds a (possibly in-flight) checkpointed session:
/// the journal file exists.  Token-keyed serve roots use this to tell
/// "unknown token" apart from "evicted/crashed session to rehydrate"
/// without attempting a full load.
pub fn checkpoint_exists(dir: &Path) -> bool {
    dir.join(JOURNAL_FILE).is_file()
}

/// Load and validate a checkpoint directory without touching it:
/// snapshot (if any) merged with the journal tail into the complete
/// exchange history, crash residue classified (torn final record →
/// dropped with a note; corruption elsewhere → hard error).
pub fn load_checkpoint(dir: &Path) -> Result<LoadedCheckpoint, TraceError> {
    let snap_path = dir.join(SNAPSHOT_FILE);
    let snapshot = match fs::read_to_string(&snap_path) {
        Ok(text) => Some(parse_snapshot(&text)?),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
        Err(e) => {
            return Err(TraceError::Io(format!(
                "cannot read snapshot {}: {e}",
                snap_path.display()
            )))
        }
    };
    let path = dir.join(JOURNAL_FILE);
    let text = fs::read_to_string(&path)
        .map_err(|e| TraceError::Io(format!("cannot read journal {}: {e}", path.display())))?;
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, first) = lines
        .next()
        .ok_or_else(|| TraceError::NotATrace("empty journal file".into()))?;
    let (header, rep) = parse_journal_header(first)?;

    let (mut exchanges, snapshot_told, snapshot_digest) = match snapshot {
        Some(s) => {
            if s.header != header || s.rep != rep {
                return Err(TraceError::Malformed(
                    "snapshot and journal headers disagree (mixed checkpoint directories?)".into(),
                ));
            }
            let told = s.exchanges.len();
            (s.exchanges, told, s.digest)
        }
        None => (Vec::new(), 0, None),
    };

    let tail: Vec<(usize, &str)> = lines.collect();
    let last = tail.len();
    let mut pending_ask: Option<(BatchMode, Vec<RecordedRequest>)> = None;
    let mut recovered = Vec::new();
    for (k, (lineno, line)) in tail.into_iter().enumerate() {
        let context = format!("journal line {}", lineno + 1);
        let rec = match unseal(line, &context).and_then(|v| parse_record(&v, &context)) {
            Ok(r) => r,
            Err(e) if k + 1 == last => {
                // a torn or half-written final record is the expected
                // crash artifact: the event never completed, drop it
                recovered.push(format!("dropped torn final journal record ({e})"));
                continue;
            }
            Err(e) => return Err(e),
        };
        match rec {
            TailRecord::Ask {
                seq,
                mode,
                requests,
            } => {
                if seq < snapshot_told {
                    continue; // pre-compaction residue, already in the snapshot
                }
                if pending_ask.is_some() || seq != exchanges.len() {
                    return Err(TraceError::Malformed(format!(
                        "{context}: ask record out of sequence (seq {seq}, {} exchanges loaded)",
                        exchanges.len()
                    )));
                }
                pending_ask = Some((mode, requests));
            }
            TailRecord::Tell {
                seq,
                outcomes,
                eval,
            } => {
                if seq < snapshot_told {
                    continue;
                }
                let (mode, requests) = pending_ask.take().ok_or_else(|| {
                    TraceError::Malformed(format!(
                        "{context}: tell record without a matching ask"
                    ))
                })?;
                if seq != exchanges.len() {
                    return Err(TraceError::Malformed(format!(
                        "{context}: tell record out of sequence (seq {seq}, {} exchanges loaded)",
                        exchanges.len()
                    )));
                }
                if outcomes.len() != requests.len() {
                    return Err(TraceError::Malformed(format!(
                        "{context}: {} requests but {} outcomes",
                        requests.len(),
                        outcomes.len()
                    )));
                }
                exchanges.push(Exchange {
                    mode,
                    requests,
                    outcomes,
                    eval,
                });
            }
        }
    }
    Ok(LoadedCheckpoint {
        header,
        rep,
        exchanges,
        snapshot_told,
        snapshot_digest,
        pending_ask,
        recovered,
    })
}

// ---------------------------------------------------------------------
// replay and driving

fn verify_replayed_batch(
    k: usize,
    batch: &MeasurementBatch,
    mode: BatchMode,
    requests: &[RecordedRequest],
) -> Result<(), TraceError> {
    let diverged = |detail: String| TraceError::Divergence { batch: k, detail };
    if batch.mode != mode {
        return Err(diverged("batch mode changed on resume".into()));
    }
    if batch.len() != requests.len() {
        return Err(diverged(format!(
            "batch size changed (journaled {}, session asked {})",
            requests.len(),
            batch.len()
        )));
    }
    for (i, (recorded, live)) in requests.iter().zip(&batch.requests).enumerate() {
        if !recorded.matches(live) {
            return Err(diverged(format!(
                "request {i}: journaled {recorded:?}, session asked {live:?}"
            )));
        }
    }
    Ok(())
}

/// Rebuild a freshly constructed session to the checkpointed state by
/// replaying the journaled exchanges through the ordinary ask/tell
/// path.  Each replayed ask is verified against the journal (a
/// divergence means a different seed/algorithm/build); replayed
/// requests are announced to the evaluator via
/// [`Evaluator::note_replayed`] so per-request bookkeeping (fault
/// attempt counters) fast-forwards without re-measuring; at the
/// snapshot boundary the rebuilt digest is checked against the
/// checkpointed one; and finally the evaluator's noise stream is
/// restored to its last journaled position.  Returns the number of
/// exchanges replayed.
pub fn replay_into(
    session: &mut dyn TunerSession,
    evaluator: &mut dyn Evaluator,
    loaded: &LoadedCheckpoint,
) -> Result<usize, TraceError> {
    for (k, ex) in loaded.exchanges.iter().enumerate() {
        let batch = session.ask();
        verify_replayed_batch(k, &batch, ex.mode, &ex.requests)?;
        for req in &batch.requests {
            evaluator.note_replayed(req);
        }
        let results: Vec<MeasurementResult> = ex
            .outcomes
            .iter()
            .map(|&outcome| MeasurementResult { outcome })
            .collect();
        session.tell(&results);
        if k + 1 == loaded.snapshot_told {
            if let (Some(want), Some(got)) = (&loaded.snapshot_digest, &session.digest()) {
                if want != got {
                    return Err(TraceError::StateMismatch {
                        detail: format!(
                            "after replaying {} exchanges the rebuilt session digest differs \
                             from the checkpointed one (checkpointed {want:?}, rebuilt {got:?})",
                            k + 1
                        ),
                    });
                }
            }
        }
    }
    if let Some(state) = loaded.eval() {
        evaluator.restore_state(&state);
    }
    Ok(loaded.exchanges.len())
}

/// [`super::session::drive`] with a write-ahead journal: every ask is
/// journaled before it reaches the evaluator and every tell before it
/// reaches the session, so a crash at any point is recoverable from
/// disk.  Journaling reads only immutable state (digests, evaluator
/// snapshots), so the tuning trajectory is bit-identical to the plain
/// driver; journaling errors are latched on `journal` for the caller.
pub fn drive_checkpointed(
    mut session: Box<dyn TunerSession + '_>,
    evaluator: &mut dyn Evaluator,
    journal: &mut SessionJournal,
) -> TunerOutput {
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        journal.record_ask(&batch);
        let results = evaluator.evaluate(&batch);
        assert_eq!(
            results.len(),
            batch.len(),
            "evaluator must answer every request of a batch"
        );
        journal.record_tell(&results, evaluator.checkpoint_state());
        session.tell(&results);
        journal.after_apply(session.digest());
    }
    session.finish()
}

/// A measurement watchdog: forwards batches to `inner` and converts
/// any batch that took longer than `deadline` wall-clock into all
/// [`MeasurementOutcome::TimedOut`] slots, which then flow through the
/// session's ordinary retry/backoff handling (and are journaled as
/// timeouts like any other outcome).  Wall-clock–dependent by nature,
/// so it is excluded from the bit-equivalence contracts.
pub struct DeadlineEvaluator<'e> {
    inner: &'e mut dyn Evaluator,
    deadline: Duration,
    timed_out_batches: usize,
}

impl<'e> DeadlineEvaluator<'e> {
    pub fn new(inner: &'e mut dyn Evaluator, deadline: Duration) -> DeadlineEvaluator<'e> {
        DeadlineEvaluator {
            inner,
            deadline,
            timed_out_batches: 0,
        }
    }

    /// Batches abandoned at the deadline so far.
    pub fn timed_out_batches(&self) -> usize {
        self.timed_out_batches
    }
}

impl Evaluator for DeadlineEvaluator<'_> {
    fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
        let start = Instant::now();
        let results = self.inner.evaluate(batch);
        if start.elapsed() > self.deadline {
            self.timed_out_batches += 1;
            return batch
                .requests
                .iter()
                .map(|_| MeasurementResult::timed_out())
                .collect();
        }
        results
    }

    fn checkpoint_state(&mut self) -> Option<EvaluatorState> {
        self.inner.checkpoint_state()
    }

    fn restore_state(&mut self, state: &EvaluatorState) -> bool {
        self.inner.restore_state(state)
    }

    fn note_replayed(&mut self, req: &super::session::MeasurementRequest) {
        self.inner.note_replayed(req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;
    use crate::tuner::common::{Collector, Pool, Problem, Tuner};
    use crate::tuner::rs::RandomSampling;
    use crate::tuner::session::{drive, MeasurementRequest};
    use crate::util::rng::Pcg32;

    fn temp_checkpoint_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ceal_journal_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn header() -> TraceHeader {
        TraceHeader {
            algo: "RS".into(),
            workflow: "LV".into(),
            objective: "comp_time".into(),
            m: 10,
            pool_size: 40,
            seed: 0xCEA1,
            scorer: "native".into(),
            ceal_params: None,
            faults: None,
        }
    }

    /// An evaluator with a deterministic internal counter, so tests
    /// can tell exchanges apart.
    struct Counting {
        next: f64,
    }
    impl Evaluator for Counting {
        fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
            batch
                .requests
                .iter()
                .map(|_| {
                    self.next += 1.0;
                    MeasurementResult::ok(self.next)
                })
                .collect()
        }
    }

    fn wf_req(i: usize) -> MeasurementRequest {
        MeasurementRequest::Workflow {
            pool_idx: i,
            config: crate::config::Config(vec![]),
        }
    }

    #[test]
    fn seal_roundtrips_and_detects_tampering() {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str("ask".into()));
        m.insert("seq".to_string(), Json::Num(3.0));
        let line = seal(m);
        assert!(line.contains("\"crc\":\""), "{line}");
        let body = unseal(&line, "test").unwrap();
        assert_eq!(body.get("seq").and_then(Json::as_usize), Some(3));
        // flip one payload byte: the seal must catch it
        let tampered = line.replace("\"seq\":3", "\"seq\":4");
        assert_eq!(
            unseal(&tampered, "test"),
            Err(TraceError::Crc {
                context: "test".into()
            })
        );
    }

    #[test]
    fn rng_and_digest_json_roundtrip() {
        let snap = RngSnapshot {
            state: u64::MAX - 17,
            inc: 0x9E37_79B9_7F4A_7C15,
            spare_normal: Some(-1.25e-3),
        };
        let back = rng_from_json(&rng_json(&snap), "test").unwrap();
        assert_eq!(back, snap);

        let d = SessionDigest {
            phase: "refine".into(),
            done: false,
            asked_batches: 3,
            told_batches: 3,
            workflow_runs: 17,
            component_runs: 4,
            failed_runs: 1,
            model_refits: 2,
            cost_bits: 4638387860618067575,
            sel_rng: snap,
            using_hifi: Some(true),
        };
        let back = digest_from_json(&digest_json(&d)).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn journal_roundtrips_exchanges_and_pending_ask() {
        let dir = temp_checkpoint_dir("roundtrip");
        let mut j = SessionJournal::create(&dir, &header(), 2).unwrap();
        let mut eval = Counting { next: 0.0 };

        let b0 = MeasurementBatch::sequential(vec![
            MeasurementRequest::Component {
                comp: 1,
                config: vec![4, 8],
            },
            wf_req(3),
        ]);
        j.record_ask(&b0);
        let r0 = eval.evaluate(&b0);
        j.record_tell(&r0, None);
        j.after_apply(None);

        let b1 = MeasurementBatch::fan_out(vec![wf_req(5), wf_req(9)]);
        j.record_ask(&b1); // asked, never told: the crash window
        assert_eq!(j.error(), None);

        let loaded = load_checkpoint(&dir).unwrap();
        assert_eq!(loaded.rep, 2);
        assert_eq!(loaded.header, header());
        assert_eq!(loaded.exchanges.len(), 1);
        assert_eq!(loaded.snapshot_told, 0);
        assert_eq!(loaded.exchanges[0].mode, BatchMode::Sequential);
        assert_eq!(
            loaded.exchanges[0].outcomes,
            vec![MeasurementOutcome::Ok(1.0), MeasurementOutcome::Ok(2.0)]
        );
        let (mode, reqs) = loaded.pending_ask.as_ref().expect("pending ask survives");
        assert_eq!(*mode, BatchMode::FanOut);
        assert_eq!(
            reqs,
            &vec![
                RecordedRequest::Workflow { pool_idx: 5 },
                RecordedRequest::Workflow { pool_idx: 9 }
            ]
        );
        assert!(loaded.recovered.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_record_is_dropped_with_a_note() {
        let dir = temp_checkpoint_dir("torn");
        let mut j = SessionJournal::create(&dir, &header(), 0).unwrap();
        let mut eval = Counting { next: 0.0 };
        let b = MeasurementBatch::sequential(vec![wf_req(1)]);
        j.record_ask(&b);
        let r = eval.evaluate(&b);
        j.record_tell(&r, None);
        drop(j);
        // simulate a crash mid-append: half a record, no newline
        let path = dir.join(JOURNAL_FILE);
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"crc\":\"0000beef\",\"kind\":\"as").unwrap();
        drop(f);

        let loaded = load_checkpoint(&dir).unwrap();
        assert_eq!(loaded.exchanges.len(), 1);
        assert_eq!(loaded.pending_ask, None);
        assert_eq!(loaded.recovered.len(), 1, "{:?}", loaded.recovered);
        assert!(loaded.recovered[0].contains("torn final"), "{:?}", loaded.recovered);

        // resume rewrites the journal cleanly: reloading recovers nothing
        let (j2, _) = SessionJournal::resume(&dir).unwrap();
        drop(j2);
        let reloaded = load_checkpoint(&dir).unwrap();
        assert!(reloaded.recovered.is_empty());
        assert_eq!(reloaded.exchanges.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_before_the_final_record_is_a_hard_error() {
        let dir = temp_checkpoint_dir("corrupt");
        let mut j = SessionJournal::create(&dir, &header(), 0).unwrap();
        let mut eval = Counting { next: 0.0 };
        for k in 0..2 {
            let b = MeasurementBatch::sequential(vec![wf_req(k)]);
            j.record_ask(&b);
            let r = eval.evaluate(&b);
            j.record_tell(&r, None);
            j.after_apply(None);
        }
        drop(j);
        // flip a digit inside the *second* line (first tail record)
        let path = dir.join(JOURNAL_FILE);
        let text = fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        assert!(lines.len() >= 3);
        lines[1] = lines[1].replace("\"pool\":0", "\"pool\":7");
        fs::write(&path, format!("{}\n", lines.join("\n"))).unwrap();

        let err = load_checkpoint(&dir).unwrap_err();
        assert!(
            matches!(err, TraceError::Crc { .. }),
            "want CRC error, got {err:?}"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_folds_history_into_the_snapshot() {
        let dir = temp_checkpoint_dir("compact");
        let mut j = SessionJournal::create(&dir, &header(), 0).unwrap();
        j.set_snapshot_every(2);
        let mut eval = Counting { next: 0.0 };
        let digest = SessionDigest {
            phase: "refine".into(),
            done: false,
            asked_batches: 2,
            told_batches: 2,
            workflow_runs: 2,
            component_runs: 0,
            failed_runs: 0,
            model_refits: 0,
            cost_bits: 0,
            sel_rng: RngSnapshot {
                state: 1,
                inc: 3,
                spare_normal: None,
            },
            using_hifi: None,
        };
        for k in 0..3 {
            let b = MeasurementBatch::sequential(vec![wf_req(k)]);
            j.record_ask(&b);
            let r = eval.evaluate(&b);
            j.record_tell(&r, eval.checkpoint_state());
            j.after_apply(Some(digest.clone()));
        }
        assert_eq!(j.error(), None);
        drop(j);
        assert!(dir.join(SNAPSHOT_FILE).exists());
        // the journal was truncated at the 2-exchange compaction: only
        // the third exchange remains in the tail
        let text = fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(text.lines().count(), 3, "{text}");

        let loaded = load_checkpoint(&dir).unwrap();
        assert_eq!(loaded.exchanges.len(), 3);
        assert_eq!(loaded.snapshot_told, 2);
        assert_eq!(loaded.snapshot_digest, Some(digest));
        assert_eq!(
            loaded.exchanges[2].outcomes,
            vec![MeasurementOutcome::Ok(3.0)]
        );
        let _ = fs::remove_dir_all(&dir);
    }

    /// The full contract on a real session: journal a run, "crash",
    /// rebuild by replay, continue — outputs must match the
    /// uninterrupted run bit-for-bit.
    #[test]
    fn journaled_run_resumes_bit_identically() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 40, 7);
        let tuner = RandomSampling;
        let head = header();

        // uninterrupted reference
        let mut rng = Pcg32::new(51, 0);
        let mut col = Collector::new(&prob, Pcg32::new(52, 0));
        let want = drive(
            tuner.session(&prob, &pool, &crate::surrogate::Scorer::Native, 10, &mut rng),
            &mut col,
        );

        // journaled run, abandoned after the first exchange
        let dir = temp_checkpoint_dir("resume");
        {
            let mut j = SessionJournal::create(&dir, &head, 0).unwrap();
            let mut rng = Pcg32::new(51, 0);
            let mut session =
                tuner.session(&prob, &pool, &crate::surrogate::Scorer::Native, 10, &mut rng);
            let mut col = Collector::new(&prob, Pcg32::new(52, 0));
            let batch = session.ask();
            j.record_ask(&batch);
            let results = Evaluator::evaluate(&mut col, &batch);
            j.record_tell(&results, Evaluator::checkpoint_state(&mut col));
            session.tell(&results);
            j.after_apply(session.digest());
            assert_eq!(j.error(), None);
            // session and collector dropped here: the "crash"
        }

        // resume from disk and finish
        let (mut j, loaded) = SessionJournal::resume(&dir).unwrap();
        let mut rng = Pcg32::new(51, 0);
        let mut session =
            tuner.session(&prob, &pool, &crate::surrogate::Scorer::Native, 10, &mut rng);
        let mut col = Collector::new(&prob, Pcg32::new(52, 0));
        replay_into(session.as_mut(), &mut col, &loaded).unwrap();
        let got = drive_checkpointed(session, &mut col, &mut j);
        assert_eq!(j.error(), None);

        assert_eq!(got.best_idx, want.best_idx);
        assert_eq!(got.measured, want.measured);
        assert_eq!(
            got.collection_cost.to_bits(),
            want.collection_cost.to_bits()
        );
        assert_eq!(got.workflow_runs, want.workflow_runs);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn deadline_evaluator_times_out_slow_batches() {
        let mut inner = Counting { next: 0.0 };
        let mut dl = DeadlineEvaluator::new(&mut inner, Duration::from_secs(3600));
        let b = MeasurementBatch::sequential(vec![wf_req(0), wf_req(1)]);
        let ok = dl.evaluate(&b);
        assert!(ok.iter().all(MeasurementResult::is_ok));
        assert_eq!(dl.timed_out_batches(), 0);

        struct Slow;
        impl Evaluator for Slow {
            fn evaluate(&mut self, batch: &MeasurementBatch) -> Vec<MeasurementResult> {
                std::thread::sleep(Duration::from_millis(5));
                batch
                    .requests
                    .iter()
                    .map(|_| MeasurementResult::ok(1.0))
                    .collect()
            }
        }
        let mut slow = Slow;
        let mut dl = DeadlineEvaluator::new(&mut slow, Duration::from_millis(1));
        let late = dl.evaluate(&b);
        assert!(late.iter().all(|r| !r.is_ok()));
        assert_eq!(
            late[0].outcome,
            MeasurementOutcome::TimedOut,
            "deadline converts to timeouts"
        );
        assert_eq!(dl.timed_out_batches(), 1);
    }

    #[test]
    fn resume_rejects_a_foreign_checkpoint() {
        let dir = temp_checkpoint_dir("foreign");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join(JOURNAL_FILE), "{\"hello\":1}\n").unwrap();
        let err = load_checkpoint(&dir).unwrap_err();
        assert!(
            matches!(err, TraceError::NotATrace(_)),
            "want NotATrace, got {err:?}"
        );
        // a future journal version is refused up front
        let mut line = header_json(&header(), 0).compact();
        line = line.replace("\"version\":1", "\"version\":99");
        line.push('\n');
        fs::write(dir.join(JOURNAL_FILE), line).unwrap();
        assert_eq!(load_checkpoint(&dir).unwrap_err(), TraceError::Version(99));
        let _ = fs::remove_dir_all(&dir);
    }
}
