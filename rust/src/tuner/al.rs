//! AL — batch active-learning baseline (§7.3, refs [4, 19]): seed with
//! random samples, then iteratively measure the configurations the
//! gradually-refined surrogate predicts to be best.
//!
//! Session shape: one sequential bootstrap batch, then `iterations`
//! sequential refinement batches (the surrogate refits after every
//! told batch).

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Pool, Problem, Tuner,
    TunerOutput,
};
use super::session::{
    MeasurementBatch, MeasurementResult, SessionCore, SessionState, TunerSession,
};
use crate::gbt::Ensemble;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct ActiveLearning {
    /// Fraction of the budget spent on the random bootstrap batch.
    pub m0_frac: f64,
    /// Refinement iterations.
    pub iterations: usize,
}

impl Default for ActiveLearning {
    fn default() -> Self {
        ActiveLearning {
            m0_frac: 0.25,
            iterations: 6,
        }
    }
}

impl Tuner for ActiveLearning {
    fn name(&self) -> &'static str {
        "AL"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        let m = m.min(pool.len());
        let m0 = ((m as f64 * self.m0_frac).round() as usize).clamp(1, m);
        let remaining = m - m0;
        let iters = self.iterations.min(remaining.max(1));
        let batch = if iters == 0 { 0 } else { remaining / iters };
        Box::new(AlSession {
            core: SessionCore::new(prob, pool, scorer, rng),
            m0,
            iters,
            batch,
            iter: 0,
            bootstrapped: false,
            pending: Vec::new(),
            model: None,
        })
    }
}

struct AlSession<'a> {
    core: SessionCore<'a>,
    m0: usize,
    iters: usize,
    batch: usize,
    /// Refinement batches completed so far.
    iter: usize,
    bootstrapped: bool,
    pending: Vec<usize>,
    model: Option<Ensemble>,
}

impl AlSession<'_> {
    fn done(&self) -> bool {
        self.bootstrapped && (self.batch == 0 || self.iter >= self.iters)
    }
}

impl TunerSession for AlSession<'_> {
    fn name(&self) -> &'static str {
        "AL"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(self.pending.is_empty(), "ask() with results outstanding");
        if self.done() {
            return MeasurementBatch::empty();
        }
        self.core.asked_batches += 1;
        let picks = if !self.bootstrapped {
            random_unmeasured(
                self.core.pool,
                &self.core.measured_set,
                self.m0,
                &mut self.core.sel_rng,
            )
        } else {
            let model = self.model.as_ref().expect("model trained at bootstrap");
            let preds = self.core.scorer.score(model, &self.core.pool.feats.workflow);
            top_unmeasured(&preds, &self.core.measured_set, self.batch)
        };
        let reqs = self.core.take_workflow_picks(&picks);
        self.pending = picks;
        MeasurementBatch::sequential(reqs)
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        let picks = std::mem::take(&mut self.pending);
        assert_eq!(results.len(), picks.len(), "tell() arity mismatch");
        self.core.told_batches += 1;
        for (&i, r) in picks.iter().zip(results) {
            self.core.record_workflow(i, r.value);
        }
        if self.bootstrapped {
            self.iter += 1;
        } else {
            self.bootstrapped = true;
        }
        self.model = Some(train_hifi(self.core.prob, self.core.pool, &self.core.measured));
        self.core.refit();
    }

    fn state(&self) -> SessionState {
        let phase = if self.done() {
            "done"
        } else if !self.bootstrapped {
            "bootstrap"
        } else {
            "refine"
        };
        self.core.state(phase, self.done(), None)
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        let model = self.model.expect("finish() before the session completed");
        let core = self.core;
        let best_idx = searcher_best(&model, core.pool, core.scorer, &core.measured);
        core.into_output(model, best_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn respects_budget_and_improves_sampling() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 11);
        let mut rng = Pcg32::new(4, 4);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 50, &mut rng);
        assert!(out.workflow_runs <= 50, "runs {}", out.workflow_runs);
        assert!(out.workflow_runs >= 40, "runs {}", out.workflow_runs);
        // AL concentrates later samples on good configs: the mean truth
        // of the second half of samples should beat the first half.
        let half = out.measured.len() / 2;
        let first: f64 = out.measured[..half]
            .iter()
            .map(|&(i, _)| pool.truth[i])
            .sum::<f64>()
            / half as f64;
        let second: f64 = out.measured[half..]
            .iter()
            .map(|&(i, _)| pool.truth[i])
            .sum::<f64>()
            / (out.measured.len() - half) as f64;
        assert!(
            second < first,
            "active batches should be better than bootstrap: {first} vs {second}"
        );
    }

    #[test]
    fn tiny_budget_does_not_panic() {
        let prob = Problem::new(WorkflowId::GP, Objective::ExecTime);
        let pool = Pool::generate(&prob, 50, 12);
        let mut rng = Pcg32::new(5, 5);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 5, &mut rng);
        assert!(out.workflow_runs <= 5);
    }

    #[test]
    fn session_refits_every_batch() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 120, 13);
        let mut rng = Pcg32::new(8, 8);
        let tuner = ActiveLearning::default();
        let mut session = tuner.session(&prob, &pool, &Scorer::Native, 30, &mut rng);
        let mut col = super::super::Collector::new(&prob, Pcg32::new(9, 9));
        let mut batches = 0usize;
        loop {
            let batch = session.ask();
            if batch.is_empty() {
                break;
            }
            batches += 1;
            let results = super::super::session::Evaluator::evaluate(&mut col, &batch);
            session.tell(&results);
            assert_eq!(session.state().model_refits, batches);
        }
        // bootstrap + 6 refinement iterations
        assert_eq!(batches, 7);
        assert!(session.state().done);
    }
}
