//! AL — batch active-learning baseline (§7.3, refs [4, 19]): seed with
//! random samples, then iteratively measure the configurations the
//! gradually-refined surrogate predicts to be best.
//!
//! Session shape: one sequential bootstrap batch, then `iterations`
//! sequential refinement batches (the surrogate refits after every
//! told batch).  Failed measurements are retried within the logical
//! batch (the iteration does not advance and the surrogate does not
//! refit until the batch is resolved); permanently lost picks are
//! skipped, and the batch closes on whatever was delivered.

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured_model, Pool, Problem, Tuner,
    TunerOutput,
};
use super::session::{
    triage_results, FailurePolicy, MeasurementBatch, MeasurementResult, SessionCore,
    SessionDigest, SessionState, TunerSession,
};
use crate::gbt::Ensemble;
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct ActiveLearning {
    /// Fraction of the budget spent on the random bootstrap batch.
    pub m0_frac: f64,
    /// Refinement iterations.
    pub iterations: usize,
}

impl Default for ActiveLearning {
    fn default() -> Self {
        ActiveLearning {
            m0_frac: 0.25,
            iterations: 6,
        }
    }
}

impl Tuner for ActiveLearning {
    fn name(&self) -> &'static str {
        "AL"
    }

    fn session<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        let m = m.min(pool.len());
        let m0 = ((m as f64 * self.m0_frac).round() as usize).clamp(1, m);
        let remaining = m - m0;
        let iters = self.iterations.min(remaining.max(1));
        let batch = if iters == 0 { 0 } else { remaining / iters };
        Box::new(AlSession {
            core: SessionCore::new(prob, pool, scorer, rng),
            m0,
            iters,
            batch,
            iter: 0,
            bootstrapped: false,
            pending: Vec::new(),
            retry: Vec::new(),
            in_gate: false,
            forced_done: false,
            model: None,
        })
    }
}

struct AlSession<'a> {
    core: SessionCore<'a>,
    m0: usize,
    iters: usize,
    batch: usize,
    /// Refinement batches completed so far.
    iter: usize,
    bootstrapped: bool,
    /// In-flight (pool index, attempt) pairs.
    pending: Vec<(usize, usize)>,
    /// Failed picks with attempt budget left, re-asked next batch.
    retry: Vec<(usize, usize)>,
    /// True while the in-flight batch re-measures gate-flagged points.
    in_gate: bool,
    /// Set when the pool runs dry before the iteration budget does.
    forced_done: bool,
    model: Option<Ensemble>,
}

impl AlSession<'_> {
    fn done(&self) -> bool {
        self.forced_done || (self.bootstrapped && (self.batch == 0 || self.iter >= self.iters))
    }

    fn issue(&mut self, picks: Vec<(usize, usize)>) -> MeasurementBatch {
        self.core.asked_batches += 1;
        let reqs = picks
            .iter()
            .map(|&(i, _)| self.core.workflow_request(i))
            .collect();
        self.pending = picks;
        MeasurementBatch::sequential(reqs)
    }

    /// The logical batch is fully resolved: advance the iteration and
    /// refit on everything delivered so far.
    fn close_batch(&mut self) {
        if self.bootstrapped {
            self.iter += 1;
        } else {
            self.bootstrapped = true;
        }
        let rows = self.core.train_measured();
        if !rows.is_empty() {
            self.model = Some(self.core.fit_hifi(&rows));
        }
        self.core.refit();
    }
}

impl TunerSession for AlSession<'_> {
    fn name(&self) -> &'static str {
        "AL"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(self.pending.is_empty(), "ask() with results outstanding");
        if !self.retry.is_empty() {
            let retry = std::mem::take(&mut self.retry);
            return self.issue(retry);
        }
        if self.done() {
            return MeasurementBatch::empty();
        }
        self.in_gate = false;
        let avail = self.core.pool.len() - self.core.measured_set.len();
        let picks = if !self.bootstrapped {
            let k = self.m0.min(avail);
            random_unmeasured(self.core.pool, &self.core.measured_set, k, &mut self.core.sel_rng)
        } else {
            match self.model.as_ref() {
                // fused score-and-select: no O(pool) prediction vector
                Some(model) => top_unmeasured_model(
                    model,
                    self.core.pool,
                    self.core.scorer,
                    &self.core.measured_set,
                    self.batch,
                ),
                // every bootstrap attempt failed: refine blind
                None => {
                    let k = self.batch.min(avail);
                    random_unmeasured(
                        self.core.pool,
                        &self.core.measured_set,
                        k,
                        &mut self.core.sel_rng,
                    )
                }
            }
        };
        if picks.is_empty() {
            self.forced_done = true;
            return MeasurementBatch::empty();
        }
        for &i in &picks {
            self.core.measured_set.insert(i);
        }
        self.issue(picks.into_iter().map(|i| (i, 0)).collect())
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        let pending = std::mem::take(&mut self.pending);
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        let in_gate = self.in_gate;
        let core = &mut self.core;
        let (ok, retry) = triage_results(pending, results, max_retries, |&i, att| {
            core.charge_failed_workflow(i, att)
        });
        for (i, y) in ok {
            if in_gate {
                self.core.replace_workflow(i, y);
            } else {
                self.core.record_workflow(i, y);
            }
        }
        self.retry = retry;
        if !self.retry.is_empty() {
            return; // batch unresolved: re-ask the failures first
        }
        if !self.in_gate {
            // resolved work batch: give flagged readings their
            // re-measure before closing the iteration
            let flagged = self.core.outlier_remeasure_picks();
            if !flagged.is_empty() {
                self.in_gate = true;
                self.retry = flagged.into_iter().map(|i| (i, 0)).collect();
                return;
            }
            self.close_batch();
        } else {
            let flagged = self.core.outlier_remeasure_picks();
            if !flagged.is_empty() {
                self.retry = flagged.into_iter().map(|i| (i, 0)).collect();
                return;
            }
            self.in_gate = false;
            self.close_batch();
        }
    }

    fn state(&self) -> SessionState {
        let phase = if self.done() {
            "done"
        } else if !self.bootstrapped {
            "bootstrap"
        } else {
            "refine"
        };
        self.core.state(phase, self.done(), None)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        // a total measurement blackout leaves no model: fall back to a
        // constant so the session still yields a valid output
        let model = self.model.unwrap_or_else(|| Ensemble::constant(1, 0.0));
        let core = self.core;
        let rows = core.train_measured();
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn respects_budget_and_improves_sampling() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 11);
        let mut rng = Pcg32::new(4, 4);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 50, &mut rng);
        assert!(out.workflow_runs <= 50, "runs {}", out.workflow_runs);
        assert!(out.workflow_runs >= 40, "runs {}", out.workflow_runs);
        // AL concentrates later samples on good configs: the mean truth
        // of the second half of samples should beat the first half.
        let half = out.measured.len() / 2;
        let first: f64 = out.measured[..half]
            .iter()
            .map(|&(i, _)| pool.truth_of(i))
            .sum::<f64>()
            / half as f64;
        let second: f64 = out.measured[half..]
            .iter()
            .map(|&(i, _)| pool.truth_of(i))
            .sum::<f64>()
            / (out.measured.len() - half) as f64;
        assert!(
            second < first,
            "active batches should be better than bootstrap: {first} vs {second}"
        );
    }

    #[test]
    fn tiny_budget_does_not_panic() {
        let prob = Problem::new(WorkflowId::GP, Objective::ExecTime);
        let pool = Pool::generate(&prob, 50, 12);
        let mut rng = Pcg32::new(5, 5);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 5, &mut rng);
        assert!(out.workflow_runs <= 5);
    }

    #[test]
    fn session_refits_every_batch() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 120, 13);
        let mut rng = Pcg32::new(8, 8);
        let tuner = ActiveLearning::default();
        let mut session = tuner.session(&prob, &pool, &Scorer::Native, 30, &mut rng);
        let mut col = super::super::Collector::new(&prob, Pcg32::new(9, 9));
        let mut batches = 0usize;
        loop {
            let batch = session.ask();
            if batch.is_empty() {
                break;
            }
            batches += 1;
            let results = super::super::session::Evaluator::evaluate(&mut col, &batch);
            session.tell(&results);
            assert_eq!(session.state().model_refits, batches);
        }
        // bootstrap + 6 refinement iterations
        assert_eq!(batches, 7);
        assert!(session.state().done);
    }
}
