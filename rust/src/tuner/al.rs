//! AL — batch active-learning baseline (§7.3, refs [4, 19]): seed with
//! random samples, then iteratively measure the configurations the
//! gradually-refined surrogate predicts to be best.

use std::collections::HashSet;

use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Collector, Pool, Problem,
    Tuner, TunerOutput,
};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

pub struct ActiveLearning {
    /// Fraction of the budget spent on the random bootstrap batch.
    pub m0_frac: f64,
    /// Refinement iterations.
    pub iterations: usize,
}

impl Default for ActiveLearning {
    fn default() -> Self {
        ActiveLearning {
            m0_frac: 0.25,
            iterations: 6,
        }
    }
}

impl Tuner for ActiveLearning {
    fn name(&self) -> &'static str {
        "AL"
    }

    fn run(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        m: usize,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");
        let m = m.min(pool.len());
        let m0 = ((m as f64 * self.m0_frac).round() as usize).clamp(1, m);
        let remaining = m - m0;
        let iters = self.iterations.min(remaining.max(1));
        let batch = if iters == 0 { 0 } else { remaining / iters };

        let mut measured: Vec<(usize, f64)> = Vec::with_capacity(m);
        let mut measured_set: HashSet<usize> = HashSet::with_capacity(m);
        for i in random_unmeasured(pool, &measured_set, m0, &mut sel_rng) {
            measured.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }

        let mut model = train_hifi(prob, pool, &measured);
        for _ in 0..iters {
            if batch == 0 {
                break;
            }
            let preds = scorer.score(&model, &pool.feats.workflow);
            for i in top_unmeasured(&preds, &measured_set, batch) {
                measured.push((i, col.measure(&pool.configs[i])));
                measured_set.insert(i);
            }
            model = train_hifi(prob, pool, &measured);
        }

        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn respects_budget_and_improves_sampling() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 11);
        let mut rng = Pcg32::new(4, 4);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 50, &mut rng);
        assert!(out.workflow_runs <= 50, "runs {}", out.workflow_runs);
        assert!(out.workflow_runs >= 40, "runs {}", out.workflow_runs);
        // AL concentrates later samples on good configs: the mean truth
        // of the second half of samples should beat the first half.
        let half = out.measured.len() / 2;
        let first: f64 = out.measured[..half]
            .iter()
            .map(|&(i, _)| pool.truth[i])
            .sum::<f64>()
            / half as f64;
        let second: f64 = out.measured[half..]
            .iter()
            .map(|&(i, _)| pool.truth[i])
            .sum::<f64>()
            / (out.measured.len() - half) as f64;
        assert!(
            second < first,
            "active batches should be better than bootstrap: {first} vs {second}"
        );
    }

    #[test]
    fn tiny_budget_does_not_panic() {
        let prob = Problem::new(WorkflowId::GP, Objective::ExecTime);
        let pool = Pool::generate(&prob, 50, 12);
        let mut rng = Pcg32::new(5, 5);
        let out = ActiveLearning::default().run(&prob, &pool, &Scorer::Native, 5, &mut rng);
        assert!(out.workflow_runs <= 5);
    }
}
