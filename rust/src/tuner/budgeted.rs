//! Resource-budgeted CEAL — the adaptation the paper sketches in §6:
//! "If a budget on real resource consumption is preferred, the
//! algorithm can be adapted to monitor the resource consumption of the
//! workflow and its component applications."
//!
//! Instead of a run-count budget m, [`BudgetedCeal`] is given a budget
//! in objective units (core-hours or seconds).  It spends a fraction on
//! component runs (phase 1), a fraction on random bootstrap, and the
//! rest on low-fidelity-guided batches, stopping a phase as soon as its
//! allowance is exhausted — so expensive samples shrink later batches
//! rather than overrunning the allocation.

use std::collections::HashSet;

use super::ceal::gbt_params_for;
use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, train_hifi, Collector, Pool, Problem,
    TunerOutput,
};
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::{ComponentSamples, LowFiModel};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

/// Cost-budgeted CEAL parameters.
#[derive(Clone, Copy, Debug)]
pub struct BudgetedCealParams {
    /// Fraction of the cost budget for component runs.
    pub component_frac: f64,
    /// Fraction of the cost budget for the random bootstrap.
    pub bootstrap_frac: f64,
    /// Active-learning batch size (configs per iteration).
    pub batch: usize,
}

impl Default for BudgetedCealParams {
    fn default() -> Self {
        BudgetedCealParams {
            component_frac: 0.30,
            bootstrap_frac: 0.10,
            batch: 4,
        }
    }
}

pub struct BudgetedCeal {
    pub params: BudgetedCealParams,
}

impl BudgetedCeal {
    pub fn new(params: BudgetedCealParams) -> BudgetedCeal {
        BudgetedCeal { params }
    }

    /// Run with a budget expressed in objective units (e.g. core-hours).
    pub fn run_with_cost_budget(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        cost_budget: f64,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        assert!(cost_budget > 0.0);
        let p = self.params;
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        let mut sel_rng = rng.derive_str("select");

        // Phase 1: component runs until the component allowance is spent.
        let comp_allowance = cost_budget * p.component_frac;
        let spec = &prob.sim.spec;
        let configurable = spec.configurable();
        let mut samples: Vec<ComponentSamples> =
            configurable.iter().map(|_| ComponentSamples::default()).collect();
        // An infeasible component skips only itself (matching CEAL /
        // ALpH); the loop ends when the allowance is spent or every
        // component is exhausted.
        let mut exhausted = vec![false; configurable.len()];
        'outer: loop {
            let mut progressed = false;
            for (slot, &comp) in configurable.iter().enumerate() {
                if exhausted[slot] {
                    continue;
                }
                if col.component_cost >= comp_allowance {
                    break 'outer;
                }
                match col.measure_component_sampled(comp, &mut sel_rng) {
                    Ok((cfg, y)) => {
                        samples[slot].push(spec.components[comp].encode(&cfg), y);
                        progressed = true;
                    }
                    Err(e) => {
                        eprintln!("warning: {e}; skipping its isolated runs");
                        exhausted[slot] = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        let n_feats = prob.n_component_features();
        let comp_params = gbt_params_for(samples.iter().map(|s| s.len()).max().unwrap_or(0));
        let lowfi = LowFiModel::fit(&samples, &n_feats, prob.objective, &comp_params);
        let lowfi_scores = lowfi.score(&pool.feats, scorer);

        // Phase 2: bootstrap + guided batches under the remaining budget.
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut measured_set: HashSet<usize> = HashSet::new();
        let boot_allowance = cost_budget * (p.component_frac + p.bootstrap_frac);
        while col.total_cost() < boot_allowance && measured_set.len() < pool.len() {
            let i = random_unmeasured(pool, &measured_set, 1, &mut sel_rng)[0];
            measured.push((i, col.measure(&pool.configs[i])));
            measured_set.insert(i);
        }

        let mut using_hifi = false;
        let mut hifi = if measured.len() >= 2 {
            Some(train_hifi(prob, pool, &measured))
        } else {
            None
        };
        while col.total_cost() < cost_budget && measured_set.len() < pool.len() {
            // M_L's pool scores are borrowed, not cloned, per round
            let hifi_scores;
            let scores: &[f64] = match (&hifi, using_hifi) {
                (Some(h), true) => {
                    hifi_scores = scorer.score(h, &pool.feats.workflow);
                    &hifi_scores
                }
                _ => &lowfi_scores,
            };
            let batch_idx = top_unmeasured(scores, &measured_set, p.batch.min(pool.len()));
            if batch_idx.is_empty() {
                break;
            }
            let mut batch: Vec<(usize, f64)> = Vec::new();
            for i in batch_idx {
                if col.total_cost() >= cost_budget {
                    break;
                }
                batch.push((i, col.measure(&pool.configs[i])));
                measured_set.insert(i);
            }
            if batch.is_empty() {
                break;
            }
            measured.extend_from_slice(&batch);
            if let Some(h) = &hifi {
                if !using_hifi {
                    let actual: Vec<f64> = measured.iter().map(|&(_, y)| y).collect();
                    let xs: Vec<_> = measured
                        .iter()
                        .map(|&(i, _)| pool.feats.workflow[i])
                        .collect();
                    let s_h = recall_sum_123(&scorer.score(h, &xs), &actual);
                    let pred_l: Vec<f64> =
                        measured.iter().map(|&(i, _)| lowfi_scores[i]).collect();
                    if s_h >= recall_sum_123(&pred_l, &actual) {
                        using_hifi = true;
                    }
                }
            }
            if measured.len() >= 2 {
                hifi = Some(train_hifi(prob, pool, &measured));
            }
        }

        let model = hifi.unwrap_or_else(|| crate::gbt::Ensemble::constant(1, 0.0));
        let best_idx = searcher_best(&model, pool, scorer, &measured);
        TunerOutput {
            model,
            measured,
            best_idx,
            collection_cost: col.total_cost(),
            workflow_runs: col.workflow_runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn respects_cost_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 150, 51);
        let mut rng = Pcg32::new(1, 1);
        let budget = 400.0; // core-hours
        let out = BudgetedCeal::new(BudgetedCealParams::default()).run_with_cost_budget(
            &prob,
            &pool,
            &Scorer::Native,
            budget,
            &mut rng,
        );
        // may overshoot by at most one sample's cost
        let max_sample = out
            .measured
            .iter()
            .map(|&(_, y)| y)
            .fold(0.0f64, f64::max)
            .max(100.0);
        assert!(
            out.collection_cost <= budget + max_sample,
            "cost {} far exceeds budget {budget}",
            out.collection_cost
        );
        assert!(out.workflow_runs >= 1);
        assert!(out.best_idx < pool.len());
    }

    #[test]
    fn bigger_budget_not_worse_on_average() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 52);
        let tuner = BudgetedCeal::new(BudgetedCealParams::default());
        let mut small_sum = 0.0;
        let mut large_sum = 0.0;
        for rep in 0..6 {
            let mut r1 = Pcg32::new(60 + rep, 1);
            let mut r2 = Pcg32::new(60 + rep, 2);
            let s = tuner.run_with_cost_budget(&prob, &pool, &Scorer::Native, 150.0, &mut r1);
            let l = tuner.run_with_cost_budget(&prob, &pool, &Scorer::Native, 1200.0, &mut r2);
            small_sum += pool.truth[s.best_idx];
            large_sum += pool.truth[l.best_idx];
        }
        assert!(
            large_sum <= small_sum * 1.1,
            "larger budget should not be clearly worse: {small_sum} vs {large_sum}"
        );
    }

    #[test]
    fn deterministic() {
        let prob = Problem::new(WorkflowId::HS, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 53);
        let tuner = BudgetedCeal::new(BudgetedCealParams::default());
        let run = |seed| {
            let mut rng = Pcg32::new(seed, 0);
            tuner
                .run_with_cost_budget(&prob, &pool, &Scorer::Native, 60.0, &mut rng)
                .best_idx
        };
        assert_eq!(run(4), run(4));
    }
}
