//! Resource-budgeted CEAL — the adaptation the paper sketches in §6:
//! "If a budget on real resource consumption is preferred, the
//! algorithm can be adapted to monitor the resource consumption of the
//! workflow and its component applications."
//!
//! Instead of a run-count budget m, [`BudgetedCeal`] is given a budget
//! in objective units (core-hours or seconds).  It spends a fraction on
//! component runs (phase 1), a fraction on random bootstrap, and the
//! rest on low-fidelity-guided batches, stopping a phase as soon as its
//! allowance is exhausted — so expensive samples shrink later batches
//! rather than overrunning the allocation.
//!
//! Session shape: because every stopping decision depends on the
//! *observed* cost of the previous sample, the session asks one
//! measurement at a time (each `tell` updates the spend before the
//! next `ask` re-checks its phase allowance) — the faithful stepwise
//! form of the monolithic per-sample loop.

use super::ceal::gbt_params_for;
use super::common::{
    random_unmeasured, searcher_best, top_unmeasured, top_unmeasured_model, Collector,
    Pool, Problem, TunerOutput,
};
use super::session::{
    drive, DiagSink, FailurePolicy, MeasurementBatch, MeasurementRequest, MeasurementResult,
    SessionCore, SessionDigest, SessionState, TunerSession,
};
use crate::config::F_MAX;
use crate::gbt::Ensemble;
use crate::metrics::recall_sum_123;
use crate::surrogate::lowfi::{ComponentSamples, LowFiModel};
use crate::surrogate::Scorer;
use crate::util::rng::Pcg32;

/// Cost-budgeted CEAL parameters.
#[derive(Clone, Copy, Debug)]
pub struct BudgetedCealParams {
    /// Fraction of the cost budget for component runs.
    pub component_frac: f64,
    /// Fraction of the cost budget for the random bootstrap.
    pub bootstrap_frac: f64,
    /// Active-learning batch size (configs per iteration).
    pub batch: usize,
}

impl Default for BudgetedCealParams {
    fn default() -> Self {
        BudgetedCealParams {
            component_frac: 0.30,
            bootstrap_frac: 0.10,
            batch: 4,
        }
    }
}

pub struct BudgetedCeal {
    pub params: BudgetedCealParams,
}

impl BudgetedCeal {
    pub fn new(params: BudgetedCealParams) -> BudgetedCeal {
        BudgetedCeal { params }
    }

    /// Open an ask/tell session with a budget expressed in objective
    /// units (e.g. core-hours).  The cost-budgeted algorithm is not a
    /// [`super::Tuner`] — its budget is a float, not a run count — but
    /// its session drives identically.
    pub fn session_with_cost_budget<'a>(
        &'a self,
        prob: &'a Problem,
        pool: &'a Pool,
        scorer: &'a Scorer,
        cost_budget: f64,
        rng: &mut Pcg32,
    ) -> Box<dyn TunerSession + 'a> {
        assert!(cost_budget > 0.0);
        let p = self.params;
        let configurable = prob.sim.spec.configurable();
        let n_comp = configurable.len();
        Box::new(BudgetedSession {
            core: SessionCore::new(prob, pool, scorer, rng),
            params: p,
            cost_budget,
            comp_allowance: cost_budget * p.component_frac,
            boot_allowance: cost_budget * (p.component_frac + p.bootstrap_frac),
            configurable,
            exhausted: vec![false; n_comp],
            cursor: 0,
            progressed: false,
            samples: (0..n_comp).map(|_| ComponentSamples::default()).collect(),
            lowfi_scores: Vec::new(),
            using_hifi: false,
            hifi: None,
            round: None,
            phase: Phase::Components,
            pending: Pending::None,
            retry: None,
            gate_q: Vec::new(),
            need_close: false,
        })
    }

    /// Run with a cost budget against the simulator:
    /// `drive(session, Collector)`.
    pub fn run_with_cost_budget(
        &self,
        prob: &Problem,
        pool: &Pool,
        scorer: &Scorer,
        cost_budget: f64,
        rng: &mut Pcg32,
    ) -> TunerOutput {
        let mut col = Collector::new(prob, rng.derive_str("collector"));
        drive(
            self.session_with_cost_budget(prob, pool, scorer, cost_budget, rng),
            &mut col,
        )
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    Components,
    Bootstrap,
    Guided,
    Done,
}

enum Pending {
    None,
    /// (configurable slot, encoded features, request for re-issue,
    /// attempt).
    Component(usize, [f32; F_MAX], MeasurementRequest, usize),
    /// (pool index, attempt).
    Workflow(usize, usize),
    /// Outlier-gate re-measure: (pool index, attempt).
    GateWorkflow(usize, usize),
}

/// One guided round: the selected batch and how far it got before the
/// budget intervened.
struct Round {
    batch_idx: Vec<usize>,
    pos: usize,
    taken: usize,
}

struct BudgetedSession<'a> {
    core: SessionCore<'a>,
    params: BudgetedCealParams,
    cost_budget: f64,
    comp_allowance: f64,
    boot_allowance: f64,
    configurable: Vec<usize>,
    exhausted: Vec<bool>,
    /// Round-robin position within the current component pass.
    cursor: usize,
    /// Did the current pass collect at least one sample?
    progressed: bool,
    samples: Vec<ComponentSamples>,
    lowfi_scores: Vec<f64>,
    using_hifi: bool,
    hifi: Option<Ensemble>,
    round: Option<Round>,
    phase: Phase,
    pending: Pending,
    /// A failed measurement with attempt budget left, re-issued by the
    /// next `ask` before any new work.
    retry: Option<Pending>,
    /// Outlier re-measures queued one at a time.
    gate_q: Vec<(usize, usize)>,
    /// The finished round still owes its `post_round` (deferred until
    /// the outlier gate drains).
    need_close: bool,
}

impl BudgetedSession<'_> {
    /// The legacy round-robin component loop, suspended at each
    /// measurement: returns the next component request, or `None` once
    /// the allowance is spent or no component can progress.
    fn next_component_request(&mut self) -> Option<MeasurementRequest> {
        loop {
            while self.cursor < self.configurable.len() {
                let slot = self.cursor;
                if self.exhausted[slot] {
                    self.cursor += 1;
                    continue;
                }
                if self.core.component_spend() >= self.comp_allowance {
                    return None; // `break 'outer`
                }
                let comp = self.configurable[slot];
                self.cursor += 1;
                match self
                    .core
                    .prob
                    .sim
                    .sample_component_feasible(comp, &mut self.core.sel_rng)
                {
                    Ok(cfg) => {
                        self.progressed = true;
                        let x = self.core.prob.sim.spec.components[comp].encode(&cfg);
                        let req = MeasurementRequest::Component { comp, config: cfg };
                        self.pending = Pending::Component(slot, x, req.clone(), 0);
                        return Some(req);
                    }
                    Err(e) => {
                        // an infeasible component skips only itself
                        self.core
                            .diag
                            .warn(format!("{e}; skipping its isolated runs"));
                        self.exhausted[slot] = true;
                    }
                }
            }
            if !self.progressed {
                return None;
            }
            self.progressed = false;
            self.cursor = 0;
        }
    }

    /// Close phase 1: fit M_L on whatever was collected.
    fn open_bootstrap(&mut self) {
        let prob = self.core.prob;
        let n_feats = prob.n_component_features();
        let comp_params = gbt_params_for(self.samples.iter().map(|s| s.len()).max().unwrap_or(0));
        let lowfi = LowFiModel::fit(&self.samples, &n_feats, prob.objective, &comp_params);
        self.lowfi_scores = lowfi.score(&self.core.pool.feats, self.core.scorer);
        self.core.refit();
        self.phase = Phase::Bootstrap;
    }

    /// Post-round processing: switch detection over everything
    /// measured, then retrain M_H (both exactly as the monolithic loop
    /// ordered them).
    fn post_round(&mut self) {
        let (pool, scorer) = (self.core.pool, self.core.scorer);
        if let Some(h) = &self.hifi {
            if !self.using_hifi {
                let actual: Vec<f64> = self.core.measured.iter().map(|&(_, y)| y).collect();
                let xs: Vec<_> = self
                    .core
                    .measured
                    .iter()
                    .map(|&(i, _)| pool.feats.workflow[i])
                    .collect();
                let s_h = recall_sum_123(&scorer.score(h, &xs), &actual);
                let pred_l: Vec<f64> = self
                    .core
                    .measured
                    .iter()
                    .map(|&(i, _)| self.lowfi_scores[i])
                    .collect();
                if s_h >= recall_sum_123(&pred_l, &actual) {
                    self.using_hifi = true;
                }
            }
        }
        if self.core.measured.len() >= 2 {
            let rows = self.core.train_measured();
            self.hifi = Some(self.core.fit_hifi(&rows));
            self.core.refit();
        }
    }
}

impl TunerSession for BudgetedSession<'_> {
    fn name(&self) -> &'static str {
        "budgeted-CEAL"
    }

    fn ask(&mut self) -> MeasurementBatch {
        assert!(
            matches!(self.pending, Pending::None),
            "ask() with results outstanding"
        );
        // a failed measurement with attempt budget left is re-issued
        // before any new work (even past a phase boundary: retries are
        // the overshoot-by-one the budget gate already tolerates)
        if let Some(p) = self.retry.take() {
            let req = match &p {
                Pending::Component(_, _, req, _) => req.clone(),
                Pending::Workflow(i, _) | Pending::GateWorkflow(i, _) => {
                    self.core.workflow_request(*i)
                }
                Pending::None => unreachable!("retry is never Pending::None"),
            };
            self.pending = p;
            self.core.asked_batches += 1;
            return MeasurementBatch::sequential(vec![req]);
        }
        loop {
            match self.phase {
                Phase::Components => {
                    if let Some(req) = self.next_component_request() {
                        self.core.asked_batches += 1;
                        return MeasurementBatch::sequential(vec![req]);
                    }
                    self.open_bootstrap();
                }
                Phase::Bootstrap => {
                    let pool = self.core.pool;
                    if self.core.total_cost() < self.boot_allowance
                        && self.core.measured_set.len() < pool.len()
                    {
                        let set = &self.core.measured_set;
                        let i = random_unmeasured(pool, set, 1, &mut self.core.sel_rng)[0];
                        self.core.measured_set.insert(i);
                        self.pending = Pending::Workflow(i, 0);
                        self.core.asked_batches += 1;
                        return MeasurementBatch::sequential(vec![self.core.workflow_request(i)]);
                    }
                    // bootstrap over: initial M_H when trainable
                    if self.core.measured.len() >= 2 {
                        let rows = self.core.train_measured();
                        self.hifi = Some(self.core.fit_hifi(&rows));
                        self.core.refit();
                    }
                    self.phase = Phase::Guided;
                }
                Phase::Guided => {
                    // drain the outlier gate one re-measure at a time,
                    // then run the deferred round close
                    if let Some((i, att)) = self.gate_q.first().copied() {
                        self.gate_q.remove(0);
                        self.pending = Pending::GateWorkflow(i, att);
                        self.core.asked_batches += 1;
                        let req = self.core.workflow_request(i);
                        return MeasurementBatch::sequential(vec![req]);
                    }
                    if self.need_close {
                        self.need_close = false;
                        self.post_round();
                        continue;
                    }
                    if let Some(round) = &mut self.round {
                        if round.pos < round.batch_idx.len()
                            && self.core.total_cost() < self.cost_budget
                        {
                            let i = round.batch_idx[round.pos];
                            round.pos += 1;
                            round.taken += 1;
                            self.core.measured_set.insert(i);
                            self.pending = Pending::Workflow(i, 0);
                            self.core.asked_batches += 1;
                            let req = self.core.workflow_request(i);
                            return MeasurementBatch::sequential(vec![req]);
                        }
                        // round finished (batch exhausted or budget hit)
                        let taken = self.round.take().map(|r| r.taken).unwrap_or(0);
                        if taken == 0 {
                            self.phase = Phase::Done;
                            continue;
                        }
                        let flagged = self.core.outlier_remeasure_picks();
                        if !flagged.is_empty() {
                            self.gate_q = flagged.into_iter().map(|i| (i, 0)).collect();
                            self.need_close = true;
                            continue;
                        }
                        self.post_round();
                    } else {
                        if self.core.total_cost() >= self.cost_budget
                            || self.core.measured_set.len() >= self.core.pool.len()
                        {
                            self.phase = Phase::Done;
                            continue;
                        }
                        // Hifi selection fuses score-and-select (no
                        // O(pool) score vector); M_L's materialized
                        // pool scores are borrowed, as before.
                        let k = self.params.batch.min(self.core.pool.len());
                        let batch_idx = match (&self.hifi, self.using_hifi) {
                            (Some(h), true) => top_unmeasured_model(
                                h,
                                self.core.pool,
                                self.core.scorer,
                                &self.core.measured_set,
                                k,
                            ),
                            _ => top_unmeasured(&self.lowfi_scores, &self.core.measured_set, k),
                        };
                        if batch_idx.is_empty() {
                            self.phase = Phase::Done;
                            continue;
                        }
                        self.round = Some(Round { batch_idx, pos: 0, taken: 0 });
                    }
                }
                Phase::Done => return MeasurementBatch::empty(),
            }
        }
    }

    fn tell(&mut self, results: &[MeasurementResult]) {
        assert_eq!(results.len(), 1, "tell() arity mismatch");
        self.core.told_batches += 1;
        let max_retries = self.core.policy.max_retries;
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => panic!("tell() without an outstanding batch"),
            Pending::Component(slot, x, req, att) => match results[0].value() {
                Some(y) => {
                    self.samples[slot].push(x, y);
                    self.core.record_component(y);
                }
                None => {
                    self.core.charge_failed_component(att);
                    if att < max_retries {
                        self.retry = Some(Pending::Component(slot, x, req, att + 1));
                    }
                    // exhausted: the round-robin pass simply moves on
                }
            },
            Pending::Workflow(i, att) => match results[0].value() {
                Some(y) => self.core.record_workflow(i, y),
                None => {
                    self.core.charge_failed_workflow(i, att);
                    if att < max_retries {
                        self.retry = Some(Pending::Workflow(i, att + 1));
                    }
                    // exhausted: the pick is skipped (it stays in the
                    // measured set so it is not re-selected)
                }
            },
            Pending::GateWorkflow(i, att) => match results[0].value() {
                Some(y) => self.core.replace_workflow(i, y),
                None => {
                    self.core.charge_failed_workflow(i, att);
                    if att < max_retries {
                        self.retry = Some(Pending::GateWorkflow(i, att + 1));
                    }
                    // exhausted: the winsorized original reading stands
                }
            },
        }
    }

    fn state(&self) -> SessionState {
        let (phase, done) = match self.phase {
            Phase::Components => ("components", false),
            Phase::Bootstrap => ("bootstrap", false),
            Phase::Guided => ("guided", false),
            Phase::Done => ("done", true),
        };
        let using = if self.lowfi_scores.is_empty() {
            None
        } else {
            Some(self.using_hifi)
        };
        self.core.state(phase, done, using)
    }

    fn digest(&self) -> Option<SessionDigest> {
        Some(self.core.digest(&self.state()))
    }

    fn finish(self: Box<Self>) -> TunerOutput {
        let model = self.hifi.unwrap_or_else(|| Ensemble::constant(1, 0.0));
        let core = self.core;
        let rows = core.train_measured();
        let best_idx = searcher_best(&model, core.pool, core.scorer, &rows);
        core.into_output(model, best_idx)
    }

    fn set_diag_sink(&mut self, sink: DiagSink) {
        self.core.diag.set_sink(sink);
    }

    fn diagnostics(&self) -> &[String] {
        self.core.diag.captured()
    }

    fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.core.policy = policy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkflowId;
    use crate::sim::Objective;

    #[test]
    fn respects_cost_budget() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 150, 51);
        let mut rng = Pcg32::new(1, 1);
        let budget = 400.0; // core-hours
        let out = BudgetedCeal::new(BudgetedCealParams::default()).run_with_cost_budget(
            &prob,
            &pool,
            &Scorer::Native,
            budget,
            &mut rng,
        );
        // may overshoot by at most one sample's cost
        let max_sample = out
            .measured
            .iter()
            .map(|&(_, y)| y)
            .fold(0.0f64, f64::max)
            .max(100.0);
        assert!(
            out.collection_cost <= budget + max_sample,
            "cost {} far exceeds budget {budget}",
            out.collection_cost
        );
        assert!(out.workflow_runs >= 1);
        assert!(out.best_idx < pool.len());
    }

    #[test]
    fn bigger_budget_not_worse_on_average() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 200, 52);
        let tuner = BudgetedCeal::new(BudgetedCealParams::default());
        let mut small_sum = 0.0;
        let mut large_sum = 0.0;
        for rep in 0..6 {
            let mut r1 = Pcg32::new(60 + rep, 1);
            let mut r2 = Pcg32::new(60 + rep, 2);
            let s = tuner.run_with_cost_budget(&prob, &pool, &Scorer::Native, 150.0, &mut r1);
            let l = tuner.run_with_cost_budget(&prob, &pool, &Scorer::Native, 1200.0, &mut r2);
            small_sum += pool.truth_of(s.best_idx);
            large_sum += pool.truth_of(l.best_idx);
        }
        assert!(
            large_sum <= small_sum * 1.1,
            "larger budget should not be clearly worse: {small_sum} vs {large_sum}"
        );
    }

    #[test]
    fn deterministic() {
        let prob = Problem::new(WorkflowId::HS, Objective::ExecTime);
        let pool = Pool::generate(&prob, 100, 53);
        let tuner = BudgetedCeal::new(BudgetedCealParams::default());
        let run = |seed| {
            let mut rng = Pcg32::new(seed, 0);
            tuner
                .run_with_cost_budget(&prob, &pool, &Scorer::Native, 60.0, &mut rng)
                .best_idx
        };
        assert_eq!(run(4), run(4));
    }

    /// The budget gate reacts to every told value: each ask carries
    /// exactly one request, and the session stops within one sample of
    /// the budget even when the driver feeds values it chooses.
    #[test]
    fn single_request_batches_and_stepwise_stop() {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 80, 54);
        let tuner = BudgetedCeal::new(BudgetedCealParams::default());
        let mut rng = Pcg32::new(2, 2);
        let mut session =
            tuner.session_with_cost_budget(&prob, &pool, &Scorer::Native, 100.0, &mut rng);
        let mut spent = 0.0;
        loop {
            let batch = session.ask();
            if batch.is_empty() {
                break;
            }
            assert_eq!(batch.len(), 1, "budgeted sessions step one sample at a time");
            // a synthetic driver: every measurement costs 9 units
            spent += 9.0;
            session.tell(&[MeasurementResult::ok(9.0)]);
        }
        let st = session.state();
        assert!(st.done);
        assert!((st.collection_cost - spent).abs() < 1e-9);
        // budget 100 at 9/sample: the session must stop within one
        // sample past the ceiling
        assert!(spent <= 100.0 + 9.0, "spent {spent}");
        let out = session.finish();
        assert!(out.best_idx < pool.len());
    }
}
