//! The low-fidelity workflow model (paper §4): per-component GBT models
//! combined by a structure-derived function — `max` for execution time
//! (Eqn 1), `sum` for computer time (Eqn 2).  Unlike ALpH, no workflow
//! run is needed to build it.

use crate::config::F_MAX;
use crate::gbt::{train_log, Ensemble, GbtParams};
use crate::sim::Objective;

use super::scorer::{PoolFeatures, Scorer};

/// Training data for one component model: its own feature encodings and
/// the objective values measured in *isolated* runs.
#[derive(Clone, Debug, Default)]
pub struct ComponentSamples {
    pub xs: Vec<[f32; F_MAX]>,
    pub y: Vec<f64>,
}

impl ComponentSamples {
    pub fn push(&mut self, x: [f32; F_MAX], y: f64) {
        self.xs.push(x);
        self.y.push(y);
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn extend_from(&mut self, other: &ComponentSamples) {
        self.xs.extend_from_slice(&other.xs);
        self.y.extend_from_slice(&other.y);
    }
}

/// The combined low-fidelity model M_L (Alg. 1 line 7).
#[derive(Clone, Debug)]
pub struct LowFiModel {
    /// One ensemble per configurable component, in spec order.
    pub comps: Vec<Ensemble>,
    pub objective: Objective,
}

impl LowFiModel {
    /// Train component models M_j on their samples (Alg. 1 lines 1-6)
    /// in log space and combine per the objective's function.
    pub fn fit(
        samples: &[ComponentSamples],
        n_features: &[usize],
        objective: Objective,
        params: &GbtParams,
    ) -> LowFiModel {
        assert_eq!(samples.len(), n_features.len());
        let comps = samples
            .iter()
            .zip(n_features)
            .map(|(s, &nf)| {
                if s.is_empty() {
                    // no data: constant log-time 0 (predicts 1 unit)
                    crate::gbt::Ensemble::constant(nf.max(1), 0.0)
                } else {
                    train_log(&s.xs, &s.y, nf.max(1), params)
                }
            })
            .collect();
        LowFiModel { comps, objective }
    }

    /// Score a pool: Score(c) = combine_j M_j(c_j) (Eqns 1-2).
    pub fn score(&self, feats: &PoolFeatures, scorer: &Scorer) -> Vec<f64> {
        scorer.lowfi(&self.comps, feats, self.objective)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lv_spec, Config};
    use crate::util::rng::Pcg32;

    #[test]
    fn fit_and_score_roundtrip() {
        let spec = lv_spec();
        let mut rng = Pcg32::new(4, 2);
        let configs: Vec<Config> = (0..60).map(|_| spec.sample(&mut rng)).collect();
        let feats = PoolFeatures::encode(&spec, &configs);

        // synthetic component truths: exec_j = 2 + 3*x0 (comp 0), 1 + x1 (comp 1)
        let mut s0 = ComponentSamples::default();
        let mut s1 = ComponentSamples::default();
        for i in 0..40 {
            let x0 = feats.per_component[0][i];
            let x1 = feats.per_component[1][i];
            s0.push(x0, 2.0 + 3.0 * x0[0] as f64);
            s1.push(x1, 1.0 + x1[1] as f64);
        }
        let lf = LowFiModel::fit(
            &[s0, s1],
            &[4, 3],
            Objective::ExecTime,
            &GbtParams::small_data(),
        );
        let scores = lf.score(&feats, &Scorer::Native);
        assert_eq!(scores.len(), 60);
        // exec combine = max over exp(log-space predictions)
        for i in 0..60 {
            let p0 = (lf.comps[0].predict(&feats.per_component[0][i]) as f64).exp();
            let p1 = (lf.comps[1].predict(&feats.per_component[1][i]) as f64).exp();
            assert!((scores[i] - p0.max(p1)).abs() < 1e-6 * p0.max(p1));
        }
        // the model should broadly rank big-x0 configs worse
        let lo_i = (0..60)
            .min_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        let hi_i = (0..60)
            .max_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        assert!(
            feats.per_component[0][lo_i][0] < feats.per_component[0][hi_i][0] + 0.3,
            "ranking should follow the synthetic trend"
        );
    }

    #[test]
    fn empty_samples_give_constant_models() {
        let lf = LowFiModel::fit(
            &[ComponentSamples::default(), ComponentSamples::default()],
            &[4, 3],
            Objective::CompTime,
            &GbtParams::small_data(),
        );
        assert_eq!(lf.comps.len(), 2);
        assert_eq!(lf.comps[0].n_trees(), 0);
    }
}
