//! Surrogate-model layer: the scoring backend (native vs PJRT), pool
//! feature encodings, and the low-fidelity component-combination model
//! (paper §4).

pub mod lowfi;
pub mod scorer;

pub use lowfi::LowFiModel;
pub use scorer::{PoolFeatures, Scorer, SCORE_CHUNK};
