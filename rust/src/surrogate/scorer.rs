//! Scoring backend and pool feature encodings.
//!
//! [`Scorer::Pjrt`] executes the AOT artifacts through the PJRT runtime
//! — the architecture's production hot path (L1 Pallas kernel inside an
//! L2 JAX graph, loaded by L3 Rust).  [`Scorer::Native`] is the exact
//! Rust mirror of the same flattened-ensemble semantics; integration
//! tests pin the two together, and multi-threaded campaigns use it to
//! avoid per-thread artifact recompilation.
//!
//! Since the ask/tell redesign the scorer is *session state*: a
//! [`crate::tuner::TunerSession`] captures its `&Scorer` at creation
//! and every model evaluation (selection scoring, switch detection,
//! the final searcher pass) happens inside the session — evaluators
//! and external drivers never see it.

use crate::config::{Config, WorkflowSpec, F_MAX};
use crate::gbt::{Ensemble, PoolCodes, QuantizedEnsemble, QUANTIZE_MIN_ROWS};
use crate::runtime::Runtime;
use crate::sim::Objective;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Fixed row width of the fused [`Scorer::score_fold`] chunks: small
/// enough that a chunk's scores live in a stack-adjacent scratch
/// buffer, below `Ensemble::predict_batch`'s internal parallel
/// threshold (each chunk evaluates serially inside its own task), and
/// independent of the worker count so chunk boundaries — and therefore
/// fold results — never change with parallelism.
pub const SCORE_CHUNK: usize = 256;

/// Warn exactly once per process when the PJRT backend degrades to
/// native scoring — the structured-failure analogue of a transport
/// fault: report it, keep the run alive.
fn warn_pjrt_degraded(what: &str, err: &crate::runtime::Error) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        eprintln!("warning: PJRT {what} failed ({err:#}); degrading to the native scorer");
    });
}

/// Lazily-built pool-resident [`PoolCodes`] for one feature view.
///
/// The codes depend only on the feature rows — never on a model — so
/// one build serves *every* refit against that view: subsequent
/// ensembles re-rank their thresholds into the fixed code grid
/// ([`QuantizedEnsemble::rerank`]) instead of re-coding the pool.
/// `get_or_build` races are resolved by `OnceLock` (first build wins;
/// any concurrent build of the same rows is bit-identical anyway).
pub struct CodeCache {
    slot: OnceLock<Arc<PoolCodes>>,
    builds: AtomicU64,
}

impl CodeCache {
    pub fn new() -> CodeCache {
        CodeCache { slot: OnceLock::new(), builds: AtomicU64::new(0) }
    }

    /// The pool codes for `rows`, building them on first use.  Callers
    /// must always pass the same rows for a given cache (the cache is
    /// owned by the feature view it encodes).
    pub fn get_or_build(&self, rows: &[[f32; F_MAX]]) -> Arc<PoolCodes> {
        self.slot
            .get_or_init(|| {
                self.builds.fetch_add(1, Ordering::Relaxed);
                Arc::new(PoolCodes::build(rows))
            })
            .clone()
    }

    /// How many times this cache actually coded its rows (0 or 1 —
    /// asserted by the amortization tests).
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Resident bytes of the built codes (0 before first use).
    pub fn approx_bytes(&self) -> usize {
        self.slot.get().map_or(0, |c| c.approx_bytes())
    }
}

impl Default for CodeCache {
    fn default() -> Self {
        CodeCache::new()
    }
}

impl std::fmt::Debug for CodeCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodeCache")
            .field("built", &self.slot.get().is_some())
            .field("builds", &self.builds())
            .finish()
    }
}

/// A borrowed feature view: the rows plus (optionally) their
/// pool-resident code cache.  Views over the full pool carry a cache
/// and take the amortized re-rank route at pool scale; ad-hoc row sets
/// (measured subsets, single configs) use [`FeatView::plain`] and fall
/// back to direct prediction.
#[derive(Clone, Copy)]
pub struct FeatView<'a> {
    pub rows: &'a [[f32; F_MAX]],
    pub codes: Option<&'a CodeCache>,
}

impl<'a> FeatView<'a> {
    /// A view with no code cache (small or one-off row sets).
    pub fn plain(rows: &'a [[f32; F_MAX]]) -> FeatView<'a> {
        FeatView { rows, codes: None }
    }
}

/// Precomputed feature encodings for a fixed configuration pool.
#[derive(Clone, Debug)]
pub struct PoolFeatures {
    /// Whole-workflow view (high-fidelity model input), one row/config.
    pub workflow: Vec<[f32; F_MAX]>,
    /// Per configurable component: that component's view of each config.
    pub per_component: Vec<Vec<[f32; F_MAX]>>,
    /// Indices of the configurable components in the workflow spec.
    pub configurable: Vec<usize>,
    /// Real (unpadded) feature count of the workflow view — lanes
    /// `n_workflow..F_MAX` are zero padding in every row.
    pub n_workflow: usize,
    /// Once-per-pool rank codes of the workflow view (built lazily on
    /// the first pool-scale scoring pass; `Clone` shares the cache).
    pub workflow_codes: Arc<CodeCache>,
    /// Once-per-pool rank codes of each per-component view.
    pub component_codes: Vec<Arc<CodeCache>>,
}

impl PoolFeatures {
    pub fn encode(spec: &WorkflowSpec, configs: &[Config]) -> PoolFeatures {
        let configurable = spec.configurable();
        let component_codes = configurable.iter().map(|_| Arc::new(CodeCache::new())).collect();
        PoolFeatures {
            workflow: configs.iter().map(|c| spec.encode_workflow(c)).collect(),
            per_component: configurable
                .iter()
                .map(|&j| configs.iter().map(|c| spec.encode_component(c, j)).collect())
                .collect(),
            configurable,
            n_workflow: spec.n_params(),
            workflow_codes: Arc::new(CodeCache::new()),
            component_codes,
        }
    }

    pub fn len(&self) -> usize {
        self.workflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workflow.is_empty()
    }

    /// The workflow rows with their pool-resident code cache.
    pub fn workflow_view(&self) -> FeatView<'_> {
        FeatView { rows: &self.workflow, codes: Some(&self.workflow_codes) }
    }

    /// Component view `k` (index into `per_component`) with its cache.
    pub fn component_view(&self, k: usize) -> FeatView<'_> {
        FeatView { rows: &self.per_component[k], codes: Some(&self.component_codes[k]) }
    }

    /// Row-subset view (for scoring C_meas etc.).  Subsets carry fresh
    /// (empty) code caches: they are measured-set-sized, so they score
    /// directly and never pay a code build.
    pub fn subset(&self, idx: &[usize]) -> PoolFeatures {
        let component_codes =
            self.per_component.iter().map(|_| Arc::new(CodeCache::new())).collect();
        PoolFeatures {
            workflow: idx.iter().map(|&i| self.workflow[i]).collect(),
            per_component: self
                .per_component
                .iter()
                .map(|v| idx.iter().map(|&i| v[i]).collect())
                .collect(),
            configurable: self.configurable.clone(),
            n_workflow: self.n_workflow,
            workflow_codes: Arc::new(CodeCache::new()),
            component_codes,
        }
    }
}

/// Scoring backend.  (The PJRT variant carries a whole runtime; the
/// enum is built once per worker, so the size asymmetry is fine.)
#[allow(clippy::large_enum_variant)]
pub enum Scorer {
    /// Exact Rust evaluation of the flattened-ensemble semantics.
    Native,
    /// AOT artifacts over PJRT (the three-layer hot path).
    Pjrt(Runtime),
}

impl Scorer {
    /// Load the PJRT backend, falling back to Native (with a warning on
    /// stderr) when artifacts are unavailable.
    pub fn pjrt_or_native() -> Scorer {
        match Runtime::load_default() {
            Ok(rt) => Scorer::Pjrt(rt),
            Err(e) => {
                eprintln!("warning: PJRT runtime unavailable ({e:#}); using native scorer");
                Scorer::Native
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scorer::Native => "native",
            Scorer::Pjrt(_) => "pjrt",
        }
    }

    /// Score rows with a single ensemble (high-fidelity model or one
    /// component model). Returns f64 for downstream stats.
    ///
    /// The native path rides `Ensemble::predict_batch`: pool-sized row
    /// batches shard across the process worker pool (bit-identical for
    /// any worker count), while small batches — the tuners' per-config
    /// calls — skip the dispatch entirely.
    pub fn score(&self, ens: &Ensemble, xs: &[[f32; F_MAX]]) -> Vec<f64> {
        self.score_view(ens, FeatView::plain(xs))
    }

    /// [`score`](Self::score) over a [`FeatView`]: when the view
    /// carries a pool-resident [`CodeCache`], pool-scale native scoring
    /// re-ranks the ensemble's thresholds into the cached codes
    /// (O(trees·depth·log uniques)) instead of re-coding all rows.
    pub fn score_view(&self, ens: &Ensemble, view: FeatView<'_>) -> Vec<f64> {
        match self {
            Scorer::Native => native_preds_view(ens, view).into_iter().map(|v| v as f64).collect(),
            Scorer::Pjrt(rt) => match rt.score(&ens.flatten(), view.rows) {
                Ok(v) => v.into_iter().map(|v| v as f64).collect(),
                // A backend fault degrades like a transport failure:
                // warn once, answer from the exact native mirror.
                Err(e) => {
                    warn_pjrt_degraded("ensemble scoring", &e);
                    native_preds_view(ens, view).into_iter().map(|v| v as f64).collect()
                }
            },
        }
    }

    /// Fused score-and-fold: evaluate `model` over `xs` in fixed
    /// [`SCORE_CHUNK`]-row chunks and fold each chunk's raw (log-space,
    /// `f64`) predictions into a per-chunk accumulator, returning the
    /// accumulators in chunk order — the streaming backbone of
    /// `top_unmeasured_model`/`searcher_best`, which never allocate an
    /// O(pool) score vector.
    ///
    /// Per-row predictions are bitwise identical to
    /// [`score`](Self::score) on the native path (`predict_batch` is
    /// chunk-size-invariant, and the quantized pool-scale route is
    /// bitwise-pinned to it), so any order-respecting reduction over
    /// the folds equals the same reduction over the materialized
    /// vector.  Native chunks fan across the worker pool (fixed
    /// boundaries, one accumulator per chunk — worker-count-invariant);
    /// the PJRT path walks chunks sequentially on the calling thread,
    /// degrading any backend fault to the native mirror with a
    /// one-time warning.
    pub fn score_fold<R: Send>(
        &self,
        ens: &Ensemble,
        xs: &[[f32; F_MAX]],
        make: impl Fn() -> R + Sync,
        fold: impl Fn(&mut R, usize, &[f64]) + Sync,
    ) -> Vec<R> {
        self.score_fold_view(ens, FeatView::plain(xs), make, fold)
    }

    /// [`score_fold`](Self::score_fold) over a [`FeatView`]; with a
    /// code cache the pool-scale quantized route becomes a per-refit
    /// threshold re-rank against the once-per-pool codes.
    pub fn score_fold_view<R: Send>(
        &self,
        ens: &Ensemble,
        view: FeatView<'_>,
        make: impl Fn() -> R + Sync,
        fold: impl Fn(&mut R, usize, &[f64]) + Sync,
    ) -> Vec<R> {
        let xs = view.rows;
        let n = xs.len();
        if n == 0 {
            return Vec::new();
        }
        let n_chunks = n.div_ceil(SCORE_CHUNK);
        match self {
            Scorer::Native => {
                // Pool-scale batches traverse the quantized SoA
                // columns, shared read-only across every chunk task.
                // A cached view re-ranks thresholds into its resident
                // codes; only cache-less views pay the O(n·F) recode.
                let quant = (n >= QUANTIZE_MIN_ROWS).then(|| match view.codes {
                    Some(cache) => QuantizedEnsemble::rerank(ens, &cache.get_or_build(xs)),
                    None => QuantizedEnsemble::build(ens, xs),
                });
                let width = crate::util::parallel::width_for(n, QUANTIZE_MIN_ROWS.min(1024));
                crate::util::parallel::map_indexed(width, n_chunks, |ci| {
                    let lo = ci * SCORE_CHUNK;
                    let hi = (lo + SCORE_CHUNK).min(n);
                    let preds: Vec<f64> = match &quant {
                        Some(q) => {
                            let mut buf = vec![0.0f32; hi - lo];
                            q.predict_range_into(lo, &mut buf);
                            buf.into_iter().map(|v| v as f64).collect()
                        }
                        None => ens
                            .predict_batch(&xs[lo..hi])
                            .into_iter()
                            .map(|v| v as f64)
                            .collect(),
                    };
                    let mut acc = make();
                    fold(&mut acc, lo, &preds);
                    acc
                })
            }
            Scorer::Pjrt(rt) => {
                let flat = ens.flatten();
                let mut out = Vec::with_capacity(n_chunks);
                for ci in 0..n_chunks {
                    let lo = ci * SCORE_CHUNK;
                    let hi = (lo + SCORE_CHUNK).min(n);
                    let preds: Vec<f64> = match rt.score(&flat, &xs[lo..hi]) {
                        Ok(v) => v.into_iter().map(|v| v as f64).collect(),
                        Err(e) => {
                            warn_pjrt_degraded("ensemble scoring", &e);
                            native_preds_view(ens, FeatView::plain(&xs[lo..hi]))
                                .into_iter()
                                .map(|v| v as f64)
                                .collect()
                        }
                    };
                    let mut acc = make();
                    fold(&mut acc, lo, &preds);
                    out.push(acc);
                }
                out
            }
        }
    }

    /// Real-scale (exponentiated) predictions of a log-space model:
    /// [`score`](Self::score) mapped through `exp`, the form every
    /// searcher/metric consumer wants.
    pub fn score_times(&self, ens: &Ensemble, xs: &[[f32; F_MAX]]) -> Vec<f64> {
        self.score(ens, xs).into_iter().map(f64::exp).collect()
    }

    /// Low-fidelity combined score (Eqns 1-2) over per-component views.
    /// Component models are log-space: each prediction is exponentiated
    /// back to a time before the max/sum combination (matching the
    /// lowfi artifact's semantics).  Each component's batched
    /// predictions parallelize row-wise like [`score`](Self::score);
    /// the cheap exp/combine fold stays sequential in row order, so the
    /// combined scores are bit-identical for any worker count.
    pub fn lowfi(
        &self,
        comps: &[Ensemble],
        feats: &PoolFeatures,
        objective: Objective,
    ) -> Vec<f64> {
        assert_eq!(comps.len(), feats.per_component.len());
        match self {
            Scorer::Native => native_lowfi(comps, feats, objective),
            Scorer::Pjrt(rt) => {
                let packed: Vec<(crate::gbt::FlatEnsemble, &[[f32; F_MAX]])> = comps
                    .iter()
                    .zip(&feats.per_component)
                    .map(|(e, xs)| (e.flatten(), xs.as_slice()))
                    .collect();
                match rt.lowfi_score(&packed, objective.mode()) {
                    Ok(v) => v.into_iter().map(|v| v as f64).collect(),
                    // Same degradation contract as `score`: a backend
                    // fault must not kill the session.
                    Err(e) => {
                        warn_pjrt_degraded("lowfi scoring", &e);
                        native_lowfi(comps, feats, objective)
                    }
                }
            }
        }
    }
}

/// Native batch predictions, routed through the quantized SoA kernel
/// at pool scale.  `QuantizedEnsemble::predict_all` is bitwise-pinned
/// to `Ensemble::predict_batch` (and `rerank` to `build`), so the
/// cutover is invisible to every equivalence test — it only changes
/// how fast the answer arrives.  Views with a [`CodeCache`] re-rank
/// into the resident codes; plain views code on the spot.
fn native_preds_view(ens: &Ensemble, view: FeatView<'_>) -> Vec<f32> {
    let xs = view.rows;
    if xs.len() >= QUANTIZE_MIN_ROWS {
        match view.codes {
            Some(cache) => QuantizedEnsemble::rerank(ens, &cache.get_or_build(xs)).predict_all(),
            None => QuantizedEnsemble::build(ens, xs).predict_all(),
        }
    } else {
        ens.predict_batch(xs)
    }
}

/// Native low-fidelity combine: fold each component's batched
/// predictions straight into the combined score — no per-row `parts`
/// vector, no per-component score matrix.  Matches
/// `Objective::combine` over exp(prediction): max folds from -inf,
/// sum folds from 0.  Also the fallback target when the PJRT lowfi
/// path degrades.  Component predictions ride the per-component code
/// caches, so repeated lowfi passes over the same pool re-rank rather
/// than re-code.
fn native_lowfi(comps: &[Ensemble], feats: &PoolFeatures, objective: Objective) -> Vec<f64> {
    let init = match objective {
        Objective::ExecTime => f64::NEG_INFINITY,
        Objective::CompTime => 0.0,
    };
    let mut out = vec![init; feats.len()];
    for (k, (e, xs)) in comps.iter().zip(&feats.per_component).enumerate() {
        // ragged views must fail loudly, not leave `init` rows that
        // would read as best-possible scores
        assert_eq!(xs.len(), out.len(), "ragged per-component views");
        let preds = native_preds_view(e, feats.component_view(k));
        match objective {
            Objective::ExecTime => {
                for (o, p) in out.iter_mut().zip(&preds) {
                    *o = o.max((*p as f64).exp());
                }
            }
            Objective::CompTime => {
                for (o, p) in out.iter_mut().zip(&preds) {
                    *o += (*p as f64).exp();
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::lv_spec;
    use crate::gbt::{train, GbtParams};
    use crate::util::rng::Pcg32;

    fn toy_pool() -> (crate::config::WorkflowSpec, Vec<Config>) {
        let spec = lv_spec();
        let mut rng = Pcg32::new(9, 9);
        let configs: Vec<Config> = (0..40).map(|_| spec.sample(&mut rng)).collect();
        (spec, configs)
    }

    #[test]
    fn encode_shapes() {
        let (spec, configs) = toy_pool();
        let f = PoolFeatures::encode(&spec, &configs);
        assert_eq!(f.len(), 40);
        assert_eq!(f.per_component.len(), 2);
        assert_eq!(f.configurable, vec![0, 1]);
        // workflow view uses 7 features, padding zero
        assert_eq!(f.workflow[0][7], 0.0);
    }

    #[test]
    fn subset_selects_rows() {
        let (spec, configs) = toy_pool();
        let f = PoolFeatures::encode(&spec, &configs);
        let s = f.subset(&[3, 7]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.workflow[0], f.workflow[3]);
        assert_eq!(s.per_component[1][1], f.per_component[1][7]);
    }

    #[test]
    fn native_lowfi_max_and_sum() {
        let (spec, configs) = toy_pool();
        let f = PoolFeatures::encode(&spec, &configs);
        let mut rng = Pcg32::new(1, 1);
        // train two tiny component models on synthetic targets
        let mk = |rng: &mut Pcg32, xs: &Vec<[f32; F_MAX]>| {
            let y: Vec<f64> = xs.iter().map(|x| 1.0 + x[0] as f64 + rng.f64() * 0.01).collect();
            train(xs, &y, 4, &GbtParams::small_data())
        };
        let comps = vec![
            mk(&mut rng, &f.per_component[0]),
            mk(&mut rng, &f.per_component[1]),
        ];
        let scorer = Scorer::Native;
        let mx = scorer.lowfi(&comps, &f, Objective::ExecTime);
        let sm = scorer.lowfi(&comps, &f, Objective::CompTime);
        for i in 0..f.len() {
            // log-space models: combination happens on exp(prediction)
            let p0 = (comps[0].predict(&f.per_component[0][i]) as f64).exp();
            let p1 = (comps[1].predict(&f.per_component[1][i]) as f64).exp();
            assert!((mx[i] - p0.max(p1)).abs() < 1e-6 * p0.max(p1));
            assert!((sm[i] - (p0 + p1)).abs() < 1e-6 * (p0 + p1));
        }
    }

    #[test]
    fn cached_view_matches_plain_scoring_and_codes_once() {
        // Pool large enough to cross QUANTIZE_MIN_ROWS, so the cached
        // view takes the re-rank route and the plain call the full
        // build route — results must agree bit for bit, and repeated
        // scoring passes must code the pool exactly once.
        let spec = lv_spec();
        let mut rng = Pcg32::new(31, 5);
        let configs: Vec<Config> =
            (0..QUANTIZE_MIN_ROWS + 64).map(|_| spec.sample(&mut rng)).collect();
        let f = PoolFeatures::encode(&spec, &configs);
        let y: Vec<f64> = f.workflow[..64].iter().map(|x| 1.5 + x[0] as f64).collect();
        let models: Vec<Ensemble> = (0..3)
            .map(|k| {
                let yk: Vec<f64> = y.iter().map(|v| v + k as f64 * 0.1).collect();
                train(&f.workflow[..64], &yk, f.n_workflow, &GbtParams::small_data())
            })
            .collect();
        let scorer = Scorer::Native;
        for ens in &models {
            let plain = scorer.score(ens, &f.workflow);
            let cached = scorer.score_view(ens, f.workflow_view());
            assert_eq!(plain.len(), cached.len());
            for (a, b) in plain.iter().zip(&cached) {
                assert_eq!(a.to_bits(), b.to_bits(), "view scoring must be bitwise exact");
            }
            // the fused fold sees the same per-row bits
            let folded = scorer.score_fold_view(
                ens,
                f.workflow_view(),
                Vec::new,
                |acc: &mut Vec<f64>, _lo, preds| acc.extend_from_slice(preds),
            );
            let flat: Vec<f64> = folded.into_iter().flatten().collect();
            for (a, b) in plain.iter().zip(&flat) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(
            f.workflow_codes.builds(),
            1,
            "three models x two passes each must share one pool code build"
        );
    }
}
